"""Distributed epidemiology with delta-encoded aura exchange — the paper's
seamless laptop-to-cluster story (§3.4): the model definition is identical to
the single-device case; only the mesh changes.

    PYTHONPATH=src python examples/epidemic_distributed.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DeltaConfig
from repro.launch.mesh import make_abm_mesh
from repro.sims import epidemiology


def main():
    mesh = make_abm_mesh((2, 2))
    delta = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=8)
    state, metrics = epidemiology.run(
        n_agents=800, steps=60, initial_infected=20,
        mesh=mesh, mesh_shape=(2, 2), interior=(5, 5), delta=delta)
    ser = metrics["series"]
    print("   t     S     I     R")
    for t in range(0, len(ser), 10):
        s, i, r = ser[t]
        print(f"{t:4d} {s:5d} {i:5d} {r:5d}")
    print(f"\nfinal attack rate: {ser[-1, 2] / ser[0].sum():.1%} "
          f"(aura wire bytes/iter: {int(state.halo_bytes[0, 0])})")
    print("4 devices, delta-encoded aura exchange, identical model code.")


if __name__ == "__main__":
    main()
