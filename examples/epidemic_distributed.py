"""Distributed epidemiology with delta-encoded aura exchange — the paper's
seamless laptop-to-cluster story (§3.4): the model definition is identical to
the single-device case; only the mesh shape changes, and the Simulation
facade builds and owns the spatial device mesh.

    PYTHONPATH=src python examples/epidemic_distributed.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax.numpy as jnp
import numpy as np

from repro.core import DeltaConfig
from repro.sims import epidemiology


def main():
    delta = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=8)
    # identical model code as 1 device: only mesh_shape differs — the
    # facade derives the (sx, sy) device mesh from the geometry itself
    sim = epidemiology.simulation(
        n_agents=800, initial_infected=20,
        mesh_shape=(2, 2), interior=(5, 5), delta=delta)
    sim.run(60)
    ser = np.array(sim.series["sir"])
    print("   t     S     I     R")
    for t in range(0, len(ser), 10):
        s, i, r = ser[t]
        print(f"{t:4d} {s:5d} {i:5d} {r:5d}")
    print(f"\nfinal attack rate: {ser[-1, 2] / ser[0].sum():.1%} "
          f"(aura wire bytes/iter: {int(sim.state.halo_bytes[0, 0])})")
    print(f"{np.prod(sim.engine.geom.mesh_shape)} devices, delta-encoded "
          "aura exchange, identical model code.")


if __name__ == "__main__":
    main()
