"""3-D tumor spheroid on a sharded spatial mesh — the one-argument
2-D -> 3-D story of the N-D Domain (docs/domains.md).

The model (``sims/tumor_spheroid.py``: soft-sphere mechanics composed with
nutrient-gated proliferation) is written exactly like the 2-D sims; making
it 3-D and distributed is the geometry argument only: a 3-axis ``interior``
and a ``(1, 1, 2)`` spatial device mesh, sharding the tissue along z.  The
halo exchange runs over all 6 directed edges with delta encoding, and the
one-pass migration forwards corner migrants across all three axes.

With ``--ownership rcb`` the spheroid seeds *off-center* (most of the
tissue in one device's half) and the dynamic load balancer re-cuts the z
axis into uneven slabs — box-granular RCB ownership on padded per-device
grids with masked halo exchange (docs/load_balancing.md).

    PYTHONPATH=src python examples/spheroid_3d.py [--ownership rcb]
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax.numpy as jnp
import numpy as np

from repro.core import DeltaConfig, Rebalance
from repro.sims import tumor_spheroid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ownership", default="equal",
                    choices=["equal", "rcb"])
    args = ap.parse_args()

    delta = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=8)
    rebalance = None
    center_frac = None
    if args.ownership == "rcb":
        # off-center on EVERY axis: no equal split along any mesh
        # factorization can balance, only an uneven cut through the ball
        center_frac = (0.3, 0.3, 0.3)
        rebalance = Rebalance(every=5, threshold=0.3, ownership="rcb")
    # identical model code as one device: only the Domain arguments differ —
    # the facade derives the (sx, sy, sz) device mesh from the geometry
    # the off-center ball concentrates the proliferating tissue in a few
    # cells: a generous cap keeps the densest cell from overflowing
    sim = tumor_spheroid.simulation(
        n_agents=40, mesh_shape=(1, 1, 2), interior=(6, 6, 3), delta=delta,
        rebalance=rebalance, center_frac=center_frac,
        cap=64 if args.ownership == "rcb" else 32)
    n0 = sim.n_agents()
    d0 = tumor_spheroid.spheroid_diameter(sim.state)
    sim.run(15, collect=lambda s: (
        int(np.sum(np.asarray(s.soa.valid))),
        tumor_spheroid.spheroid_diameter(s)))
    series = sim.series["collect"]
    print("   t  cells  spheroid_diam")
    for t in range(0, len(series), 5):
        n, d = series[t]
        print(f"{t:4d} {n:6d} {d:14.2f}")
    n1, d1 = series[-1]
    print(f"\ncells {n0} -> {n1}, bounding-box diameter "
          f"{d0:.2f} -> {d1:.2f}")
    print(f"{np.prod(sim.engine.geom.mesh_shape)} devices over mesh "
          f"{sim.engine.geom.mesh_shape}, 6-edge delta-encoded aura "
          f"exchange ({int(sim.state.halo_bytes.ravel()[0])} wire "
          "bytes/iter), zero drops:", int(sim.state.dropped.sum()))
    if args.ownership == "rcb":
        applied = [r for r in sim.rebalancer.history if r["applied"]]
        assert applied and sim.engine.geom.uneven, sim.rebalancer.history
        print(f"uneven re-cut at it {applied[0]['it']}: z slab widths "
              f"{sim.engine.geom.partition.widths[2]} (cells), imbalance "
              f"{applied[0]['imbalance_before']:.2f} -> "
              f"{applied[0]['imbalance_after']:.2f}")
    assert n1 > n0 and int(sim.state.dropped.sum()) == 0


if __name__ == "__main__":
    main()
