"""Quickstart: define agents, behaviors, and run a simulation — the paper's
three-step modeling workflow (§1) in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, Engine, GridGeom, total_agents
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion

# 1. What is an agent?  A position plus these attributes:
schema = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})

# 2. How does it behave?  Same-type adhesion + soft-sphere repulsion,
#    overdamped displacement dynamics:
behavior = Behavior(
    schema=schema,
    pair_fn=soft_repulsion_adhesion,
    pair_attrs=("diameter", "ctype"),
    update_fn=displacement_update,
    radius=2.0,
    params={"repulsion": 2.0, "adhesion": 0.6, "same_type_only": 1.0,
            "max_step": 0.5},
)

# 3. Initial condition: 400 agents of two types, uniformly placed.
engine = Engine(
    geom=GridGeom(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1), cap=64),
    behavior=behavior, dt=0.1,
)
rng = np.random.default_rng(0)
n = 400
pos = rng.uniform(0.5, 15.5, size=(n, 2)).astype(np.float32)
state = engine.init_state(pos, {
    "diameter": np.full((n,), 1.0, np.float32),
    "ctype": rng.integers(0, 2, n).astype(np.int32),
}, seed=0)

step = engine.make_local_step()
for i in range(30):
    state = step(state, full_halo=True)

print(f"agents: {total_agents(state)} (conserved), "
      f"iterations: {int(state.it[0, 0])}, "
      f"dropped: {int(state.dropped.sum())}")
print("The same Behavior runs unchanged on a multi-pod mesh via "
      "engine.make_sharded_step(mesh) — see examples/epidemic_distributed.py")
