"""Quickstart: define agents, behaviors, and run a simulation — the paper's
three-step modeling workflow (§1) on the ``Simulation`` facade.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, Simulation, operations
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion

# 1. What is an agent?  A position plus these attributes:
schema = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})

# 2. How does it behave?  Same-type adhesion + soft-sphere repulsion,
#    overdamped displacement dynamics:
behavior = Behavior(
    schema=schema,
    pair_fn=soft_repulsion_adhesion,
    pair_attrs=("diameter", "ctype"),
    update_fn=displacement_update,
    radius=2.0,
    params={"repulsion": 2.0, "adhesion": 0.6, "same_type_only": 1.0,
            "max_step": 0.5},
)

# 3. Initial condition + run: the Simulation facade owns the engine, the
#    device mesh, the state, and any scheduled operations.
sim = Simulation(dict(cell_size=2.0, interior=(8, 8), cap=64),
                 behavior, dt=0.1)
rng = np.random.default_rng(0)
n = 400
pos = rng.uniform(0.5, 15.5, size=(n, 2)).astype(np.float32)
sim.init(pos, {
    "diameter": np.full((n,), 1.0, np.float32),
    "ctype": rng.integers(0, 2, n).astype(np.int32),
}, seed=0)

sim.every(10, operations.agent_count)   # scheduled SumOverAllRanks reducer
sim.run(30)

print(f"agents: {sim.n_agents()} (conserved), "
      f"iterations: {sim.iteration}, "
      f"dropped: {int(sim.state.dropped.sum())}, "
      f"count series: {sim.series['agent_count']}")
print("The same Simulation runs unchanged on a multi-device mesh — set "
      "mesh_shape=(2, 2) in the geometry (see "
      "examples/epidemic_distributed.py) — and behaviors stack with "
      "compose() (see examples/sir_mechanics_demo.py).")
