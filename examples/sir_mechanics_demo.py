"""Composable behavior stacks: SIR epidemic on top of cell mechanics.

Two library behaviors — the clustering mechanics from
``sims.cell_clustering`` and the SIR compartment dynamics from
``sims.epidemiology`` — are merged with ``compose()`` into one model: the
pair kernels share a single neighborhood sweep (infection gated to its
smaller radius), the updates chain, and the infection spreads along the
contact structure the adhesion dynamics create.  No hand-fused kernel.

    PYTHONPATH=src python examples/sir_mechanics_demo.py
"""

import numpy as np

from repro.sims import sir_mechanics
from repro.sims.cell_clustering import same_type_fraction


def main():
    sim = sir_mechanics.simulation(n_agents=400, initial_infected=20, seed=0)
    f0 = same_type_fraction(sim.state, sim.engine)
    sim.run(40)
    f1 = same_type_fraction(sim.state, sim.engine)

    ser = np.array(sim.series["sir"])
    print("   t     S     I     R")
    for t in range(0, len(ser), 8):
        s, i, r = ser[t]
        print(f"{t:4d} {s:5d} {i:5d} {r:5d}")
    print(f"\nattack rate: {ser[-1, 2] / ser[0].sum():.1%}, "
          f"same-type contact fraction {f0:.2f} -> {f1:.2f}")
    print("compose(mechanics, sir): one neighborhood sweep, two behaviors, "
          "zero fused-kernel code.")


if __name__ == "__main__":
    main()
