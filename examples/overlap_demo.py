"""Communication-budget smoke (docs/performance.md §4): the three layers
that keep the wire off the critical path, end-to-end on a 2x2 host-device
mesh.

* **Overlap** — ``overlap="on"`` splits every sweep into an interior pass
  (scheduled concurrently with the ``ppermute`` aura exchange) and a
  boundary pass that consumes the received ring; results are pinned
  bit-exact vs the sequential sweep, so this demo just runs it hot.
* **Delta by default** — ``make_sim`` resolves multi-device sims to the
  int8 delta-encoded aura exchange (paper §2.3).
* **Device-to-device re-shard** — a skewed two-cluster density triggers
  one mid-run rebalance onto an uneven RCB partition, migrated by the
  collective-permute fast path (``transport="device"``) with a deferred
  (async-snapshot) plan: zero bytes through the host, asserted by
  trapping ``flatten_state``.

    PYTHONPATH=src python examples/overlap_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np

import repro.core.reshard as reshard_mod
from repro.core import Rebalance
from repro.core.reshard import current_imbalance
from repro.sims import cell_clustering
from repro.sims.common import make_sim


def main():
    sim = make_sim(
        cell_clustering.behavior(adhesion=0.3),
        interior=(8, 8), mesh_shape=(2, 2), cap=64, dt=0.1,
        overlap="on",
        rebalance=Rebalance(every=6, threshold=0.3, ownership="rcb",
                            transport="device", defer=True))
    assert sim.engine.delta_cfg.enabled, "multi-device sims default to delta"
    print(f"aura exchange: int8 delta, refresh_interval="
          f"{sim.engine.delta_cfg.refresh_interval}; overlap=on")

    # two diagonal Gaussian clusters: half the devices own almost nothing
    rng = np.random.default_rng(0)
    n = 600
    centers = np.asarray([(8.0, 8.0), (24.0, 24.0)])
    pos = centers[rng.integers(0, 2, n)] + rng.normal(0, 3.0, (n, 2))
    pos = np.clip(pos, 0.5, 31.5).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    sim.init(pos, attrs, seed=0)
    print(f"static 2x2 split: imbalance = "
          f"{current_imbalance(sim.geom, sim.state):.2f}")

    # any call into the host-path flattener during the run is a regression
    calls = []
    orig = reshard_mod.flatten_state

    def trap(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    reshard_mod.flatten_state = trap
    try:
        sim.run(20)
    finally:
        reshard_mod.flatten_state = orig

    applied = [r for r in sim.rebalancer.history if r["applied"]]
    assert applied, sim.rebalancer.history
    for rec in applied:
        assert rec["transport"] == "device", rec
        assert rec.get("deferred"), rec
        print(f"it {rec['it']}: deferred device-to-device re-shard "
              f"{rec['mesh_from']} -> {rec['mesh_to']}  imbalance "
              f"{rec['imbalance_before']:.2f} -> "
              f"{rec['imbalance_after']:.2f}  "
              f"(migration {rec['migration_s']*1e3:.0f} ms)")
    assert not calls, "device re-shard must not touch flatten_state"
    assert sim.engine.geom.uneven, "rcb re-shard should land uneven"

    dropped = int(np.asarray(sim.state.dropped).sum())
    assert sim.n_agents() + dropped == n, (sim.n_agents(), dropped)
    print(f"final mesh {sim.engine.geom.mesh_shape} (uneven rcb), "
          f"imbalance = {current_imbalance(sim.geom, sim.state):.2f}, "
          f"agents {sim.n_agents()}/{n} (drops: {dropped}), "
          f"zero host bytes moved")


if __name__ == "__main__":
    main()
