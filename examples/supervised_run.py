"""Supervised run demo (docs/resilience.md): health guards + deterministic
fault injection + automatic checkpoint-rollback recovery.

A 4-device sharded run has two faults scripted into it: a NaN burst at
step 7 (caught by the fused NaN/Inf guard at the next host control point)
and, with ``--device-loss``, the loss of two devices at step 13 (recovered
by degrading onto the two survivors via elastic restore).  The supervisor
rolls back to the newest checksum-verified checkpoint each time and
replays; fire-once fault plans make the replay clean, so the run completes
— and the final state is bit-exact with an uninterrupted run resumed from
the same checkpoint (asserted below).

    PYTHONPATH=src python examples/supervised_run.py [--device-loss]
"""

import argparse
import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np

from repro.core import Simulation
from repro.distributed.chaos import Fault, FaultPlan
from repro.launch.supervise import Supervised, Supervisor
from repro.sims import cell_clustering
from repro.sims.common import make_sim


def state_key(state):
    """Live (positions, gids) in gid order — the bit-exactness currency."""
    v = np.asarray(state.soa.valid).ravel()
    nd = np.asarray(state.soa.attrs["pos"]).shape[-1]
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, nd)[v]
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    o = np.lexsort((gc, gr))
    return p[o], gr[o], gc[o]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device-loss", action="store_true",
                    help="also lose 2 of 4 devices mid-run and degrade")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    beh = cell_clustering.behavior(adhesion=0.3)
    sim = make_sim(beh, interior=(8, 8), mesh_shape=(2, 2), cap=48,
                   dt=0.1, guards="error")
    rng = np.random.default_rng(0)
    n = 400
    pos = rng.uniform(0.5, 31.5, size=(n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    sim.init(pos, attrs, seed=0)

    faults = [Fault(step=7, kind="nan_attrs", frac=0.1,
                    note="silent corruption burst")]
    if args.device_loss:
        faults.append(Fault(step=13, kind="device_loss", survivors=2,
                            note="half the mesh walks away"))
    plan = FaultPlan(tuple(faults), seed=42)

    with tempfile.TemporaryDirectory() as ck:
        sv = Supervisor(sim, Supervised(dir=ck, every=5, keep=9),
                        fault_plan=plan)
        sv.run(args.steps)

        for e in sv.log:
            extra = {k: v for k, v in e.items()
                     if k not in ("kind", "wall_time")}
            print(f"  [{e['kind']}] {extra}")

        recs = sv.events("recovered")
        assert recs, "the scripted faults should have forced a recovery"
        assert sim.iteration == args.steps, sim.iteration
        assert sv.events("completed"), "supervised run should complete"
        if args.device_loss:
            assert sim.engine.geom.n_devices == 2, \
                "device loss should degrade onto the 2 survivors"

        # bit-exactness: replay == uninterrupted resume from the same
        # checkpoint the (last) recovery rolled back to
        rb = recs[-1]["rolled_back_to"]
        ctl = Simulation.restore(
            ck, beh, step=rb, guards="error",
            n_devices=sim.engine.geom.n_devices)
        ctl.run(args.steps - rb)
        for a, b in zip(state_key(sim.state), state_key(ctl.state)):
            np.testing.assert_array_equal(a, b)

    print(f"recovered {len(recs)} fault(s); final it {sim.iteration}, "
          f"{sim.n_agents()}/{n} agents on "
          f"{sim.engine.geom.n_devices} device(s) — "
          f"bit-exact with uninterrupted resume from step {rb}")


if __name__ == "__main__":
    main()
