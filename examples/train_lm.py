"""End-to-end LM training driver: a ~25M-param OLMo-family model for a few
hundred steps on CPU with WSD schedule, async checkpointing, and resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get
from repro.data.pipeline import SyntheticLM
from repro.distributed import checkpoint as ck
from repro.models import params as P
from repro.models.model import build_model
from repro.training.optimizer import AdamW, WSDSchedule
from repro.training.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # ~25M params: olmo family, scaled between smoke and full
    cfg = dataclasses.replace(
        get("olmo-1b").smoke, n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=8, d_ff=1024, vocab=8192)
    model = build_model(cfg)
    opt = AdamW(schedule=WSDSchedule(
        peak_lr=3e-4, warmup_steps=20, stable_steps=args.steps - 60,
        decay_steps=40, final_frac=0.1))
    pipe = SyntheticLM(cfg, seq_len=args.seq, global_batch=args.batch)
    step_fn = jax.jit(make_train_step(model, opt, remat="none"))
    ckpt = ck.AsyncCheckpointer(args.ckpt_dir, keep=2)

    start = ck.latest_step(args.ckpt_dir)
    if start is not None:
        params = P.init(model.spec, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        start, restored, _ = ck.restore(
            args.ckpt_dir, like={"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from step {start}")
    else:
        start = 0
        params = P.init(model.spec, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        print(f"fresh start: {P.count_params(model.spec)/1e6:.1f}M params")

    t0 = time.time()
    for i in range(start, args.steps):
        params, opt_state, m = step_fn(params, opt_state,
                                       pipe.batch_for_step(i))
        if (i + 1) % 20 == 0:
            tps = args.batch * args.seq * (i + 1 - start) / (time.time() - t0)
            print(f"step {i+1:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  tok/s {tps:.0f}")
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt_state})
    ckpt.wait()
    print(f"done; final loss {float(m['loss']):.4f} "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
