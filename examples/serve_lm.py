"""Serving example: batched prefill + autoregressive decode with a KV cache
(greedy sampling), on the MLA architecture whose cache is the compressed
latent (minicpm3 family).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get
from repro.models import params as P
from repro.models.model import build_model
from repro.training.steps import make_serve_decode_step


def main():
    cfg = get("minicpm3-4b").smoke
    model = build_model(cfg)
    params = P.init(model.spec, jax.random.PRNGKey(0))

    batch, prompt_len, gen_len = 4, 24, 16
    max_len = prompt_len + gen_len
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab)

    cache = model.init_cache(batch, max_len)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(make_serve_decode_step(model))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
    out = [tok]
    for t in range(gen_len - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + t))
        tok = jnp.argmax(logits[:, 0, :cfg.vocab], axis=-1)[:, None]
        out.append(tok)
    gen = np.asarray(jnp.concatenate(out, axis=1))
    dt = time.time() - t0
    print(f"prefill {batch}x{prompt_len} + decode {gen_len} tokens "
          f"in {dt:.2f}s ({batch * gen_len / dt:.1f} tok/s)")
    for b in range(batch):
        print(f"  seq {b}: {gen[b].tolist()}")
    print("\nMLA cache stores the compressed KV latent "
          f"({cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim} dims/token vs "
          f"{2 * cfg.n_heads * 8} for full KV at this scale).")


if __name__ == "__main__":
    main()
