"""Parameter sweep + ABC calibration through the scenario server.

Two workloads the batching server turns from N sequential runs into a few
vmapped dispatches (docs/serving.md):

1. **Sweep** — a grid over the infection rate ``beta`` of the
   ``sir_mechanics`` family, every point streamed as S/I/R frames from
   shared ensemble batches.
2. **Calibration** — approximate Bayesian computation (ABC rejection with
   a shrinking tolerance): a hidden "true" beta produces an observed
   attack rate; each round submits a batch of candidate betas, keeps the
   candidates whose simulated attack rate lands within tolerance, and
   resamples around the survivors.  The accepted cloud is the ABC
   posterior; its mean is the fitted beta.

    PYTHONPATH=src python examples/param_sweep.py

Everything runs in-process: the server, its compiled-runner cache, and
the compile-cache telemetry printed at the end are the same machinery the
CI serve smoke exercises.
"""

import numpy as np

from repro.launch.serve import (
    ScenarioRequest,
    ScenarioServer,
    sir_mechanics_family,
)

N_AGENTS = 200
STEPS = 20
SLOT = 8


def attack_rate(handle) -> float:
    """Final fraction of agents ever infected (I + R at the horizon)."""
    _, final = handle.frames[-1]
    return float(final[1] + final[2]) / float(final.sum())


def run_batch(server, betas, seed0=0, stream_every=0):
    rids = [server.submit(ScenarioRequest(
                family="sir_mechanics", params={"beta": float(b)},
                steps=STEPS, stream_every=stream_every, seed=seed0 + i))
            for i, b in enumerate(betas)]
    server.drain()
    return [server.handle(r) for r in rids]


def main():
    server = ScenarioServer([sir_mechanics_family(n_agents=N_AGENTS)],
                            slot_size=SLOT)

    # -- 1. sweep ------------------------------------------------------
    grid = np.linspace(0.01, 0.15, 8)
    print(f"sweep: {len(grid)} beta points, {STEPS} steps each")
    for h in run_batch(server, grid, stream_every=10):
        curve = " ".join(f"t={s}:I={int(f[1])}" for s, f in h.frames)
        print(f"  beta={h.request.params['beta']:.3f}  {curve}  "
              f"attack={attack_rate(h):.2f}")

    # -- 2. ABC calibration -------------------------------------------
    # A target on the steep part of the response curve (the sweep above
    # shows attack rate saturating past beta ~0.07, where no finite data
    # could identify beta).
    rng = np.random.default_rng(7)
    true_beta = 0.04
    [obs_handle] = run_batch(server, [true_beta], seed0=100)
    target = attack_rate(obs_handle)
    print(f"\ncalibration target: attack rate {target:.2f} "
          f"(hidden beta={true_beta})")

    lo, hi = 0.005, 0.2
    candidates = rng.uniform(lo, hi, SLOT)
    accepted = []
    for rnd, tol in enumerate((0.15, 0.08, 0.04)):
        handles = run_batch(server, candidates, seed0=200 + rnd * SLOT)
        scored = [(abs(attack_rate(h) - target),
                   h.request.params["beta"]) for h in handles]
        hits = [b for d, b in scored if d <= tol]
        accepted = hits or [min(scored)[1]]
        # resample around the surviving cloud (ABC-SMC style jitter)
        width = max((hi - lo) * 0.5 ** (rnd + 1), 0.01)
        candidates = np.clip(
            rng.choice(accepted, SLOT) + rng.normal(0, width / 4, SLOT),
            lo, hi)
        print(f"  round {rnd}: tol={tol:.2f} accepted "
              f"{len(hits)}/{len(handles)} -> "
              f"beta in [{min(accepted):.3f}, {max(accepted):.3f}]")

    fit = float(np.mean(accepted))
    print(f"fitted beta = {fit:.3f} (true {true_beta})")

    st = server.stats()
    rc = st["caches"]["ensemble.runner"]
    print(f"\nserver: {st['batches']} batches, mean occupancy "
          f"{st['mean_occupancy']:.2f}, runner cache {rc['hits']}h/"
          f"{rc['misses']}m — every batch after the first reused the "
          "compiled ensemble runner")


if __name__ == "__main__":
    main()
