"""Dynamic load balancing demo (paper §2.4.5): a Gaussian-clustered cell
population starts on a pathological static 2x2 partition; the Rebalancer
detects the imbalance mid-run, plans over the occupancy histogram, and pays
one mass migration to a better mesh — then keeps simulating, identical
model code.

    PYTHONPATH=src python examples/rebalance_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np

from repro.core import Rebalancer, total_agents
from repro.core.reshard import current_imbalance
from repro.launch.mesh import make_abm_mesh
from repro.sims import cell_clustering
from repro.sims.common import make_engine


def main():
    # adhesion kept gentle and cap generous so the condensing clusters never
    # overflow a cell's slot capacity over the demo horizon
    eng = make_engine(cell_clustering.behavior(adhesion=0.3), interior=(8, 8),
                      mesh_shape=(2, 2), cap=64,
                      rebalance_every=5, imbalance_threshold=0.3)

    # Two diagonal Gaussian clusters: half the devices own almost nothing.
    rng = np.random.default_rng(0)
    n = 600
    centers = np.asarray([(8.0, 8.0), (24.0, 24.0)])
    pos = centers[rng.integers(0, 2, n)] + rng.normal(0, 3.0, (n, 2))
    pos = np.clip(pos, 0.5, 31.5).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    state = eng.init_state(pos, attrs, seed=0)

    print(f"static 2x2 split: imbalance = "
          f"{current_imbalance(eng.geom, state):.2f}  (0 = perfect)")

    rb = Rebalancer(every=eng.rebalance_every,
                    threshold=eng.imbalance_threshold)
    step = eng.make_sharded_step(make_abm_mesh((2, 2)))
    eng, state, _ = eng.drive(state, 20, step_fn=step, rebalancer=rb)

    for rec in rb.history:
        if rec["applied"]:
            print(f"it {rec['it']}: re-shard {rec['mesh_from']} -> "
                  f"{rec['mesh_to']}  imbalance "
                  f"{rec['imbalance_before']:.2f} -> "
                  f"{rec['imbalance_after']:.2f}  "
                  f"(RCB bound {rec['rcb_bound']:.2f}, "
                  f"migration {rec['migration_s']*1e3:.0f} ms)")

    print(f"final mesh {eng.geom.mesh_shape}, imbalance = "
          f"{current_imbalance(eng.geom, state):.2f}, "
          f"agents {total_agents(state)}/{n} "
          f"(capacity drops: {int(np.asarray(state.dropped).sum())})")


if __name__ == "__main__":
    main()
