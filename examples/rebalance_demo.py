"""Dynamic load balancing demo (paper §2.4.5): a Gaussian-clustered cell
population starts on a pathological static 2x2 partition; the facade's
scheduled rebalance operation detects the imbalance mid-run (weighted by
measured per-device step timing), pays one mass migration to a better mesh,
and keeps simulating — ``sim.engine``/``sim.state`` stay consistent the
whole way, with no stale engine handle to juggle.

With ``--ownership rcb`` the re-shard realizes a box-granular *uneven*
rectilinear partition (padded per-device grids + masked halo exchange)
instead of an equal-split mesh — on this diagonal-cluster density it
closes the remaining gap to the planner's box-granular bound.

    PYTHONPATH=src python examples/rebalance_demo.py [--ownership rcb]
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np

from repro.core import Rebalance, Simulation
from repro.core.reshard import current_imbalance
from repro.sims import cell_clustering


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ownership", default="equal",
                    choices=["equal", "rcb"],
                    help="what the re-shard may realize: equal-split "
                         "meshes or uneven RCB partitions")
    args = ap.parse_args()

    # adhesion kept gentle and cap generous so the condensing clusters never
    # overflow a cell's slot capacity over the demo horizon
    sim = Simulation(
        dict(interior=(8, 8), mesh_shape=(2, 2), cap=64),
        cell_clustering.behavior(adhesion=0.3), dt=0.1,
        rebalance=Rebalance(every=5, threshold=0.3, weighted=True,
                            ownership=args.ownership))

    # Two diagonal Gaussian clusters: half the devices own almost nothing.
    rng = np.random.default_rng(0)
    n = 600
    centers = np.asarray([(8.0, 8.0), (24.0, 24.0)])
    pos = centers[rng.integers(0, 2, n)] + rng.normal(0, 3.0, (n, 2))
    pos = np.clip(pos, 0.5, 31.5).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    sim.init(pos, attrs, seed=0)

    print(f"static 2x2 split: imbalance = "
          f"{current_imbalance(sim.geom, sim.state):.2f}  (0 = perfect)")

    sim.run(20)

    for rec in sim.rebalancer.history:
        if rec["applied"]:
            print(f"it {rec['it']}: re-shard {rec['mesh_from']} -> "
                  f"{rec['mesh_to']}  imbalance "
                  f"{rec['imbalance_before']:.2f} -> "
                  f"{rec['imbalance_after']:.2f}  "
                  f"(RCB bound {rec['rcb_bound']:.2f}, "
                  f"migration {rec['migration_s']*1e3:.0f} ms)")
            if rec.get("partition_widths") is not None and rec["applied"] \
                    and sim.engine.geom.uneven:
                print(f"  uneven slab widths (cells): "
                      f"{rec['partition_widths']}  padded-grid overhead "
                      f"{rec['pad_fraction']*100:.0f}%")

    print(f"final mesh {sim.engine.geom.mesh_shape} "
          f"({'uneven rcb' if sim.engine.geom.uneven else 'equal'} "
          f"ownership), imbalance = "
          f"{current_imbalance(sim.geom, sim.state):.2f}, "
          f"agents {sim.n_agents()}/{n} "
          f"(capacity drops: {int(np.asarray(sim.state.dropped).sum())})")
    if args.ownership == "rcb":
        assert sim.engine.geom.uneven, "rcb run should land uneven"


if __name__ == "__main__":
    main()
