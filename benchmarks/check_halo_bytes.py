"""CI guard: compressed aura wire bytes must not regress.

Recomputes the steady-state int8-delta halo payload per iteration for
each bundled sim and compares it against the checked-in
``halo_bytes_per_iter_*`` rows in ``BENCH_results.json``.  Unlike the
timing rows, these are *static* properties of the slab spec (payload
shapes per directed edge), so any increase is a real payload regression
— a widened slab, a field that stopped compressing, a codec fallback —
not machine noise.  Exits non-zero on regression or missing rows.

    PYTHONPATH=src python benchmarks/check_halo_bytes.py
"""

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import DeltaConfig
from repro.sims import (cell_clustering, cell_proliferation, epidemiology,
                        oncology)

SIMS = (
    ("cell_clustering", cell_clustering, dict(n_agents=300)),
    ("cell_proliferation", cell_proliferation, dict(n_agents=50)),
    ("epidemiology", epidemiology, dict(n_agents=400)),
    ("oncology", oncology, dict(n_agents=30)),
)


def main() -> int:
    rows = {r["name"]: r for r in
            json.loads((ROOT / "BENCH_results.json").read_text())}
    cfg = DeltaConfig(enabled=True, qdtype=jnp.int8, refresh_interval=16)
    fail = False
    for name, mod, kw in SIMS:
        state, _ = mod.run(steps=8, delta=cfg, **kw)
        comp = int(np.asarray(state.halo_bytes).sum())
        row = rows.get(f"halo_bytes_per_iter_{name}")
        if row is None:
            print(f"MISSING   halo_bytes_per_iter_{name} "
                  "(run benchmarks/run.py --only comm_budget)")
            fail = True
            continue
        pinned = float(row["us_per_call"])
        ok = comp <= pinned
        print(f"{'OK       ' if ok else 'REGRESSED'} {name}: "
              f"compressed {comp}B/iter vs pinned {pinned:.0f}B/iter")
        fail |= not ok
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
