"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  serialization_*   — paper Fig. 10: TeraAgent IO (zero-copy SoA slab) vs a
                      generic pack/unpack serializer baseline
  delta_*           — paper Fig. 11: delta encoding message-size reduction +
                      distribution-op overhead per benchmark simulation
  sweep_*           — interaction-sweep micro-bench: the three backends
                      (reference | tiled | pallas) on one workload, pair
                      evaluations/s and speedup vs the reference gather
                      (docs/performance.md explains how to read these);
                      sweep_3d_* repeats it on a 3-D Domain (27-offset
                      stencil, incl. the pallas row — the kernel factory
                      takes 3-D blocks)
  halo_bytes_3d     — 3-D aura-exchange wire bytes/iter (6 directed edges),
                      full f32 vs int16 delta
  halo_bytes_per_iter_* / overlap_efficiency / reshard_downtime_steps
                    — communication budget (ROADMAP item 1,
                      docs/performance.md): per-sim steady-state aura wire
                      bytes int8-compressed (R=16) vs raw, % of exchange
                      wall time hidden behind the interior pass, re-shard
                      downtime in steps host-path vs device-to-device
  sim_*             — paper Fig. 6 analogue: per-simulation iteration rate
                      (agent_updates/s, the Biocellion comparison metric
                      §3.8); sim_tumor_spheroid_3d tracks the 3-D flagship
  scaling_*         — paper Fig. 8/9 analogue: strong scaling over placeholder
                      spatial meshes at FIXED global problem size
                      (subprocess: needs >1 XLA host device); derived reports
                      agent_updates/s, parallel efficiency vs 1 device, and
                      halo bytes/iter
  rebalance_uneven_* — §2.4.5 uneven ownership: per clustered workload the
                      imbalance before / after-equal / after-rcb (the
                      realized box-granular partition) vs the rcb_bound,
                      plus the padded-grid memory overhead
  roofline_*        — LM stack: dry-run-derived roofline summary per chosen
                      cell (reads results/dryrun; skips if absent)

CPU wall-clock here characterizes the harness, not TPU performance; the TPU
performance analysis lives in EXPERIMENTS.md §Roofline/§Perf.

``--only PREFIX[,PREFIX...]`` runs a subset (e.g. ``--only sweep`` for the
CI sweep smoke step).
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

ROWS = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def merge_rows(path, rows):
    """Merge this run's rows into the checked-in results keyed by row
    name: a partial run (``--only``) updates its rows and leaves the rest
    of the perf trajectory in place instead of truncating the file."""
    merged = {}
    if path.exists():
        try:
            for row in json.loads(path.read_text()):
                merged[row["name"]] = row
        except (ValueError, KeyError, TypeError):
            pass  # unreadable history: rebuild from this run
    for n, us, d in rows:
        merged[n] = {"name": n, "us_per_call": us, "derived": d}
    return list(merged.values())


def timeit(fn, n=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------
# Fig 10 analogue: serialization
# ---------------------------------------------------------------------------

def bench_serialization():
    """TeraAgent IO == the SoA slab itself (serialization is the identity);
    baseline == generic per-leaf pack/unpack into a byte buffer (the
    ROOT-IO-style copy pipeline)."""
    from repro.core import AgentSchema
    from repro.core.agent_soa import AgentSoA
    from repro.core.halo import take_slab

    schema = AgentSchema.create({
        "diameter": ((), jnp.float32), "ctype": ((), jnp.int32)})
    soa = AgentSoA.empty(schema, (66, 66), 16)
    soa = soa.replace(valid=soa.valid.at[:, :, :8].set(True))

    def ta_io():
        # zero-copy: the exchange slab IS the wire format
        slab = take_slab(soa, 0, 1)
        return jax.block_until_ready(slab["pos"])

    def generic_pack_unpack():
        slab = take_slab(soa, 0, 1)
        bufs = [np.asarray(v).tobytes() for v in slab.values()]  # pack
        wire = b"".join(bufs)
        out = []
        off = 0                                                   # unpack
        for k, v in slab.items():
            n = np.asarray(v).nbytes
            arr = np.frombuffer(wire[off:off + n],
                                dtype=np.asarray(v).dtype.str)
            out.append(jnp.asarray(arr.reshape(np.asarray(v).shape)))
            off += n
        return jax.block_until_ready(out[0])

    t_ta = timeit(ta_io, n=20)
    t_gen = timeit(generic_pack_unpack, n=20)
    emit("serialization_ta_io", t_ta, f"speedup_vs_generic={t_gen/t_ta:.1f}x")
    emit("serialization_generic", t_gen, "baseline")


# ---------------------------------------------------------------------------
# Fig 11 analogue: delta encoding
# ---------------------------------------------------------------------------

def bench_delta():
    from repro.core import DeltaConfig
    from repro.sims import cell_clustering

    for qd, label in ((jnp.int8, "int8"), (jnp.int16, "int16")):
        delta = DeltaConfig(enabled=True, qdtype=qd, refresh_interval=16)
        # plain
        t0 = time.perf_counter()
        s_plain, _ = cell_clustering.run(n_agents=300, steps=8)
        t_plain = time.perf_counter() - t0
        b_plain = int(s_plain.halo_bytes[0, 0])
        t0 = time.perf_counter()
        s_delta, _ = cell_clustering.run(n_agents=300, steps=8, delta=delta)
        t_delta = time.perf_counter() - t0
        b_delta = int(s_delta.halo_bytes[0, 0])
        emit(f"delta_{label}_msg_bytes", t_delta / 8 * 1e6,
             f"reduction={b_plain/max(b_delta,1):.2f}x "
             f"({b_plain}->{b_delta}B/iter)")
    # steady-state analytic reduction for float-only payloads
    r = 16
    emit("delta_int8_float_payload", 0.0,
         f"steady_state_reduction={4*r/(4+(r-1)*1):.2f}x_at_R={r}")


# ---------------------------------------------------------------------------
# Interaction-sweep micro-bench: the hot kernel, isolated per backend
# ---------------------------------------------------------------------------

def bench_sweep():
    """Time one jitted neighborhood sweep per backend on a shared workload.

    ``pairs/s`` counts candidate pair evaluations (interior agents x 9K
    neighborhood slots) — the sweep's actual arithmetic work.  The Pallas
    row runs in interpret mode on CPU (that row measures the interpreter,
    not Mosaic; it exists to keep the TPU path's parity + plumbing hot).
    """
    from repro.core import Engine, Domain
    from repro.core.neighbors import sweep_accumulate
    from repro.sims import cell_clustering

    beh = cell_clustering.behavior()
    geom = Domain(cell_size=2.0, interior=(16, 16), mesh_shape=(1, 1),
                    cap=24)
    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    rng = np.random.default_rng(0)
    n = 2000
    lx, ly = geom.domain_size
    pos = rng.uniform(0.5, lx - 0.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    state = eng.init_state(pos, attrs, seed=0)
    ix, iy = geom.interior
    # the sweep's masked arithmetic runs over every interior agent SLOT
    # (valid or not) x its 9K neighborhood candidates
    pairs = ix * iy * geom.cap * 9 * geom.cap

    times = {}
    for backend in ("reference", "tiled", "pallas"):
        fn = jax.jit(lambda soa, b=backend: sweep_accumulate(
            geom, soa, beh.pair_fn, beh.pair_attrs, beh.radius, beh.params,
            backend=b))
        out = fn(state.soa)                      # compile
        jax.block_until_ready(out)
        reps = 2 if backend == "pallas" else 10
        t = timeit(lambda: jax.block_until_ready(fn(state.soa)),
                   n=reps, warmup=1)
        times[backend] = t
        extra = "_interpret" if backend == "pallas" else ""
        emit(f"sweep_{backend}", t,
             f"pairs_per_s={pairs / (t / 1e6):.3g}"
             f"_speedup_vs_reference={times['reference'] / t:.2f}x{extra}")


# ---------------------------------------------------------------------------
# 3-D sweep micro-bench: the same hot kernel on the new spatial axis
# ---------------------------------------------------------------------------

def bench_sweep_3d():
    """reference | tiled | pallas on a 3-D Domain (27-offset stencil).
    The kernel factory takes 3-D blocks since the uneven-ownership PR;
    as in :func:`bench_sweep`, the pallas row runs the interpreter on CPU
    (it tracks parity/plumbing, not Mosaic performance)."""
    from repro.core import Domain, Engine
    from repro.core.neighbors import sweep_accumulate
    from repro.sims import cell_clustering

    beh = cell_clustering.behavior()
    geom = Domain(cell_size=2.0, interior=(8, 8, 8), mesh_shape=(1, 1, 1),
                  cap=16)
    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    rng = np.random.default_rng(0)
    n = 2000
    size = geom.domain_size
    pos = rng.uniform([0.5] * 3, [s - 0.5 for s in size],
                      (n, 3)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    state = eng.init_state(pos, attrs, seed=0)
    cells = geom.interior[0] * geom.interior[1] * geom.interior[2]
    pairs = cells * geom.cap * 27 * geom.cap

    times = {}
    for backend in ("reference", "tiled", "pallas"):
        fn = jax.jit(lambda soa, b=backend: sweep_accumulate(
            geom, soa, beh.pair_fn, beh.pair_attrs, beh.radius, beh.params,
            backend=b))
        jax.block_until_ready(fn(state.soa))     # compile
        reps = 2 if backend == "pallas" else 5
        t = timeit(lambda: jax.block_until_ready(fn(state.soa)),
                   n=reps, warmup=1)
        times[backend] = t
        extra = "_interpret" if backend == "pallas" else ""
        emit(f"sweep_3d_{backend}", t,
             f"pairs_per_s={pairs / (t / 1e6):.3g}"
             f"_speedup_vs_reference={times['reference'] / t:.2f}x{extra}")


# ---------------------------------------------------------------------------
# 3-D aura-exchange wire bytes: 6 directed edges, full vs delta
# ---------------------------------------------------------------------------

def bench_halo_bytes_3d():
    """Wire bytes per iteration of the 3-D aura exchange (2*ndim = 6
    directed face slabs), full f32 vs int16 quantized-delta — the 3-D
    continuation of the ``delta_*`` rows."""
    from repro.core import DeltaConfig
    from repro.sims import tumor_spheroid

    _ = tumor_spheroid.run(n_agents=40, steps=2)   # warm compile
    t0 = time.perf_counter()
    s_plain, _ = tumor_spheroid.run(n_agents=40, steps=4)
    t_plain = time.perf_counter() - t0
    b_plain = int(s_plain.halo_bytes.ravel()[0])
    delta = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=16)
    s_delta, _ = tumor_spheroid.run(n_agents=40, steps=4, delta=delta)
    b_delta = int(s_delta.halo_bytes.ravel()[0])
    emit("halo_bytes_3d", t_plain / 4 * 1e6,
         f"reduction={b_plain/max(b_delta,1):.2f}x "
         f"({b_plain}->{b_delta}B/iter_6_edges)")


# ---------------------------------------------------------------------------
# Fig 6 / §3.8 analogue: per-sim iteration rate
# ---------------------------------------------------------------------------

def bench_sims():
    from repro.sims import (cell_clustering, cell_proliferation,
                            epidemiology, oncology)

    for name, mod, kw in (
        ("cell_clustering", cell_clustering, dict(n_agents=400, steps=4)),
        ("cell_proliferation", cell_proliferation,
         dict(n_agents=60, steps=4)),
        ("epidemiology", epidemiology, dict(n_agents=500, steps=4)),
        ("oncology", oncology, dict(n_agents=30, steps=4)),
    ):
        _ = mod.run(**{**kw, "steps": 2})  # warm compile
        t0 = time.perf_counter()
        state, _ = mod.run(**kw)
        dt_iter = (time.perf_counter() - t0) / kw["steps"]
        from repro.core.engine import total_agents

        n = total_agents(state)
        emit(f"sim_{name}", dt_iter * 1e6,
             f"agent_updates_per_s={n/dt_iter:.0f}")


def bench_sim_tumor_spheroid():
    """3-D flagship workload (sims/tumor_spheroid): iteration rate of the
    composed mechanics + nutrient-gated-growth stack on a 3-D Domain."""
    from repro.core.engine import total_agents
    from repro.sims import tumor_spheroid

    kw = dict(n_agents=40, steps=4)
    _ = tumor_spheroid.run(**{**kw, "steps": 2})   # warm compile
    t0 = time.perf_counter()
    state, _ = tumor_spheroid.run(**kw)
    dt_iter = (time.perf_counter() - t0) / kw["steps"]
    n = total_agents(state)
    emit("sim_tumor_spheroid_3d", dt_iter * 1e6,
         f"agent_updates_per_s={n/dt_iter:.0f}_ndim=3")


# ---------------------------------------------------------------------------
# Fig 8/9 analogue: strong scaling over spatial meshes (subprocess)
# ---------------------------------------------------------------------------

def bench_scaling():
    """Strong scaling at FIXED global problem size (800 agents on a fixed
    16x16 global cell grid): the step loop itself is timed (init and metric
    setup excluded), normalized to agent_updates/s, with parallel
    efficiency vs the 1-device run and the aura-exchange wire bytes per
    iteration — the quantities a mesh-shape comparison is actually about."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time, numpy as np, jax
from repro.sims import cell_clustering

n, steps = 800, 12
base_rate = None
for mesh_shape in ((1, 1), (2, 1), (2, 2)):
    n_dev = mesh_shape[0] * mesh_shape[1]
    from repro.launch.mesh import make_abm_mesh
    mesh = make_abm_mesh(mesh_shape) if n_dev > 1 else None
    interior = (16 // mesh_shape[0], 16 // mesh_shape[1])
    sim = cell_clustering.simulation(n_agents=n, interior=interior,
                                     mesh_shape=mesh_shape, mesh=mesh)
    sim.run(2)                                    # warm compile
    jax.block_until_ready(sim.state.soa.valid)
    t0 = time.perf_counter()
    sim.run(steps)
    jax.block_until_ready(sim.state.soa.valid)
    dt = (time.perf_counter() - t0) / steps
    rate = n / dt
    base_rate = base_rate or rate
    eff = rate / (base_rate * n_dev)
    hb = int(np.asarray(sim.state.halo_bytes).sum())
    print(f"scaling_devices_{n_dev},{dt*1e6:.1f},"
          f"agent_updates_per_s={rate:.0f}_efficiency={eff:.2f}"
          f"_halo_bytes_iter={hb}")
"""
    run_sub_bench(code, "scaling_")


def run_sub_bench(code: str, prefix: str) -> None:
    """Run a benchmark snippet in a subprocess (placeholder devices need a
    fresh XLA) and collect its ``prefix``-named CSV rows."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=1800, env=env)
    if p.returncode != 0:
        emit(prefix + "error", 0.0, p.stderr.strip()[-120:])
        return
    for line in p.stdout.strip().splitlines():
        if line.startswith(prefix):
            print(line)
            name, us, derived = line.split(",", 2)
            ROWS.append((name, float(us), derived))


# ---------------------------------------------------------------------------
# §2.4.5 analogue: dynamic load balancing (re-shard runtime)
# ---------------------------------------------------------------------------

def bench_rebalance():
    """Gaussian-clustered density on a 2x2 mesh: report imbalance() and
    iteration rate before/after the Rebalancer's one-time mass migration
    (subprocess: needs 4 XLA host devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time, numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, Engine, Domain, Rebalancer, total_agents
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.core.reshard import current_imbalance
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.6,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 600
c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}

geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=48)
eng = Engine(geom=geom, behavior=beh, dt=0.1)
state = eng.init_state(pos, attrs, seed=0)
imb0 = current_imbalance(eng.geom, state)

def rate(engine, st, steps=6):
    step = engine.make_sharded_step(make_abm_mesh(engine.geom.mesh_shape))
    st = step(st, full_halo=True)  # warm compile
    t0 = time.perf_counter()
    for _ in range(steps):
        st = step(st, full_halo=True)
    jax.block_until_ready(st.soa.valid)
    dt = (time.perf_counter() - t0) / steps
    return dt, st

dt0, _ = rate(eng, state)
rb = Rebalancer(every=1, threshold=0.2)
t0 = time.perf_counter()
eng2, state2, did = rb.maybe_reshard(eng, state)
t_mig = time.perf_counter() - t0
assert did, rb.history
imb1 = current_imbalance(eng2.geom, state2)
assert total_agents(state2) == n
dt1, _ = rate(eng2, state2)
rec = rb.history[-1]
print(f"rebalance_imbalance,{t_mig*1e6:.1f},"
      f"imb={imb0:.2f}->{imb1:.2f}_mesh={rec['mesh_from']}->{rec['mesh_to']}"
      f"_rcb_bound={rec['rcb_bound']:.2f}".replace(" ", ""))
print(f"rebalance_iter_rate,{dt1*1e6:.1f},"
      f"agent_updates_per_s={n/dt1:.0f}_vs_{n/dt0:.0f}_static")
"""
    run_sub_bench(code, "rebalance_")


def bench_rebalance_uneven():
    """Uneven ownership on the clustered workloads: per workload the
    imbalance before / after the equal-split plan / after the realized
    box-granular RCB partition, plus the reported ``rcb_bound`` — the rows
    that show the former plan-vs-realizable gap is closed (subprocess:
    needs 4 XLA host devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time, numpy as np, jax
from repro.core import total_agents
from repro.core.reshard import (current_imbalance, occupancy_histogram,
                                plan_reshard, reshard_state)

def report(name, eng, state, n):
    hist = occupancy_histogram(eng.geom, state)
    imb0 = current_imbalance(eng.geom, state)
    plan = plan_reshard(hist, eng.geom)
    eng_eq, st_eq = reshard_state(eng, state, plan.mesh_shape)
    imb_eq = current_imbalance(eng_eq.geom, st_eq)
    assert total_agents(st_eq) == n
    t0 = time.perf_counter()
    eng_un, st_un = reshard_state(eng, state, partition=plan.partition)
    t_mig = time.perf_counter() - t0
    imb_un = current_imbalance(eng_un.geom, st_un)
    assert total_agents(st_un) == n
    rcb = plan.rcb_bound
    within = imb_un <= rcb * 1.1 + 1e-9
    print(f"rebalance_uneven_{name},{t_mig*1e6:.1f},"
          f"imb={imb0:.2f}_after_equal={imb_eq:.2f}_after_rcb={imb_un:.2f}"
          f"_rcb_bound={rcb:.2f}_within_10pct={within}"
          f"_mesh={eng_un.geom.mesh_shape}"
          f"_pad={eng_un.geom.partition.pad_fraction() if eng_un.geom.uneven else 0.0:.2f}"
          .replace(" ", ""))

# (a) cell_clustering: diagonal two-cluster Gaussian density on a 2x2 mesh
from repro.sims import cell_clustering
from repro.sims.common import init_agents, make_sim
rng = np.random.default_rng(0)
n = 600
c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}
sim = make_sim(cell_clustering.behavior(adhesion=0.3),
               interior=(8, 8), mesh_shape=(2, 2), cap=64)
init_agents(sim, pos, attrs, seed=0)
sim.run(2)
report("cell_clustering", sim.engine, sim.state, n)

# (b) tumor_spheroid: off-center 3-D ball on a 2x2x1 mesh
from repro.sims import tumor_spheroid
sim3 = tumor_spheroid.simulation(
    n_agents=60, mesh_shape=(2, 2, 1), interior=(6, 6, 12), cap=64,
    center_frac=(0.3, 0.3, 0.3))
sim3.run(2)
report("tumor_spheroid", sim3.engine, sim3.state, sim3.n_agents())
"""
    run_sub_bench(code, "rebalance_uneven_")


# ---------------------------------------------------------------------------
# ROADMAP item 1: the communication budget (docs/performance.md)
# ---------------------------------------------------------------------------

def bench_comm_budget():
    """Communication-budget rows: per-sim steady-state aura wire bytes
    compressed (int8 delta, R=16) vs raw f32, the fraction of exchange
    wall time the overlapped interior pass hides, and re-shard downtime
    in steps for the host path vs the device-to-device collective."""
    from repro.core import DeltaConfig
    from repro.sims import (cell_clustering, cell_proliferation,
                            epidemiology, oncology)

    cfg = DeltaConfig(enabled=True, qdtype=jnp.int8, refresh_interval=16)
    for name, mod, kw in (
        ("cell_clustering", cell_clustering, dict(n_agents=300)),
        ("cell_proliferation", cell_proliferation, dict(n_agents=50)),
        ("epidemiology", epidemiology, dict(n_agents=400)),
        ("oncology", oncology, dict(n_agents=30)),
    ):
        sp, _ = mod.run(steps=8, **kw)
        raw = int(np.asarray(sp.halo_bytes).sum())
        sd, _ = mod.run(steps=8, delta=cfg, **kw)
        comp = int(np.asarray(sd.halo_bytes).sum())
        # Static per-slot byte split from the slab spec: int attrs and
        # the valid mask ride the codec unchanged, float attrs quantize
        # 4B -> 1B (+ one 4B scale per field per slab), so the whole-slab
        # reduction is diluted by the integer payload while the float
        # payload itself hits the codec's steady-state 4R/(4+(R-1)q).
        nd = int(np.asarray(sd.soa.attrs["pos"]).shape[-1])
        fB = iB = 0
        for _n, v in sd.soa.attrs.items():
            per = int(np.dtype(np.asarray(v).dtype).itemsize) * int(
                np.prod(np.asarray(v).shape[nd + 1:], dtype=int))
            if np.issubdtype(np.asarray(v).dtype, np.floating):
                fB += per
            else:
                iB += per
        tot = fB + iB + 1                      # + 1B valid mask
        raw_f = raw * fB / tot
        comp_f = comp - raw * (iB + 1) / tot   # ints pass through as-is
        amort = (raw + 15 * comp) / 16
        emit(f"halo_bytes_per_iter_{name}", float(comp),
             f"compressed={comp}B_raw={raw}B"
             f"_slab_reduction={raw / max(comp, 1):.2f}x"
             f"_float_payload_reduction={raw_f / max(comp_f, 1e-9):.2f}x"
             f"_amortized={raw / max(amort, 1e-9):.2f}x_at_R=16")

    # --- overlap efficiency (subprocess: 2x2 placeholder mesh) ---------
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, time, numpy as np, jax, jax.numpy as jnp
from repro.core import DeltaConfig, Domain, Engine
from repro.core.domain import spatial_axis_names
from repro.core.engine import _shard_comm, shard_map_compat
from repro.core.grid import clear_ring
from repro.core.halo import halo_exchange
from repro.core.neighbors import sweep_accumulate
from repro.launch.mesh import make_abm_mesh
from repro.sims import cell_clustering

beh = cell_clustering.behavior()
geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=24)
cfg = DeltaConfig(enabled=True, qdtype=jnp.int8, refresh_interval=16)
eng = Engine(geom=geom, behavior=beh, delta_cfg=cfg, dt=0.1)
rng = np.random.default_rng(0)
n = 600
pos = rng.uniform(0.5, 31.5, (n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}
state = eng.init_state(pos, attrs, seed=0)
mesh = make_abm_mesh((2, 2))
axes = tuple(spatial_axis_names(2))
comm, spec = _shard_comm(eng, axes)

# a few real steps so the timed delta exchange runs against warm refs
step = eng.make_sharded_step(mesh)
state = step(state, full_halo=True)
for _ in range(3):
    state = step(state, full_halo=False)
jax.block_until_ready(state.soa.valid)

idx0 = (0, 0)

def exch_body(state):
    # the wire leg of local_step in isolation: ring invalidation, codec
    # encode, ppermute per directed edge, codec decode, ring fill
    refs = {d: {f: v[idx0] for f, v in slab.items()}
            for d, slab in state.refs.items()}
    soa_pre = clear_ring(state.soa)
    soa2, _refs2, nb, _of = halo_exchange(
        geom, soa_pre, comm, refs, cfg, False, None)
    return soa2.valid, jnp.reshape(nb, (1, 1))

def interior_body(state):
    # the interior pass in isolation: the monolithic sweep on the
    # ring-invalidated SoA (exactly what overlaps the exchange)
    soa_pre = clear_ring(state.soa)
    acc = sweep_accumulate(geom, soa_pre, beh.pair_fn, beh.pair_attrs,
                           beh.radius, beh.params, backend="tiled")
    return acc

f_exch = jax.jit(shard_map_compat(
    exch_body, mesh=mesh, in_specs=spec, out_specs=(spec, spec)))
f_int = jax.jit(shard_map_compat(
    interior_body, mesh=mesh, in_specs=spec, out_specs=spec))

def timeit(fn, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(state))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(state))
    return (time.perf_counter() - t0) / n * 1e6

t_exch = timeit(f_exch)
t_int = timeit(f_int)
hidden = min(t_int, t_exch) / t_exch * 100.0

def step_rate(overlap):
    e = dataclasses.replace(eng, overlap=overlap)
    st = e.make_sharded_step(mesh)(state, full_halo=True)
    f = lambda: jax.block_until_ready(
        e.make_sharded_step(mesh)(state, full_halo=False).soa.valid)
    for _ in range(2):
        f()
    t0 = time.perf_counter()
    for _ in range(6):
        f()
    return (time.perf_counter() - t0) / 6 * 1e6

t_on, t_off = step_rate("on"), step_rate("off")
print(f"overlap_efficiency,{t_exch:.1f},"
      f"hidden={hidden:.0f}%_t_exchange={t_exch:.0f}us_t_interior={t_int:.0f}us"
      f"_step_overlap_on={t_on:.0f}us_off={t_off:.0f}us")
"""
    run_sub_bench(code, "overlap_")

    # --- re-shard downtime: host vs device transport (subprocess) ------
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time, numpy as np, jax, jax.numpy as jnp
from repro.core import (AgentSchema, Behavior, Domain, Engine, Rebalancer,
                        total_agents)
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.6,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 600
c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}
geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=48)
eng = Engine(geom=geom, behavior=beh, dt=0.1)
state = eng.init_state(pos, attrs, seed=0)
mesh = make_abm_mesh((2, 2))

step = eng.make_sharded_step(mesh)
st = step(state, full_halo=True)
t0 = time.perf_counter()
for _ in range(6):
    st = step(st, full_halo=True)
jax.block_until_ready(st.soa.valid)
dt = (time.perf_counter() - t0) / 6

mig = {}
for transport in ("host", "device"):
    # one warm pass populates the compiled-migration cache, the timed
    # pass (fresh Rebalancer, same state) reports steady re-shard cost
    for rnd in range(2):
        rb = Rebalancer(every=1, threshold=0.2, ownership="rcb",
                        transport=transport)
        e2, s2, did = rb.maybe_reshard(eng, state)
        assert did, rb.history
        rec = rb.history[-1]
        assert rec["transport"] == transport, rec
        assert total_agents(s2) == n
    mig[transport] = rec["migration_s"]
host_steps = mig["host"] / dt
dev_steps = mig["device"] / dt
print(f"reshard_downtime_steps,{mig['device']*1e6:.1f},"
      f"host={host_steps:.2f}_device={dev_steps:.2f}_steps"
      f"_at_step={dt*1e6:.0f}us"
      f"_migration_host={mig['host']*1e6:.0f}us_device={mig['device']*1e6:.0f}us")
"""
    run_sub_bench(code, "reshard_downtime")


# ---------------------------------------------------------------------------
# Facade overhead: Simulation.run vs the raw Engine.drive loop
# ---------------------------------------------------------------------------

def bench_api_overhead():
    """Driver dispatch cost: per-step dispatch vs the scan-fused segment
    runner, and the Simulation facade vs the raw fused ``engine.drive``
    (the facade must stay within noise — its work is pure Python
    scheduling at segment boundaries)."""
    import numpy as np

    from repro.core import Engine, Domain, Simulation
    from repro.sims import cell_clustering

    beh = cell_clustering.behavior()
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                    cap=24)
    rng = np.random.default_rng(0)
    n = 400
    lx, ly = geom.domain_size
    pos = rng.uniform(0.5, lx - 0.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    steps = 30

    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    state0 = eng.init_state(pos, attrs, seed=0)
    step = eng.make_local_step()

    def time_per_step():
        t0 = time.perf_counter()
        _, s, _ = eng.drive(state0, steps, step_fn=step)
        jax.block_until_ready(s.soa.valid)
        return (time.perf_counter() - t0) / steps

    def time_fused():
        t0 = time.perf_counter()
        _, s, _ = eng.drive(state0, steps)
        jax.block_until_ready(s.soa.valid)
        return (time.perf_counter() - t0) / steps

    sim = Simulation(geom, beh, dt=0.1)

    def time_facade():
        sim.init(pos, attrs, seed=0)
        t0 = time.perf_counter()
        sim.run(steps)
        jax.block_until_ready(sim.state.soa.valid)
        return (time.perf_counter() - t0) / steps

    time_per_step(), time_fused(), time_facade()           # warm compile
    # interleave two passes each and keep the best: on shared CPU the
    # scheduler noise exceeds the facade's pure-Python per-step cost
    t_step = min(time_per_step(), time_per_step())
    t_fuse = min(time_fused(), time_fused())
    t_fac = min(time_facade(), time_facade())

    emit("api_overhead_per_step_drive", t_step * 1e6,
         f"agent_updates_per_s={n/t_step:.0f}_dispatch_per_step")
    emit("api_overhead_raw_drive", t_fuse * 1e6,
         f"agent_updates_per_s={n/t_fuse:.0f}"
         f"_scan_fused_speedup={t_step/t_fuse:.1f}x")
    emit("api_overhead_facade", t_fac * 1e6,
         f"overhead={(t_fac/t_fuse - 1)*100:+.1f}%_vs_raw_drive")


# ---------------------------------------------------------------------------
# LM roofline summary (from dry-run records)
# ---------------------------------------------------------------------------

def bench_roofline():
    d = ROOT / "results" / "dryrun"
    if not d.exists():
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    best = {}
    for p in sorted(d.glob("*__baseline.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        best[key] = r
    for (arch, shape, mesh), r in sorted(best.items()):
        if mesh != "single":
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(f"roofline_{arch}_{shape}", bound * 1e6,
             f"dominant={r['dominant']};frac={r['roofline_fraction']:.4f}")


# ---------------------------------------------------------------------------
# simcheck: construction-time audit cost, zero per-step cost
# ---------------------------------------------------------------------------

def bench_simcheck():
    """Cost of the static contract gate and the full validate() audit.
    Both run at construction / on demand only — the contract the row pins
    is that the *per-step* cost of a gated simulation is zero (the gate
    adds no tracing, no callbacks, nothing to the compiled step)."""
    import numpy as np

    from repro.analysis import check_engine
    from repro.core import Engine, Domain, Simulation
    from repro.sims import cell_clustering

    beh = cell_clustering.behavior()
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                  cap=24)
    rng = np.random.default_rng(0)
    n = 400
    lx, ly = geom.domain_size
    pos = rng.uniform(0.5, lx - 0.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}

    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    t_gate = timeit(lambda: check_engine(eng), n=20, warmup=2)

    sim = Simulation(dict(interior=(8, 8), cap=24), beh, dt=0.1)
    sim.init(pos, attrs, seed=0)
    t_validate = timeit(lambda: sim.validate(), n=3, warmup=1)

    steps = 30

    def per_step(check):
        e = Engine(geom=geom, behavior=beh, dt=0.1, check=check)
        s0 = e.init_state(pos, attrs, seed=0)
        step = e.make_local_step()

        def run():
            _, s, _ = e.drive(s0, steps, step_fn=step)
            jax.block_until_ready(s.soa.attrs["pos"])
        return timeit(run, n=3, warmup=1) / steps

    t_off = per_step("off")
    t_gated = per_step("error")

    emit("simcheck_contract_gate", t_gate, "construction_time_only")
    emit("simcheck_validate_ms", t_validate / 1e3,
         "full_audit=contracts+jaxpr+lint_on_demand_only")
    emit("simcheck_step_overhead", t_gated - t_off,
         f"per_step_cost_gated_vs_off={t_gated/t_off - 1:+.2%}_target_0")


def bench_resilience():
    """Cost of the resilience stack (docs/resilience.md): the fused guard
    set's per-step overhead (budget: <= 5%) and the replay debt of a
    checkpoint-rollback recovery at the bench's cadence."""
    import tempfile

    import numpy as np

    from repro.core import Engine, Domain
    from repro.core.guards import GuardConfig
    from repro.distributed.chaos import Fault, FaultPlan
    from repro.launch.supervise import Supervised, Supervisor
    from repro.sims import cell_clustering
    from repro.sims.common import make_sim

    beh = cell_clustering.behavior()
    geom = Domain(cell_size=2.0, interior=(16, 16), mesh_shape=(1, 1),
                  cap=24)
    rng = np.random.default_rng(0)
    n = 900
    lx, ly = geom.domain_size
    pos = rng.uniform(0.5, lx - 0.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}

    steps = 30

    def per_step(guards):
        e = Engine(geom=geom, behavior=beh, dt=0.1,
                   guards=GuardConfig(policy=guards))
        s0 = e.init_state(pos, attrs, seed=0)
        step = e.make_local_step()

        def run():
            _, s, _ = e.drive(s0, steps, step_fn=step)
            jax.block_until_ready(s.soa.attrs["pos"])
        return timeit(run, n=3, warmup=1) / steps

    t_off = per_step("off")
    t_guarded = per_step("error")
    emit("guard_overhead_per_step", t_guarded - t_off,
         f"guarded_vs_off={t_guarded/t_off - 1:+.2%}_budget_5%")

    # recovery: NaN burst mid-chunk -> guard trip -> rollback -> replay
    every, fault_at, total = 10, 14, 30
    with tempfile.TemporaryDirectory() as ck:
        sim = make_sim(beh, interior=(16, 16), cap=24, dt=0.1,
                       guards="error")
        sim.init(pos, attrs, seed=0)
        plan = FaultPlan((Fault(step=fault_at, kind="nan_attrs",
                                frac=0.05),), seed=7)
        sv = Supervisor(sim, Supervised(dir=ck, every=every, keep=3),
                        fault_plan=plan)
        t0 = time.perf_counter()
        sv.run(total)
        wall = time.perf_counter() - t0
        rec = sv.events("recovered")[0]
    emit("recovery_time_steps", rec["replay_steps"],
         f"replay_debt_steps_at_every={every}_"
         f"supervised_{total}_steps_wall={wall:.2f}s")


def bench_ensemble():
    """Configs/s through the ensemble vs sequential solo runs over FRESH
    parameter points — the sweep/calibration workload the serving layer
    exists for (docs/serving.md; acceptance bar: >= 2x at R >= 8 on CPU).

    Every round of a sweep or an ABC fit proposes parameter points never
    run before.  Sequentially, each distinct point is a distinct behavior
    -> a distinct engine -> its own trace + compile (the solo compiled-
    step caches key on behavior identity, so fresh points always miss).
    The ensemble traces its family ONCE with parameters as tracers; new
    points ride the cached runner.  So the steady-state comparison is
    warm-family batched vs compile-inclusive sequential — per fresh
    config, forever, by construction.  The warm-vs-warm ratio (pure
    batching, no compile anywhere) is reported alongside for honesty."""
    import time as _time

    from repro.core.ensemble import replica_state
    from repro.sims import sir_mechanics as sm

    R, steps, n_agents = 8, 20, 200

    def mk_points(lo):
        return [{**sm.ensemble_defaults(), "beta": lo + 0.01 * r,
                 "seed": r} for r in range(R)]

    ens = sm.ensemble_family(interior=(8, 8))
    warm = sm.ensemble_init(ens, mk_points(0.010), n_agents=n_agents)
    t0 = _time.perf_counter()
    out, _ = ens.run(warm, steps)   # compiles the family runner once
    jax.block_until_ready(out.state.soa.attrs["pos"])
    family_compile_s = _time.perf_counter() - t0

    # fresh points through the warm family: no retrace
    estate = sm.ensemble_init(ens, mk_points(0.011), n_agents=n_agents)

    def run_batched():
        o, _ = ens.run(estate, steps)
        jax.block_until_ready(o.state.soa.attrs["pos"])

    us_batched = timeit(run_batched, n=3, warmup=1)

    # sequential over another fresh set: per-point compile is inherent
    # (cold by construction — each point measured once)
    seq_points = mk_points(0.012)
    states = [replica_state(estate.state, r) for r in range(R)]
    t0 = _time.perf_counter()
    warm_solo_us = 0.0
    for r, p in enumerate(seq_points):
        eng = ens.solo_engine({k: p[k] for k in ens.param_names})
        seg = eng.make_segment_runner(None)
        jax.block_until_ready(seg(states[r], steps, True)
                              .soa.attrs["pos"])
        t1 = _time.perf_counter()   # warm rerun, for the no-compile ratio
        jax.block_until_ready(seg(states[r], steps, True)
                              .soa.attrs["pos"])
        warm_solo_us += (_time.perf_counter() - t1) * 1e6
    us_seq = (_time.perf_counter() - t0) * 1e6 - warm_solo_us

    speedup = us_seq / us_batched
    warm_ratio = warm_solo_us / us_batched
    cps = R / (us_batched / 1e6)
    emit("ensemble_configs_per_s", us_batched / R,
         f"{cps:.2f} configs/s at R={R} x {steps} steps; {speedup:.1f}x "
         f"vs sequential solo over fresh points (compile-inclusive, "
         f"{us_seq / R / 1e6:.1f} s/config); warm-vs-warm {warm_ratio:.2f}x; "
         f"family compile {family_compile_s:.0f}s, amortized over every "
         "later batch")


def bench_serve():
    """Steady-state request latency through the scenario server: one
    warm-up slot compiles the family's runner, then a full slot measures
    submit->done wall time per request (shared cached dispatches)."""
    from repro.launch.serve import (
        ScenarioRequest, ScenarioServer, sir_mechanics_family)

    slot, steps = 8, 20
    server = ScenarioServer([sir_mechanics_family(n_agents=200)],
                            slot_size=slot)

    def batch(seed0):
        rids = [server.submit(ScenarioRequest(
                    family="sir_mechanics", params={"beta": 0.05},
                    steps=steps, stream_every=5, seed=seed0 + i))
                for i in range(slot)]
        server.drain()
        return [server.handle(r) for r in rids]

    batch(0)                       # warm-up: compiles the runner
    handles = batch(slot)
    lat_ms = [h.latency_s * 1e3 for h in handles]
    occ = server.stats()["mean_occupancy"]
    emit("serve_request_latency_ms", float(np.mean(lat_ms)) * 1e3,
         f"{np.mean(lat_ms):.1f} ms mean over a full slot of {slot} "
         f"({steps} steps, stream_every=5, occupancy {occ:.2f})")


BENCHES = {
    "serialization": bench_serialization,
    "simcheck": bench_simcheck,
    "resilience": bench_resilience,
    "delta": bench_delta,
    "sweep": bench_sweep,
    "sweep_3d": bench_sweep_3d,
    "halo_bytes_3d": bench_halo_bytes_3d,
    "comm_budget": bench_comm_budget,
    "sim": bench_sims,
    "sim_tumor_spheroid": bench_sim_tumor_spheroid,
    "api_overhead": bench_api_overhead,
    "scaling": bench_scaling,
    "rebalance": bench_rebalance,
    "rebalance_uneven": bench_rebalance_uneven,
    "ensemble": bench_ensemble,
    "serve": bench_serve,
    "roofline": bench_roofline,
}


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    only = None
    if argv and argv[0] == "--only":
        if len(argv) < 2:
            sys.exit("--only needs a prefix list, e.g. --only sweep,sim")
        only = [p.strip() for p in argv[1].split(",")]
        if not any(n.startswith(p) for n in BENCHES for p in only):
            sys.exit(f"--only {argv[1]}: no benchmark matches "
                     f"(known: {', '.join(BENCHES)})")
    for name, fn in BENCHES.items():
        if only is None or any(name.startswith(p) for p in only):
            fn()
    out = ROOT / "BENCH_results.json"
    merged = merge_rows(out, ROWS)
    out.write_text(json.dumps(merged, indent=1))
    print(f"\n# {len(ROWS)} benchmark rows -> {out} "
          f"({len(merged)} total after merge)")


if __name__ == "__main__":
    main()
