"""AdamW with Warmup-Stable-Decay (WSD) schedule (MiniCPM, arXiv:2404.06395).

Optimizer state keeps f32 master weights plus f32 first/second moments;
model params stay bf16 (recast from the master copy each step).  All state
arrays inherit the parameter sharding, so the optimizer is ZeRO-sharded for
free wherever params are FSDP-sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class WSDSchedule:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    stable_steps: int = 1000
    decay_steps: int = 100
    final_frac: float = 0.1

    def __call__(self, step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = self.peak_lr * jnp.minimum(s / max(self.warmup_steps, 1), 1.0)
        t_decay = s - (self.warmup_steps + self.stable_steps)
        frac = jnp.clip(t_decay / max(self.decay_steps, 1), 0.0, 1.0)
        decay_mult = 1.0 - (1.0 - self.final_frac) * frac
        return jnp.where(
            s < self.warmup_steps + self.stable_steps, warm,
            self.peak_lr * decay_mult,
        )


class AdamWState(NamedTuple):
    step: Array
    master: Any   # f32 copy of params
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: WSDSchedule = WSDSchedule()
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        f32 = lambda p: p.astype(jnp.float32)
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            master=jax.tree_util.tree_map(f32, params),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, master, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mh = m2 / bc1
            vh = v2 / bc2
            new_master = master - lr * (
                mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * master
            )
            return m2, v2, new_master, new_master.astype(p.dtype)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_w = treedef.flatten_up_to(state.master)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(*t) for t in zip(flat_g, flat_m, flat_v, flat_w, flat_p)]
        m2 = treedef.unflatten([o[0] for o in out])
        v2 = treedef.unflatten([o[1] for o in out])
        w2 = treedef.unflatten([o[2] for o in out])
        p2 = treedef.unflatten([o[3] for o in out])
        return p2, AdamWState(step=step, master=w2, m=m2, v=v2)
