"""Train / serve step functions — the units the dry-run lowers and compiles.

``make_train_step`` builds ``(params, opt_state, batch) -> (params,
opt_state, metrics)`` with optional gradient accumulation and optional
delta-encoded gradient compression on the data-parallel all-reduce (the
paper's §2.3 insight applied beyond-paper; see
repro.distributed.grad_compress).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model
from repro.training.optimizer import AdamW, AdamWState

Array = jax.Array


def cross_entropy(logits: Array, labels: Array, mask: Optional[Array] = None
                  ) -> Array:
    """Mean token cross-entropy in f32; logits (B, S, V), labels (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def loss_fn(model: Model, params, batch: Dict[str, Array],
            backend: str = "chunked", remat: str = "dots") -> Array:
    cfg = model.cfg
    logits = model.logits(params, batch, backend=backend, remat=remat)
    if cfg.family == "vlm":
        # loss only on the text span (logits cover patches ++ text)
        logits = logits[:, cfg.n_patches:]
    return cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


def make_train_step(
    model: Model,
    opt: AdamW,
    accum_steps: int = 1,
    backend: str = "chunked",
    remat: str = "dots",
    grad_transform: Optional[Callable] = None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``grad_transform(grads, ctx) -> (grads, ctx)`` hooks gradient compression
    between backward and optimizer (identity if None).
    """

    def compute_grads(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(
                lambda p: loss_fn(model, p, batch, backend, remat))(params)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(
                lambda p: loss_fn(model, p, mb, backend, remat))(params)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (loss_acc + l, g_acc), None

        def split(x):
            b = x.shape[0]
            return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

        micro_batches = jax.tree_util.tree_map(split, batch)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            micro, (jnp.float32(0.0), zeros), micro_batches)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree_util.tree_map(
            lambda g: g * inv, grads)

    def train_step(params, opt_state: AdamWState, batch, grad_ctx=None):
        loss, grads = compute_grads(params, batch)
        if grad_transform is not None:
            grads, grad_ctx = grad_transform(grads, grad_ctx)
        new_params, new_opt = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g.astype(jnp.float32) ** 2)
            for g in jax.tree_util.tree_leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.schedule(new_opt.step)}
        if grad_transform is not None:
            return new_params, new_opt, metrics, grad_ctx
        return new_params, new_opt, metrics

    return train_step


def make_serve_decode_step(model: Model):
    """decode_step(params, cache, tokens, index) -> (logits, cache)."""

    def step(params, cache, tokens: Array, index: Array):
        b = tokens.shape[0]
        max_len = _cache_len(model.cfg, cache)
        length_mask = (jnp.arange(max_len)[None, :]
                       <= index) & jnp.ones((b, 1), jnp.bool_)
        logits, new_cache = model.decode_step(
            params, tokens, cache, index, length_mask)
        return logits, new_cache

    return step


def _cache_len(cfg: ArchConfig, cache) -> int:
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attention == "mla":
            return cache.shape[2]
        return cache[0].shape[3]
    if cfg.family == "hybrid":
        return cache["attn"][0].shape[3]
    if cfg.family == "ssm":
        return 1  # recurrent state only; mask unused
    raise ValueError(cfg.family)


def make_prefill_step(model: Model):
    def step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return step
