"""Fault-tolerant checkpointing: atomic, sharded-friendly, elastic-restore.

Design (DESIGN.md §5):
  * save: every array leaf -> .npy under a temp dir; metadata (step, tree
    structure, user extras) -> JSON; atomic publish via directory rename.
    A crashed writer can never corrupt the latest checkpoint.
  * restore: host-side load + device_put against the *current* mesh's
    shardings — the device count may differ from the writer's (elastic
    restart after node failure); re-sharding happens at placement time.
  * async: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes to disk on a background thread, overlapping I/O with the
    next training steps.
  * retention: ``keep`` newest checkpoints are retained, older ones pruned.

Combined with the deterministic data pipeline (batch = f(seed, step)), a
restore needs only (params, opt_state, step) to resume bit-identically.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any,
         extras: Optional[Dict] = None, keep: int = 3) -> str:
    """Synchronous atomic checkpoint save.  Returns the published path."""
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f".tmp_step_{step:010d}_{os.getpid()}"
    final = base / f"step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extras": extras or {}, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # numpy can't round-trip bf16: widen
            arr = arr.astype(np.float32)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_name})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    _prune(base, keep)
    return str(final)


def _prune(base: pathlib.Path, keep: int):
    steps = sorted(p for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Any, extras: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def work():
            self.last_path = save(self.ckpt_dir, step, host_tree,
                                  extras, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def save_abm(ckpt_dir: str, step: int, engine, state,
             extras: Optional[Dict] = None, keep: int = 3) -> str:
    """Checkpoint an ABM :class:`SimState` *logically*: the flattened live
    agents plus the engine carry (iteration, spawn counters, RNG root) and
    the occupancy histogram.

    Storing the flattened form instead of the sharded SoA makes the
    checkpoint mesh-independent — restore is a re-shard whose target mesh is
    chosen from the stored histogram (elastic.elastic_restore_abm), so a
    run can resume on any surviving device count.
    """
    from repro.core.reshard import flatten_state, occupancy_histogram

    flat = flatten_state(engine.geom, state)
    hist = occupancy_histogram(engine.geom, state)
    tree = {
        "positions": flat.positions,
        "attrs": {k: np.asarray(v) for k, v in sorted(flat.attrs.items())},
        "gid_counters": flat.gid_counters,
        "base_key": flat.base_key,
        "histogram": hist,
    }
    geom = engine.geom
    abm_meta = {
        "it": int(flat.it),
        "dropped_total": int(flat.dropped_total),
        "cell_size": float(geom.cell_size),
        "ndim": int(geom.ndim),
        "global_cells": list(geom.global_cells),
        "cap": int(geom.cap),
        # per-axis boundary list (legacy checkpoints stored one string;
        # Domain normalizes either form on restore)
        "boundary": list(geom.boundary),
        "box_factor": int(geom.box_factor),
        "dt": float(engine.dt),
        "attr_names": sorted(flat.attrs),
        # uneven-ownership provenance: the live cut positions (cells) and
        # the ownership mode a restore should re-cut with.  Restore never
        # reuses the cuts verbatim — the device count may differ — it cuts
        # a FRESH plan from the stored histogram (elastic_restore_abm);
        # legacy checkpoints without these keys restore as "equal".
        "partition": ([list(c) for c in geom.partition.cuts]
                      if geom.uneven else None),
        "ownership": "rcb" if geom.uneven else "equal",
    }
    return save(ckpt_dir, step, tree,
                extras={"abm": abm_meta, **(extras or {})}, keep=keep)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = sorted(p.name for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore(ckpt_dir: str, step: Optional[int] = None,
            like: Any = None, shardings: Any = None
            ) -> Tuple[int, Any, Dict]:
    """Restore a checkpoint.

    Args:
      like: a pytree with the same structure (e.g. abstract params) used to
        rebuild the tree; if None, returns a flat {key: array} dict.
      shardings: optional matching pytree of NamedSharding for elastic
        placement on the current (possibly different-sized) mesh.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = pathlib.Path(ckpt_dir) / f"step_{step:010d}"
    manifest = json.loads((path / "manifest.json").read_text())
    arrays = [np.load(path / leaf["file"]) for leaf in manifest["leaves"]]

    if like is None:
        flat = {leaf["key"]: arr
                for leaf, arr in zip(manifest["leaves"], arrays)}
        return manifest["step"], flat, manifest["extras"]

    leaves, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves) == len(arrays), (
        f"checkpoint has {len(arrays)} leaves, tree expects {len(leaves)}")
    def cast(a, l):
        return jax.numpy.asarray(a).astype(l.dtype)

    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(
                x, jax.sharding.Sharding))
        placed = [jax.device_put(cast(a, l), s)
                  for a, l, s in zip(arrays, leaves, shard_leaves)]
    else:
        placed = [cast(a, l) for a, l in zip(arrays, leaves)]
    return (manifest["step"],
            jax.tree_util.tree_unflatten(treedef, placed),
            manifest["extras"])
