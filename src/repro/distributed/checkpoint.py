"""Fault-tolerant checkpointing: atomic, sharded-friendly, elastic-restore.

Design (DESIGN.md §5):
  * save: every array leaf -> .npy under a temp dir; metadata (step, tree
    structure, user extras) -> JSON; atomic publish via directory rename.
    A crashed writer can never corrupt the latest checkpoint.
  * restore: host-side load + device_put against the *current* mesh's
    shardings — the device count may differ from the writer's (elastic
    restart after node failure); re-sharding happens at placement time.
  * async: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes to disk on a background thread, overlapping I/O with the
    next training steps.
  * retention: ``keep`` newest checkpoints are retained, older ones pruned.

Combined with the deterministic data pipeline (batch = f(seed, step)), a
restore needs only (params, opt_state, step) to resume bit-identically.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import warnings
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A checkpoint directory failed verification: missing or unparsable
    manifest, unreadable array leaf, or a per-leaf checksum mismatch."""


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any,
         extras: Optional[Dict] = None, keep: int = 3) -> str:
    """Synchronous atomic checkpoint save.  Returns the published path."""
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f".tmp_step_{step:010d}_{os.getpid()}"
    final = base / f"step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extras": extras or {}, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # numpy can't round-trip bf16: widen
            arr = arr.astype(np.float32)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_name,
             # per-leaf content checksum: restore verifies it so a torn
             # write or storage-level corruption is detected, not loaded
             "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes())})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    _prune(base, keep)
    return str(final)


def _prune(base: pathlib.Path, keep: int):
    steps = sorted(p for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


def _sweep_stale_tmp(base: pathlib.Path) -> List[str]:
    """Remove ``.tmp_step_*_<pid>`` dirs whose writer process is dead — a
    crashed writer's half-written temp dir otherwise lingers forever (the
    atomic-rename protocol never publishes it, but it wastes storage and
    confuses humans).  Temp dirs of live pids (a concurrent writer) are
    left alone."""
    removed = []
    if not base.exists():
        return removed
    for p in base.glob(".tmp_step_*"):
        if not p.is_dir():
            continue
        pid_s = p.name.rsplit("_", 1)[-1]
        if not pid_s.isdigit():
            continue
        pid = int(pid_s)
        alive = pid == os.getpid()
        if not alive:
            try:
                os.kill(pid, 0)
                alive = True
            except ProcessLookupError:
                alive = False
            except PermissionError:  # exists, owned by someone else
                alive = True
            except OSError:
                alive = False
        if not alive:
            shutil.rmtree(p, ignore_errors=True)
            removed.append(str(p))
    return removed


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread.

    A background write that fails does not vanish: the exception is
    recorded and re-raised from the next :meth:`wait` or :meth:`save` —
    otherwise a run could march on for hours believing it has checkpoints
    it does not.  Construction sweeps stale temp dirs left by dead
    writers (see :func:`_sweep_stale_tmp`).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_path: Optional[str] = None
        self.swept = _sweep_stale_tmp(pathlib.Path(ckpt_dir))

    def save(self, step: int, tree: Any, extras: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def work():
            try:
                self.last_path = save(self.ckpt_dir, step, host_tree,
                                      extras, self.keep)
            except BaseException as e:  # noqa: BLE001 - recorded, re-raised
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_abm(self, step: int, engine, state,
                 extras: Optional[Dict] = None):
        """Async variant of :func:`save_abm`: the mesh-independent logical
        snapshot (flatten + histogram + host gather) runs synchronously —
        it must see the state *now* — and only the disk write overlaps
        with subsequent steps."""
        self.wait()
        tree, merged = _abm_snapshot(engine, state, extras)
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                self.last_path = save(self.ckpt_dir, step, host_tree,
                                      merged, self.keep)
            except BaseException as e:  # noqa: BLE001 - recorded, re-raised
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> Optional[str]:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        return self.last_path


def _delta_meta(cfg) -> Optional[Dict]:
    """JSON-able record of an engine's aura-codec config (None if absent)."""
    if cfg is None:
        return None
    return {
        "enabled": bool(cfg.enabled),
        "qdtype": np.dtype(cfg.qdtype).name,
        "refresh_interval": int(cfg.refresh_interval),
        "scale": None if cfg.scale is None else float(cfg.scale),
    }


def _abm_snapshot(engine, state, extras: Optional[Dict] = None
                  ) -> Tuple[Dict, Dict]:
    """Build the logical (mesh-independent) checkpoint tree + extras for
    an ABM state — shared by the sync :func:`save_abm` and the async
    :meth:`AsyncCheckpointer.save_abm`."""
    from repro.core.reshard import flatten_state, occupancy_histogram

    flat = flatten_state(engine.geom, state)
    hist = occupancy_histogram(engine.geom, state)
    tree = {
        "positions": flat.positions,
        "attrs": {k: np.asarray(v) for k, v in sorted(flat.attrs.items())},
        "gid_counters": flat.gid_counters,
        "base_key": flat.base_key,
        "histogram": hist,
    }
    geom = engine.geom
    abm_meta = {
        "it": int(flat.it),
        "dropped_total": int(flat.dropped_total),
        "cell_size": float(geom.cell_size),
        "ndim": int(geom.ndim),
        "global_cells": list(geom.global_cells),
        "cap": int(geom.cap),
        # per-axis boundary list (legacy checkpoints stored one string;
        # Domain normalizes either form on restore)
        "boundary": list(geom.boundary),
        "box_factor": int(geom.box_factor),
        "dt": float(engine.dt),
        "attr_names": sorted(flat.attrs),
        # uneven-ownership provenance: the live cut positions (cells) and
        # the ownership mode a restore should re-cut with.  Restore never
        # reuses the cuts verbatim — the device count may differ — it cuts
        # a FRESH plan from the stored histogram (elastic_restore_abm);
        # legacy checkpoints without these keys restore as "equal".
        "partition": ([list(c) for c in geom.partition.cuts]
                      if geom.uneven else None),
        "ownership": "rcb" if geom.uneven else "equal",
        # aura-codec provenance: restore re-applies the same delta config
        # by default so a recovery replay stays bit-exact with the
        # checkpointed run (the quantized closed loop is part of the
        # dynamics once enabled).  Legacy checkpoints without the key
        # restore with the codec off, as before.
        "delta": _delta_meta(getattr(engine, "delta_cfg", None)),
    }
    return tree, {"abm": abm_meta, **(extras or {})}


def save_abm(ckpt_dir: str, step: int, engine, state,
             extras: Optional[Dict] = None, keep: int = 3) -> str:
    """Checkpoint an ABM :class:`SimState` *logically*: the flattened live
    agents plus the engine carry (iteration, spawn counters, RNG root) and
    the occupancy histogram.

    Storing the flattened form instead of the sharded SoA makes the
    checkpoint mesh-independent — restore is a re-shard whose target mesh is
    chosen from the stored histogram (elastic.elastic_restore_abm), so a
    run can resume on any surviving device count.
    """
    tree, merged = _abm_snapshot(engine, state, extras)
    return save(ckpt_dir, step, tree, extras=merged, keep=keep)


def _step_dirs(base: pathlib.Path) -> List[pathlib.Path]:
    out = []
    for p in base.iterdir():
        if not (p.is_dir() and p.name.startswith("step_")):
            continue
        suffix = p.name.split("_", 1)[1]
        if suffix.isdigit():
            out.append(p)
    return sorted(out)


def _load_verified(path: pathlib.Path) -> Tuple[Dict, List[np.ndarray]]:
    """Load (manifest, arrays) from one checkpoint dir, verifying per-leaf
    checksums when present.  Raises :class:`CheckpointCorrupt` on any
    missing/unparsable manifest, unreadable leaf, or checksum mismatch."""
    mpath = path / "manifest.json"
    if not mpath.exists():
        raise CheckpointCorrupt(f"{path}: missing manifest.json")
    try:
        manifest = json.loads(mpath.read_text())
        leaves = manifest["leaves"]
    except (ValueError, KeyError, TypeError) as e:
        raise CheckpointCorrupt(
            f"{path}: unparsable manifest.json ({e})") from e
    arrays = []
    for leaf in leaves:
        try:
            arr = np.load(path / leaf["file"])
        except Exception as e:  # torn/truncated/missing .npy
            raise CheckpointCorrupt(
                f"{path}: unreadable leaf {leaf.get('file')} "
                f"[{leaf.get('key')}] ({e})") from e
        want = leaf.get("crc32")  # absent on legacy checkpoints
        if want is not None:
            got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if got != want:
                raise CheckpointCorrupt(
                    f"{path}: checksum mismatch on leaf "
                    f"{leaf['file']} [{leaf.get('key')}] "
                    f"(crc32 {got:#010x} != manifest {want:#010x})")
        arrays.append(arr)
    return manifest, arrays


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *plausibly usable* checkpoint step: dirs without a parsable
    ``manifest.json`` are skipped with a warning (a torn write past the
    atomic rename, or external corruption) instead of crashing the
    restore path.  Content checksums are verified at :func:`restore`."""
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    for p in reversed(_step_dirs(base)):
        try:
            json.loads((p / "manifest.json").read_text())
        except (OSError, ValueError) as e:
            warnings.warn(
                f"skipping checkpoint {p.name} in {ckpt_dir}: "
                f"missing/corrupt manifest.json ({e})", stacklevel=2)
            continue
        return int(p.name.split("_", 1)[1])
    return None


def restore(ckpt_dir: str, step: Optional[int] = None,
            like: Any = None, shardings: Any = None
            ) -> Tuple[int, Any, Dict]:
    """Restore a checkpoint.

    Args:
      like: a pytree with the same structure (e.g. abstract params) used to
        rebuild the tree; if None, returns a flat {key: array} dict.
      shardings: optional matching pytree of NamedSharding for elastic
        placement on the current (possibly different-sized) mesh.

    With ``step=None`` the newest checkpoint that passes full verification
    (manifest parses, every leaf loads, checksums match) is used —
    corrupt ones are skipped newest-to-oldest with a warning naming the
    skipped dir.  An explicit ``step`` that fails verification raises
    :class:`CheckpointCorrupt`.
    """
    base = pathlib.Path(ckpt_dir)
    if step is not None:
        manifest, arrays = _load_verified(base / f"step_{step:010d}")
    else:
        if not base.exists():
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
        manifest = arrays = None
        for path in reversed(_step_dirs(base)):
            try:
                manifest, arrays = _load_verified(path)
                break
            except CheckpointCorrupt as e:
                warnings.warn(
                    f"skipping corrupt checkpoint {path.name}: {e}",
                    stacklevel=2)
        if manifest is None:
            raise FileNotFoundError(
                f"no usable checkpoints in {ckpt_dir} (all candidates "
                "failed verification)")

    if like is None:
        flat = {leaf["key"]: arr
                for leaf, arr in zip(manifest["leaves"], arrays)}
        return manifest["step"], flat, manifest["extras"]

    leaves, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves) == len(arrays), (
        f"checkpoint has {len(arrays)} leaves, tree expects {len(leaves)}")
    def cast(a, l):
        return jax.numpy.asarray(a).astype(l.dtype)

    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(
                x, jax.sharding.Sharding))
        placed = [jax.device_put(cast(a, l), s)
                  for a, l, s in zip(arrays, leaves, shard_leaves)]
    else:
        placed = [cast(a, l) for a, l in zip(arrays, leaves)]
    return (manifest["step"],
            jax.tree_util.tree_unflatten(treedef, placed),
            manifest["extras"])
