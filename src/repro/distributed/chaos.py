"""Deterministic fault injection: make every recovery path a tested path.

At the paper's scale (hundreds of servers, half a trillion agents) faults
are routine; a recovery path that only runs when production breaks is an
untested path.  This module injects the failure modes the resilience
stack (core.guards + launch.supervise) must survive — on CPU, in tests,
bit-reproducibly:

* ``nan_attrs`` — NaN a seeded fraction of one attribute's live slots
  (a diverging kernel, a bad reduction, bit rot in device memory).
* ``halo_slab`` — NaN the live agents in one device's owned boundary
  layer along an axis: exactly the slab the next aura exchange puts on
  the wire, so the corruption propagates into a neighbor's received aura
  (a corrupted transmission buffer).
* ``device_loss`` — raise :class:`DeviceLost` from the driver's host
  control point (a node dropping out mid-run); the supervisor restores
  onto the surviving device count via ``elastic_restore_abm``.
* ``torn_checkpoint`` — truncate the newest published checkpoint's first
  array leaf after a save (a writer dying mid-write past the atomic
  rename, or storage-level corruption); the hardened
  ``checkpoint.restore`` must skip it.
* ``raise`` — raise :class:`ChaosError` from the host control point (any
  unhandled exception in the step pipeline).

Faults live in a :class:`FaultPlan`: each fires **once**, at an absolute
engine iteration, from the driver's host control points
(``Engine.drive`` / ``Simulation.run`` break their fused segments at
pending fault steps).  Fire-once matters for recovery semantics: after
the supervisor rolls back *below* a fault's step, the replay must not
re-corrupt — that is what makes a recovered run bit-exact with an
uninterrupted run resumed from the same checkpoint.  All randomness
derives from ``(plan.seed, fault index, step)``, never from global RNG
state.  ``fault_plan=None`` everywhere is the zero-cost default: no
extra syncs, no extra dispatches, identical compiled code.
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Optional, Set, Tuple

import numpy as np

FAULT_KINDS = ("nan_attrs", "halo_slab", "device_loss",
               "torn_checkpoint", "raise")


class ChaosError(RuntimeError):
    """An injected generic failure (``kind="raise"``)."""


class DeviceLost(RuntimeError):
    """An injected device/node loss.  ``survivors`` is the device count
    the run should degrade onto."""

    def __init__(self, survivors: int, message: str = ""):
        self.survivors = int(survivors)
        super().__init__(
            message or f"injected device loss: {survivors} device(s) "
                       "survive")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    ``step`` is the absolute engine iteration the fault fires at (state
    corruption lands *before* that step runs, so step ``step`` computes on
    corrupted state).  ``frac`` (nan_attrs) is the fraction of live slots
    to corrupt; ``attr`` the attribute to hit (default positions);
    ``axis`` (halo_slab) the grid axis whose boundary layer is corrupted;
    ``survivors`` (device_loss) the surviving device count (default: one
    less than the run's).
    """

    step: int
    kind: str
    frac: float = 0.05
    attr: str = "pos"
    axis: int = 0
    survivors: Optional[int] = None
    note: str = ""

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind {self.kind!r} not in {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step {self.step} must be >= 0")


@dataclasses.dataclass
class FaultPlan:
    """A seeded, fire-once schedule of faults.

    Drivers call :meth:`fire` at every host control point with the
    absolute iteration about to run; checkpoint writers (the supervisor)
    call :meth:`maybe_tear` after each save.  ``fired`` is mutable
    bookkeeping — share one plan instance across a supervised run so a
    fault never re-fires after rollback.
    """

    faults: Tuple[Fault, ...]
    seed: int = 0

    def __post_init__(self):
        self.faults = tuple(self.faults)
        self.fired: Set[int] = set()

    # -- scheduling ------------------------------------------------------
    def next_step(self, after: int) -> Optional[int]:
        """Smallest unfired state/raise fault step strictly after
        ``after`` (torn_checkpoint rides on saves, not on steps)."""
        steps = [f.step for i, f in enumerate(self.faults)
                 if i not in self.fired and f.kind != "torn_checkpoint"
                 and f.step > after]
        return min(steps) if steps else None

    def _due(self, it: int):
        return [(i, f) for i, f in enumerate(self.faults)
                if i not in self.fired and f.kind != "torn_checkpoint"
                and f.step == it]

    # -- firing ----------------------------------------------------------
    def fire(self, engine, state, it: int):
        """Apply every unfired fault scheduled at iteration ``it``.

        Returns ``(state, corrupted)``; raising faults (device_loss,
        raise) propagate as exceptions *after* any state corruption at
        the same step is applied and marked fired.
        """
        due = self._due(it)
        if not due:
            return state, False
        corrupted = False
        pending_raise = None
        for idx, fault in due:
            self.fired.add(idx)
            if fault.kind == "raise":
                pending_raise = pending_raise or ChaosError(
                    f"injected failure at iteration {it}"
                    + (f" ({fault.note})" if fault.note else ""))
            elif fault.kind == "device_loss":
                n = fault.survivors if fault.survivors is not None \
                    else max(1, engine.geom.n_devices - 1)
                pending_raise = pending_raise or DeviceLost(n)
            else:
                rng = np.random.default_rng([self.seed, idx, it])
                state = _corrupt(engine, state, fault, rng)
                corrupted = True
        if pending_raise is not None:
            raise pending_raise
        return state, corrupted

    def maybe_tear(self, ckpt_dir: str, it: int) -> Optional[str]:
        """Tear the newest published checkpoint if a torn_checkpoint
        fault is due (``fault.step <= it``).  Returns the torn path, or
        None.  Stays armed until a checkpoint exists to tear."""
        due = [(i, f) for i, f in enumerate(self.faults)
               if i not in self.fired and f.kind == "torn_checkpoint"
               and f.step <= it]
        if not due:
            return None
        base = pathlib.Path(ckpt_dir)
        steps = sorted(p for p in base.glob("step_*") if p.is_dir()) \
            if base.exists() else []
        if not steps:
            return None
        target = steps[-1]
        leaves = sorted(target.glob("leaf_*.npy"))
        victim = leaves[0] if leaves else (target / "manifest.json")
        size = victim.stat().st_size
        with open(victim, "r+b") as fh:
            fh.truncate(max(size // 2, 1))
        for i, _ in due:
            self.fired.add(i)
        return str(target)


# ---------------------------------------------------------------------------
# State corruption (host side: gather, poke, re-place)
# ---------------------------------------------------------------------------

def _corrupt(engine, state, fault: Fault, rng: np.random.Generator):
    import jax.numpy as jnp

    from repro.core.agent_soa import POS

    soa = state.soa
    valid = np.asarray(soa.valid)
    if fault.kind == "nan_attrs":
        name = POS if fault.attr in ("pos", POS) else fault.attr
        arr = np.asarray(soa.attrs[name]).copy()
        if not np.issubdtype(arr.dtype, np.floating):
            raise ValueError(
                f"nan_attrs targets float attrs; {name!r} is {arr.dtype}")
        live = np.flatnonzero(valid.reshape(-1))
        if live.size:
            k = max(1, int(round(fault.frac * live.size)))
            pick = rng.choice(live, size=min(k, live.size), replace=False)
            flat = arr.reshape((valid.size,) + arr.shape[valid.ndim:])
            flat[pick] = np.nan
        new = arr
    elif fault.kind == "halo_slab":
        nd = engine.geom.ndim
        if not 0 <= fault.axis < nd:
            raise ValueError(
                f"halo_slab axis {fault.axis} out of range for "
                f"{nd}-D domain")
        name = POS
        arr = np.asarray(soa.attrs[name]).copy()
        mesh = engine.geom.mesh_shape
        # device axes are folded into the grid axes (shard_map blocks):
        # valid has shape (mesh0*local0, mesh1*local1, ..., slots)
        grid = valid.shape[:nd]
        loc = tuple(g // m for g, m in zip(grid, mesh))
        dev = tuple(int(rng.integers(m)) for m in mesh)
        sl = tuple(
            dev[a] * loc[a] + 1 if a == fault.axis  # first owned layer:
            else slice(dev[a] * loc[a],             # the low-side send slab
                       dev[a] * loc[a] + loc[a])
            for a in range(nd))
        layer = arr[sl]
        layer[valid[sl]] = np.nan
        arr[sl] = layer
        new = arr
    else:  # pragma: no cover - fire() routes only corrupting kinds here
        raise ValueError(f"not a state-corrupting fault: {fault.kind}")
    return dataclasses.replace(
        state, soa=soa.replace(attrs={**soa.attrs, name: jnp.asarray(new)}))
