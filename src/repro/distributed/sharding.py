"""Logical-axis sharding rules with divisibility-aware fallback.

Every parameter/activation dimension carries a logical name; rules map each
logical name to an ordered list of mesh-axis candidates.  An axis candidate
is accepted only if (a) it is not already used by another dim of the same
array and (b) its size divides the dim — otherwise the next candidate is
tried, falling back to replication.  This auto-degradation guarantees that
every (arch x mesh) cell lowers and compiles; the roofline/hillclimb loop
then improves the rules where degradation costs performance.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> ordered candidate mesh axes (tuples = combined axes)
Rules = Dict[str, Tuple[object, ...]]

# Default rules. "fsdp" composes data (+pod): weights' embed dim is sharded
# over the data axes, ZeRO-3 style; XLA inserts the all-gathers.
DEFAULT_RULES: Rules = {
    "batch": (("pod", "data"), "data"),
    "seq": ("model",),            # sequence parallelism for long decode
    "vocab": ("model",),
    "embed": ("fsdp",),           # resolved to ("pod","data") or ("data",)
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": (),
    "layers": (),
    "state": (),
    "conv": (),
    "lora": (),
    "frontend": (),
    "patches": (),
    # activation dims
    "seq_act": (),
    "embed_act": (),
    "vocab_act": ("model",),
    "heads_act": ("model",),
    "mlp_act": ("model",),
    # attention fallback: when heads don't divide the model axis, shard the
    # query sequence dim instead (sequence-parallel attention) so attention
    # compute/memory never replicates over "model"
    "qseq_act": ("model",),
    "val_act": ("model",),
    # MoE dispatch: experts over model (EP), capacity over data so the
    # (E, C, D) dispatched-token tensor is fully sharded
    "capacity": ("fsdp",),
}


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _resolve(axis, mesh: Mesh):
    """Map virtual axes to concrete mesh axes."""
    if axis == "fsdp":
        return ("pod", "data") if "pod" in mesh.shape else ("data",)
    if isinstance(axis, tuple):
        out = []
        for a in axis:
            if a in mesh.shape:
                out.append(a)
        return tuple(out) if out else None
    return axis if axis in mesh.shape else None


def spec_for(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Rules] = None,
) -> P:
    """Build a PartitionSpec for ``shape`` whose dims are named ``logical``."""
    rules = {**DEFAULT_RULES, **(rules or {})}
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        chosen = None
        if name is not None and name in rules:
            for cand in rules[name]:
                cand = _resolve(cand, mesh)
                if cand is None:
                    continue
                axes = cand if isinstance(cand, tuple) else (cand,)
                if any(a in used for a in axes):
                    continue
                size = math.prod(mesh.shape[a] for a in axes)
                if size > 1 and dim % size == 0:
                    chosen = cand
                    used.update(axes)
                    break
        parts.append(chosen)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(shape, logical, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, mesh, rules))


def tree_specs(spec_tree, mesh: Mesh, rules: Optional[Rules] = None):
    """Map a pytree of (shape, logical) ParamSpec leaves to PartitionSpecs."""
    from repro.models.params import ParamSpec

    def one(leaf):
        if isinstance(leaf, ParamSpec):
            return spec_for(leaf.shape, leaf.logical, mesh, rules)
        raise TypeError(f"unexpected spec leaf {leaf!r}")

    return jax.tree_util.tree_map(
        one, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


# ---------------------------------------------------------------------------
# Activation sharding constraints (MaxText-style logical constraints)
# ---------------------------------------------------------------------------

import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Optional[Rules] = None):
    """While active, ``constrain`` pins intermediate activations to the mesh.
    A no-op outside this context (CPU unit tests, single-device runs)."""
    old = getattr(_ACTIVE, "v", None)
    _ACTIVE.v = (mesh, rules)
    try:
        yield
    finally:
        _ACTIVE.v = old


def constrain(x, logical: Sequence[Optional[str]]):
    """Pin an activation's sharding by logical dim names (no-op when no
    activation_sharding context is active)."""
    active = getattr(_ACTIVE, "v", None)
    if active is None:
        return x
    mesh, rules = active
    spec = spec_for(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
