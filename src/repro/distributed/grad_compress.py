"""Delta-encoded gradient compression with error feedback — the paper's §2.3
insight (iterative state changes gradually; transmit a narrow delta against a
shared reference) applied beyond-paper to data-parallel training.

Two layers:

* ``DeltaEFCompressor`` — a grad_transform hook for make_train_step:
  maintains per-leaf f32 references (the previous step's transmitted
  gradient) and error-feedback residuals; emits
  ``dequant(quant(grad + residual - ref))`` and folds the quantization error
  into the next step's residual.  This is the closed-loop scheme of
  core.delta applied to gradients; wire bytes drop 4x (int8) / 2x (int16)
  versus f32 and the EF residual guarantees the *sum over steps* of
  transmitted gradients converges to the sum of true gradients (standard
  EF-SGD argument).

* ``compressed_psum`` — the explicit-collective building block: inside
  shard_map, quantize locally, psum the int32-accumulated int8 payload,
  dequantize.  The lowered HLO's all-reduce operand is int8 — the 4x
  collective-byte reduction is directly visible to the roofline parser.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DeltaEFCompressor:
    qdtype: Any = jnp.int8
    refresh_interval: int = 16   # full-precision sync every R steps

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "ref": jax.tree_util.tree_map(zeros, params),
            "residual": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def wire_bytes(self, params, full: bool) -> int:
        import math

        n = sum(math.prod(p.shape)
                for p in jax.tree_util.tree_leaves(params))
        itemsize = 4 if full else jnp.dtype(self.qdtype).itemsize
        return n * itemsize

    def __call__(self, grads, ctx: Optional[dict]) -> Tuple[Any, dict]:
        assert ctx is not None, "pass ctx=compressor.init(params)"
        qinfo = jnp.iinfo(self.qdtype)
        qmax = jnp.float32(qinfo.max)
        step = ctx["step"]
        full = (step % self.refresh_interval) == 0

        def one(g, ref, res):
            g = g.astype(jnp.float32) + res
            delta = g - ref

            def q_path():
                scale = jnp.maximum(jnp.max(jnp.abs(delta)), 1e-30) / qmax
                q = jnp.clip(jnp.round(delta / scale), qinfo.min, qinfo.max)
                recon = ref + q * scale
                return recon

            recon = jnp.where(full, g, q_path())
            residual = g - recon        # error feedback
            return recon, recon, residual

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_ref = treedef.flatten_up_to(ctx["ref"])
        flat_res = treedef.flatten_up_to(ctx["residual"])
        outs = [one(g, r, e) for g, r, e in zip(flat_g, flat_ref, flat_res)]
        new_grads = treedef.unflatten([o[0] for o in outs])
        new_ctx = {
            "ref": treedef.unflatten([o[1] for o in outs]),
            "residual": treedef.unflatten([o[2] for o in outs]),
            "step": step + 1,
        }
        return new_grads, new_ctx


def compressed_psum(x: Array, axis_name: str, axis_size: int,
                    qdtype=jnp.int8) -> Array:
    """int8-on-the-wire all-reduce (call inside shard_map).

    Canonical two-phase compressed ring all-reduce (1-bit-Adam-style):
      1. quantize the local vector per destination chunk; ``all_to_all`` the
         int8 payload (each device becomes the reducer of its chunk),
      2. dequantize + sum in f32, re-quantize the reduced chunk, and
         ``all_gather`` the int8 result.
    Wire bytes: ~2 * N * 1 B vs ~2 * N * 4 B for a ring f32 all-reduce — a
    4x collective-byte reduction, with both wire ops visibly int8 in the
    lowered HLO (asserted in tests).  Naive ``psum(int8.astype(int32))``
    would put s32 on the wire and save nothing.
    """
    qinfo = jnp.iinfo(qdtype)
    qmax = jnp.float32(qinfo.max)
    n = axis_size
    orig_shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    chunks = flat.reshape(n, -1)                       # (n, N/n)

    # phase 1: per-chunk quantize + all_to_all (int8 wire)
    s1 = jnp.maximum(jnp.max(jnp.abs(chunks), axis=1), 1e-30) / qmax  # (n,)
    q1 = jnp.clip(jnp.round(chunks / s1[:, None]), qinfo.min, qinfo.max
                  ).astype(qdtype)
    rq = jax.lax.all_to_all(q1, axis_name, split_axis=0, concat_axis=0)
    rs = jax.lax.all_to_all(s1.reshape(n, 1), axis_name, split_axis=0,
                            concat_axis=0)             # (n, 1) peer scales
    part = jnp.sum(rq.astype(jnp.float32) * rs, axis=0)  # reduced chunk

    # phase 2: re-quantize + all_gather (int8 wire)
    s2 = jnp.maximum(jnp.max(jnp.abs(part)), 1e-30) / qmax
    q2 = jnp.clip(jnp.round(part / s2), qinfo.min, qinfo.max).astype(qdtype)
    all_q = jax.lax.all_gather(q2, axis_name)          # (n, N/n) int8
    all_s = jax.lax.all_gather(s2, axis_name)          # (n,)
    out = (all_q.astype(jnp.float32) * all_s[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape)
