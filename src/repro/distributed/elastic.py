"""Elastic scaling and straggler mitigation.

Node failure / resize protocol (DESIGN.md §5):
  1. AsyncCheckpointer keeps the newest K checkpoints on durable storage.
  2. On failure, the launcher restarts with whatever device count survives;
     ``elastic_restore`` rebuilds the mesh (largest (data, model)
     factorization that divides the parameter shapes), re-derives all
     NamedShardings against the new mesh, and places the checkpoint.
  3. The deterministic data pipeline (batch = f(seed, step)) resumes from
     the checkpointed step with zero data-loader state — this is also the
     straggler story: any peer can recompute any shard's batch, so a slow
     host can be dropped at a step boundary without coordination.

For the ABM engine, re-partitioning uses the load-balance planners
(core.load_balance) to pick the new spatial mesh from the occupancy
histogram before re-initializing device state.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.distributed import checkpoint as ckpt_lib
from repro.distributed.sharding import Rules
from repro.launch.mesh import make_mesh


def choose_lm_mesh(n_devices: int, model_parallel: int = 16
                   ) -> Tuple[Tuple[int, int], Tuple[str, str]]:
    """Largest (data, model) factorization for a (possibly degraded) device
    count: keep model parallelism at ``model_parallel`` if it divides, else
    fall back to the largest power-of-two divisor."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    return (n_devices // mp, mp), ("data", "model")


def elastic_restore_abm(ckpt_dir: str, behavior, *,
                        n_devices: Optional[int] = None,
                        step: Optional[int] = None,
                        delta_cfg=None, dt: Optional[float] = None,
                        rebalance_every: int = 0,
                        imbalance_threshold: float = 0.5,
                        ownership: Optional[str] = None):
    """Restore an ABM checkpoint (checkpoint.save_abm) onto the *current*
    device population — the ABM half of the elastic protocol.

    The checkpoint stores mesh-independent flattened agents plus the
    occupancy histogram; ``choose_partition`` cuts a fresh plan for the
    surviving device count over that histogram (2-D or 3-D, per the
    checkpointed Domain): the least-imbalanced equal-split factorization
    for ``ownership="equal"``, or a box-granular uneven rectilinear
    partition for ``ownership="rcb"`` (padded per-device grids + masked
    halo).  ``ownership=None`` keeps the checkpointed run's mode, so an
    uneven run restores uneven on a different device count without the
    caller restating the policy.  The :class:`Domain` is re-derived for
    the plan and the state is re-initialized through the same
    mass-migration path the mid-run re-shard uses — global agent ids,
    spawn-counter floors, the iteration counter, and the RNG lineage all
    carry over.

    Returns ``(engine, state, step)``; drive the state with
    ``engine.make_sharded_step(make_abm_mesh(engine.geom.mesh_shape))`` (or
    ``engine.make_local_step()`` on one device).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.domain import Domain
    from repro.core.engine import Engine
    from repro.core.load_balance import choose_partition
    from repro.core.delta import DeltaConfig

    n = n_devices if n_devices is not None else len(jax.devices())
    step_, flat, extras = ckpt_lib.restore(ckpt_dir, step=step)
    meta = extras["abm"]
    hist = np.asarray(flat["histogram"])
    if ownership is None:
        ownership = meta.get("ownership", "equal")
    global_cells = tuple(meta["global_cells"])
    boundary = meta["boundary"]   # str (legacy) or per-axis list
    geom_kw = dict(
        cell_size=meta["cell_size"],
        cap=meta["cap"],
        boundary=boundary if isinstance(boundary, str) else tuple(boundary),
        box_factor=meta["box_factor"],
    )
    if ownership == "rcb":
        plan = choose_partition(hist, n, ownership="rcb")
        part = plan.partition.scale(meta["box_factor"])
        geom = Domain(
            interior=part.max_widths, mesh_shape=part.mesh_shape,
            partition=part, **geom_kw)
    else:
        mesh_shape = choose_partition(hist, n,
                                      ownership="equal").mesh_shape
        geom = Domain(
            interior=tuple(g // m for g, m in zip(global_cells,
                                                  mesh_shape)),
            mesh_shape=mesh_shape, **geom_kw)
    if delta_cfg is None:
        # Re-apply the checkpointed run's aura codec: once the quantized
        # closed loop is on, it is part of the dynamics, so a bit-exact
        # recovery replay must restore with the same config.  Legacy
        # checkpoints (no "delta" key) restore with the codec off.
        dmeta = meta.get("delta")
        if dmeta is not None:
            delta_cfg = DeltaConfig(
                enabled=bool(dmeta["enabled"]),
                qdtype=getattr(jnp, dmeta["qdtype"]),
                refresh_interval=int(dmeta["refresh_interval"]),
                scale=dmeta["scale"],
            )
    engine = Engine(
        geom=geom, behavior=behavior,
        delta_cfg=delta_cfg or DeltaConfig(enabled=False),
        dt=meta["dt"] if dt is None else dt,
        rebalance_every=rebalance_every,
        imbalance_threshold=imbalance_threshold,
    )
    attrs = {k.split("/", 1)[1]: v for k, v in flat.items()
             if k.startswith("attrs/")}
    state = engine.init_state(
        flat["positions"], attrs,
        gid_counters=flat["gid_counters"],
        it0=meta["it"],
        base_key=flat["base_key"],
    )
    if meta["dropped_total"]:
        state.dropped = state.dropped.at[(0,) * geom.ndim].add(
            jnp.int32(meta["dropped_total"]))
    return engine, state, step_


def elastic_restore(ckpt_dir: str, model, *, n_devices: Optional[int] = None,
                    rules: Optional[Rules] = None, step: Optional[int] = None):
    """Restore (params, opt_state-free) training state onto the current
    device population.  Returns (step, params, mesh)."""
    from repro.launch.specs import params_specs

    n = n_devices if n_devices is not None else len(jax.devices())
    shape, axes = choose_lm_mesh(n)
    mesh = make_mesh(shape, axes)
    abstract = params_specs(model, mesh, rules)
    shardings = jax.tree_util.tree_map(
        lambda a: a.sharding, abstract,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    step, params, extras = ckpt_lib.restore(
        ckpt_dir, step=step, like=abstract, shardings=shardings)
    return step, params, mesh, extras
