"""Elastic scaling and straggler mitigation.

Node failure / resize protocol (DESIGN.md §5):
  1. AsyncCheckpointer keeps the newest K checkpoints on durable storage.
  2. On failure, the launcher restarts with whatever device count survives;
     ``elastic_restore`` rebuilds the mesh (largest (data, model)
     factorization that divides the parameter shapes), re-derives all
     NamedShardings against the new mesh, and places the checkpoint.
  3. The deterministic data pipeline (batch = f(seed, step)) resumes from
     the checkpointed step with zero data-loader state — this is also the
     straggler story: any peer can recompute any shard's batch, so a slow
     host can be dropped at a step boundary without coordination.

For the ABM engine, re-partitioning uses the load-balance planners
(core.load_balance) to pick the new spatial mesh from the occupancy
histogram before re-initializing device state.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax

from repro.distributed import checkpoint as ckpt_lib
from repro.distributed.sharding import Rules
from repro.launch.mesh import make_mesh


def choose_lm_mesh(n_devices: int, model_parallel: int = 16
                   ) -> Tuple[Tuple[int, int], Tuple[str, str]]:
    """Largest (data, model) factorization for a (possibly degraded) device
    count: keep model parallelism at ``model_parallel`` if it divides, else
    fall back to the largest power-of-two divisor."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    return (n_devices // mp, mp), ("data", "model")


def elastic_restore(ckpt_dir: str, model, *, n_devices: Optional[int] = None,
                    rules: Optional[Rules] = None, step: Optional[int] = None):
    """Restore (params, opt_state-free) training state onto the current
    device population.  Returns (step, params, mesh)."""
    from repro.launch.specs import params_specs

    n = n_devices if n_devices is not None else len(jax.devices())
    shape, axes = choose_lm_mesh(n)
    mesh = make_mesh(shape, axes)
    abstract = params_specs(model, mesh, rules)
    shardings = jax.tree_util.tree_map(
        lambda a: a.sharding, abstract,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    step, params, extras = ckpt_lib.restore(
        ckpt_dir, step=step, like=abstract, shardings=shardings)
    return step, params, mesh, extras
