"""Bounded, instrumented compile caches — the memory contract of a
long-lived process.

Every compiled artifact this repo memoizes (engine step/segment
executables, sims behavior objects, ensemble runners) goes through a
:class:`CompiledCache`: an LRU-bounded mapping with hit / miss / eviction
counters registered in a process-wide registry.  A serving process that
lives for days must not leak executables — ``functools.lru_cache`` bounds
them but hides the churn; these caches expose it, so the scenario server
can report cache behavior per family (docs/serving.md) and a bench row can
pin the hit rate.

Two entry points:

* :func:`memoize` — drop-in decorator replacing ``functools.lru_cache``
  for the engine/sims factories (same hashable-args keying, plus
  ``cache_clear``/``__wrapped__`` for compatibility).
* ``CompiledCache.get_or_build(key, builder)`` — explicit keying for
  callers that compute their own family fingerprint (core.ensemble).

``cache_stats()`` snapshots every registered cache; ``reset_stats()``
zeroes the counters without dropping entries (benchmarks isolate phases
with it).
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

_REGISTRY: "OrderedDict[str, CompiledCache]" = OrderedDict()
_REGISTRY_LOCK = threading.Lock()


@dataclasses.dataclass
class CacheStats:
    """Counter snapshot of one cache (cumulative since the last reset)."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0
    maxsize: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": self.size,
                "maxsize": self.maxsize,
                "hit_rate": round(self.hit_rate, 4)}


class CompiledCache:
    """LRU-bounded cache with instrumentation, safe under concurrent
    access (the scenario server builds runners from worker threads).

    The builder runs *outside* the lock — compiling an executable can take
    seconds and must not serialize unrelated lookups.  Two threads racing
    on the same missing key may both build; the first insertion wins and
    the loser's artifact is dropped (JAX compilation is pure, so this is
    only wasted work, never wrong results).
    """

    def __init__(self, name: str, maxsize: int = 64):
        if maxsize < 1:
            raise ValueError(f"CompiledCache maxsize must be >= 1, "
                             f"got {maxsize}")
        self.name = name
        self.maxsize = int(maxsize)
        self._data: "OrderedDict[Any, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        with _REGISTRY_LOCK:
            _REGISTRY[name] = self

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._data

    def get_or_build(self, key, builder: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._data:
                self._hits += 1
                self._data.move_to_end(key)
                return self._data[key]
            self._misses += 1
        value = builder()
        with self._lock:
            if key in self._data:          # lost a build race: keep winner
                self._data.move_to_end(key)
                return self._data[key]
            self._data[key] = value
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self._evictions += 1
        return value

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(hits=self._hits, misses=self._misses,
                              evictions=self._evictions,
                              size=len(self._data), maxsize=self.maxsize)

    def reset_stats(self) -> None:
        with self._lock:
            self._hits = self._misses = self._evictions = 0


def memoize(name: str, maxsize: int = 64) -> Callable:
    """``functools.lru_cache`` replacement backed by a registered
    :class:`CompiledCache` (positional-args keying; kwargs are folded in
    as a sorted items tuple, so equivalent calls share an entry)."""

    def deco(fn: Callable) -> Callable:
        cache = CompiledCache(name, maxsize=maxsize)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            key = args if not kwargs \
                else args + (("__kw__",) + tuple(sorted(kwargs.items())),)
            return cache.get_or_build(key, lambda: fn(*args, **kwargs))

        wrapper.cache = cache
        wrapper.cache_clear = cache.clear
        wrapper.__wrapped__ = fn
        return wrapper

    return deco


def get_cache(name: str) -> Optional[CompiledCache]:
    with _REGISTRY_LOCK:
        return _REGISTRY.get(name)


def cache_stats(prefix: str = "") -> Dict[str, Dict[str, Any]]:
    """Snapshot of every registered cache (optionally name-filtered) —
    the figure the scenario server's ``stats()`` endpoint reports."""
    with _REGISTRY_LOCK:
        caches: Tuple[Tuple[str, CompiledCache], ...] = tuple(
            _REGISTRY.items())
    return {n: c.stats().as_dict() for n, c in caches
            if n.startswith(prefix)}


def reset_stats(prefix: str = "") -> None:
    with _REGISTRY_LOCK:
        caches = tuple(_REGISTRY.values())
    for c in caches:
        if c.name.startswith(prefix):
            c.reset_stats()
