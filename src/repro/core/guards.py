"""Runtime health guards: cheap invariants fused into the compiled step.

The static ``simcheck`` contracts (analysis.contracts) prove a
configuration *can* run correctly; these guards watch that it actually
*is* — a silent NaN from a diverging interaction, a halo slab corrupted on
the wire, or an agent teleported past the one-hop migration envelope all
invalidate every step that follows, and at production scale (the paper's
half-trillion-agent runs) such faults are routine, not exceptional.

Each guard is a pure reduction over the per-device state, computed inside
``Engine.local_step`` and accumulated into the ``SimState.health`` word
(one cumulative int32 counter per guard, mirroring the ``codec_overflow``
word).  Like every other carry they cost nothing at the host boundary:
drivers read the counters only at segment boundaries (the existing host
control points) and compare against a mark — see :func:`check_health`.

Guard catalogue (indices into the health word):

* ``nan_inf`` — any non-finite value in a float attribute (positions
  included) of a live agent, checked right after the aura exchange so a
  corrupted halo receive is caught before the interaction sweep consumes
  it.
* ``out_of_domain`` — a live *owned* agent whose position lies outside the
  global domain ``[0, L)`` on any axis (aura copies are excluded: they
  legitimately mirror remote agents).
* ``out_of_slab`` — a live owned agent whose position does not fall inside
  this device's owned slab, checked at step entry (after the previous
  step's migration settled): residency is the invariant one-pass binning
  relies on.
* ``conservation`` — global agent-count balance across one full step:
  live agents before re-binning (spawns included) must equal owned agents
  after migration plus the capacity drops the step reported.  A one-hop
  violation (an agent skipping a whole slab) or a lost migration slab
  shows up here.
* ``gid_duplicate`` — two live owned agents carrying the same
  ``(gid_rank, gid_count)`` identity: spawn-counter reuse or a duplicated
  halo slab.  Unlike the others this one is checked **host-side** inside
  :func:`check_health` (a numpy lexsort at control points): an XLA sort
  per step costs more than every other guard combined, and a duplicated
  identity cannot self-heal, so control-point granularity detects every
  violation the per-step sort would.

Severity policy (:class:`GuardConfig.policy`): ``"off"`` compiles the
guards out entirely (the default — zero cost, identical jaxprs),
``"warn"`` surfaces trips as warnings, ``"error"`` raises
:class:`HealthError` at the host control point — the trigger the
supervisor (launch.supervise) rolls back on.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent_soa import AgentSoA, GID_COUNT, GID_RANK, POS

Array = jax.Array

GUARD_NAN = 0
GUARD_DOMAIN = 1
GUARD_SLAB = 2
GUARD_CONSERVATION = 3
GUARD_GID_DUP = 4
NUM_GUARDS = 5

GUARD_NAMES: Tuple[str, ...] = (
    "nan_inf", "out_of_domain", "out_of_slab", "conservation",
    "gid_duplicate",
)

_POLICIES = ("off", "warn", "error")


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Which invariants to fuse into the step, and what a trip does.

    Hashable and frozen so it can ride on the (cached, hashable)
    :class:`repro.core.Engine`.  With ``policy="off"`` the engine traces
    byte-identical jaxprs to a guard-free build — the flags only matter
    when the policy enables the guards.
    """

    policy: str = "off"
    nan: bool = True
    domain: bool = True
    slab: bool = True
    conservation: bool = True
    gid_unique: bool = True

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(
                f"guard policy {self.policy!r} not in {_POLICIES}")

    @property
    def enabled(self) -> bool:
        return self.policy != "off"


def as_guard_config(guards) -> GuardConfig:
    """Normalize the facade shorthand: None -> off, str -> policy."""
    if guards is None:
        return GuardConfig()
    if isinstance(guards, str):
        return GuardConfig(policy=guards)
    if isinstance(guards, GuardConfig):
        return guards
    raise TypeError(
        f"guards must be a GuardConfig, a policy string or None, "
        f"got {type(guards).__name__}")


# ---------------------------------------------------------------------------
# Traced reductions (called from Engine.local_step, per device)
# ---------------------------------------------------------------------------

def nan_count(soa: AgentSoA) -> Array:
    """Live slots carrying a non-finite value in any float attribute."""
    total = jnp.int32(0)
    v = soa.valid
    for arr in soa.attrs.values():
        if not jnp.issubdtype(arr.dtype, jnp.inexact):
            continue
        bad = ~jnp.isfinite(arr)
        if bad.ndim > v.ndim:
            bad = jnp.any(bad.reshape(v.shape + (-1,)), axis=-1)
        total = total + jnp.sum(bad & v, dtype=jnp.int32)
    return total


def residency_counts(geom, soa: AgentSoA, origin: Array,
                     own_cells: Array) -> Tuple[Array, Array]:
    """(out_of_domain, out_of_slab) counts over live owned agents.

    ``own_cells`` is the (local_shape) bool mask of this device's owned
    interior cells; the slab test recomputes the same relative coordinate
    ``(pos - origin) / cell_size`` the binning uses, so it is exact
    against :func:`repro.core.grid.cell_of` — an owned agent is in-slab
    iff that coordinate lies in ``[0, w)`` per axis.  NaN positions fail
    both comparisons and are counted (they are also caught by the NaN
    guard; double-reporting is intentional: each counter answers its own
    question).
    """
    v = soa.valid & own_cells[..., None]
    pos = soa.attrs[POS]
    nd = geom.ndim
    lsz = jnp.asarray(geom.domain_size, jnp.float32)
    in_dom = jnp.all((pos >= 0.0) & (pos < lsz), axis=-1)
    dom_bad = jnp.sum(v & ~in_dom, dtype=jnp.int32)

    # owned widths in cells per axis, derived from the mask itself (its
    # True run along each axis is exactly [1, w])
    rel = (pos - origin) / jnp.float32(geom.cell_size)
    in_slab = jnp.ones(pos.shape[:-1], jnp.bool_)
    for a in range(nd):
        red = tuple(c for c in range(nd) if c != a)
        w = jnp.sum(jnp.any(own_cells, axis=red), dtype=jnp.int32)
        in_slab = in_slab & (rel[..., a] >= 0.0) \
                          & (rel[..., a] < w.astype(jnp.float32))
    slab_bad = jnp.sum(v & ~in_slab, dtype=jnp.int32)
    return dom_bad, slab_bad


def gid_duplicate_count(state) -> int:
    """Pairs of live slots sharing a (gid_rank, gid_count) identity,
    over the whole mesh — **host-side**, called from :func:`check_health`
    at the drivers' control points rather than traced into the step: an
    XLA sort per step costs more than every other guard combined, and a
    duplicated identity cannot self-heal, so control-point granularity
    detects every violation the per-step sort would."""
    v = np.asarray(state.soa.valid).reshape(-1)
    r = np.asarray(state.soa.attrs[GID_RANK]).reshape(-1)[v]
    c = np.asarray(state.soa.attrs[GID_COUNT]).reshape(-1)[v]
    order = np.lexsort((c, r))
    rs, cs = r[order], c[order]
    return int(np.sum((rs[1:] == rs[:-1]) & (cs[1:] == cs[:-1])))


# ---------------------------------------------------------------------------
# Host-side surfacing (drivers, at segment boundaries)
# ---------------------------------------------------------------------------

def health_counts(state) -> np.ndarray:
    """Cumulative per-guard counts, reduced over the device mesh.

    Per-device guards sum across devices; the conservation guard is
    already a global (psum'd) quantity replicated on every device, so its
    reduction is the max.
    """
    h = np.asarray(state.health).reshape(-1, NUM_GUARDS)
    out = h.sum(axis=0, dtype=np.int64)
    out[GUARD_CONSERVATION] = h[:, GUARD_CONSERVATION].max(initial=0)
    return out


@dataclasses.dataclass
class HealthReport:
    """One host-side health reading: cumulative counts plus the delta
    since the previous mark (what tripped *now*)."""

    counts: np.ndarray       # (NUM_GUARDS,) cumulative
    new: np.ndarray          # (NUM_GUARDS,) since the last mark
    iteration: int
    policy: str

    @property
    def tripped(self):
        return [(GUARD_NAMES[i], int(self.new[i]))
                for i in range(NUM_GUARDS) if self.new[i] > 0]

    @property
    def ok(self) -> bool:
        return not self.tripped

    def format(self) -> str:
        if self.ok:
            return f"health@it={self.iteration}: ok"
        parts = ", ".join(f"{n}=+{c}" for n, c in self.tripped)
        return (f"health@it={self.iteration}: guard trip ({parts}; "
                f"cumulative {dict(zip(GUARD_NAMES, self.counts.tolist()))})")


class HealthError(RuntimeError):
    """A runtime health guard tripped under ``policy="error"``.

    Carries the :class:`HealthReport`; the supervisor catches this and
    rolls back to the last verified checkpoint.
    """

    def __init__(self, report: HealthReport):
        self.report = report
        super().__init__(report.format())


def check_health(guards: GuardConfig, state, mark: np.ndarray,
                 iteration: Optional[int] = None
                 ) -> Tuple[np.ndarray, Optional[HealthReport]]:
    """Read the health word against ``mark``; warn or raise per policy.

    Returns ``(new_mark, report)`` — report is None when nothing tripped.
    A count *below* the mark means the counters were reset (re-shard or
    restore re-initialized the state); the mark follows down without
    reporting.
    """
    counts = health_counts(state)
    new = np.where(counts >= mark, counts - mark, counts)
    mark = counts.copy()
    if guards.gid_unique:
        # host-side check of the *current* state (see gid_duplicate_count):
        # a persisting duplicate re-reports at every control point
        dups = gid_duplicate_count(state)
        new[GUARD_GID_DUP] += dups
        counts[GUARD_GID_DUP] += dups
    if not new.any():
        return mark, None
    it = iteration if iteration is not None \
        else int(np.max(np.asarray(state.it)))
    report = HealthReport(counts=counts, new=new, iteration=it,
                          policy=guards.policy)
    if guards.policy == "error":
        raise HealthError(report)
    warnings.warn(f"runtime guard: {report.format()}", stacklevel=3)
    return mark, report
