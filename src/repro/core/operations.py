"""Scheduled standalone operations — the facade's analogue of BioDynaMo's
``Scheduler``/``Operation`` list and of the paper's two-line
``SumOverAllRanks`` reduction (§3.4).

An operation is a callable ``op(sim) -> value | None`` registered on a
:class:`repro.core.simulation.Simulation` with ``sim.every(n, op)``; non-None
return values are appended to ``sim.series[name]``.  The reducers here are
built on global reductions over the sharded state (``jnp.sum`` over a
mesh-sharded array lowers to the per-device partial sum plus the cross-rank
all-reduce — exactly the engine's ``Comm.sum_over_all_ranks``), so the same
operation reads correctly on one device and on a multi-pod mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Operation:
    """One scheduled operation: ``fn(sim)`` every ``every`` iterations.

    ``pre`` operations run before the step on iterations where
    ``tick % every == 0`` (like the re-shard check); post operations run
    after the step on iterations where ``(tick + 1) % every == 0`` (so
    ``every=1`` sees every post-step state, and ``every=n`` fires after n
    completed steps).  ``record`` appends non-None results to
    ``sim.series[name]``.
    """

    fn: Callable[[Any], Any]
    every: int = 1
    name: str = ""
    pre: bool = False
    record: bool = True

    def due(self, tick: int) -> bool:
        if self.every <= 0:
            return False
        return (tick % self.every == 0) if self.pre \
            else ((tick + 1) % self.every == 0)


# ---------------------------------------------------------------------------
# Reducers (SumOverAllRanks family)
# ---------------------------------------------------------------------------

def sum_over_all_ranks(extract: Callable[[Any], Any],
                       name: str = "") -> Callable:
    """Generic global-sum reducer: ``extract(state)`` returns a (sharded)
    array whose global sum is the metric — the paper's §3.4 two-liner."""

    def op(sim):
        return float(jnp.sum(extract(sim.state)))

    op.__name__ = name or getattr(extract, "__name__", "sum")
    return op


def agent_count(sim) -> int:
    """Total live agents across all ranks."""
    return int(jnp.sum(sim.state.soa.valid))


def attr_sum(attr: str, name: str = "") -> Callable:
    """Sum of a scalar attribute over all live agents, all ranks."""

    def op(sim):
        soa = sim.state.soa
        return float(jnp.sum(jnp.where(soa.valid, soa.attrs[attr], 0)))

    op.__name__ = name or f"sum_{attr}"
    return op


def attr_mean(attr: str, name: str = "") -> Callable:
    """Mean of a scalar attribute over all live agents, all ranks."""

    def op(sim):
        soa = sim.state.soa
        n = jnp.sum(soa.valid)
        s = jnp.sum(jnp.where(soa.valid, soa.attrs[attr], 0))
        return float(s) / max(float(n), 1.0)

    op.__name__ = name or f"mean_{attr}"
    return op


def attr_counts(attr: str, values: Sequence[int],
                name: str = "") -> Callable:
    """Per-value occupation counts of an integer attribute (e.g. SIR state
    compartments) over all live agents, all ranks."""
    vals = tuple(values)

    def op(sim) -> Tuple[int, ...]:
        soa = sim.state.soa
        a = soa.attrs[attr]
        return tuple(int(jnp.sum((a == v) & soa.valid)) for v in vals)

    op.__name__ = name or f"counts_{attr}"
    return op


# ---------------------------------------------------------------------------
# Checkpoint operation
# ---------------------------------------------------------------------------

def checkpoint_op(ckpt_dir: str, keep: int = 3) -> Callable:
    """Operation wrapping ``distributed.checkpoint.save_abm``: a logical,
    mesh-independent ABM checkpoint of the facade's current engine+state,
    labeled with the live iteration counter."""

    def op(sim) -> Optional[str]:
        from repro.distributed.checkpoint import save_abm
        return save_abm(ckpt_dir, sim.iteration, sim.engine, sim.state,
                        keep=keep)

    op.__name__ = "checkpoint"
    return op


def positions_of(state) -> np.ndarray:
    """Host-side (N, ndim) positions of all live agents (diagnostics
    helper)."""
    v = np.asarray(state.soa.valid).ravel()
    pos = np.asarray(state.soa.attrs["pos"])
    return pos.reshape(-1, pos.shape[-1])[v]


# ---------------------------------------------------------------------------
# Batched (per-replica) reducers — the ensemble analogue of the family
# above.  Each takes a *stacked* SimState (every leaf carrying a leading
# (R,) replica axis, core.ensemble) and reduces each lane independently,
# returning an (R, ...) array: lane r's value is bit-identical to the solo
# reducer on replica r, untouched by its batch neighbors.
# ---------------------------------------------------------------------------

def batch_agent_count(state) -> np.ndarray:
    """Per-replica live-agent totals: (R,) int64."""
    v = state.soa.valid
    return np.asarray(jnp.sum(v.reshape(v.shape[0], -1), axis=1),
                      dtype=np.int64)


def batch_attr_sum(attr: str, name: str = "") -> Callable:
    """Per-replica sum of a scalar attribute over live agents: (R,)."""

    def reduce(state) -> np.ndarray:
        soa = state.soa
        r = soa.valid.shape[0]
        a = soa.attrs[attr].reshape(r, -1)
        v = soa.valid.reshape(r, -1)
        return np.asarray(jnp.sum(jnp.where(v, a, 0), axis=1))

    reduce.__name__ = name or f"batch_sum_{attr}"
    return reduce


def batch_attr_counts(attr: str, values: Sequence[int],
                      name: str = "") -> Callable:
    """Per-replica compartment counts of an integer attribute (e.g. the
    SIR occupation per ensemble lane): (R, len(values)) int64."""
    vals = tuple(values)

    def reduce(state) -> np.ndarray:
        soa = state.soa
        r = soa.valid.shape[0]
        a = soa.attrs[attr].reshape(r, -1)
        v = soa.valid.reshape(r, -1)
        cols = [jnp.sum((a == val) & v, axis=1) for val in vals]
        return np.asarray(jnp.stack(cols, axis=1), dtype=np.int64)

    reduce.__name__ = name or f"batch_counts_{attr}"
    return reduce
