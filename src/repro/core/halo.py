"""Aura (halo) exchange and spatial communication primitives.

The paper exchanges boundary-region agents between neighboring MPI ranks every
iteration with non-blocking point-to-point sends (§2.1, §2.4.3).  The TPU
analogue is ``jax.lax.ppermute`` along the axes of a spatial device mesh: a
neighbor-only collective that XLA schedules asynchronously and overlaps with
compute (the paper's speculative receives correspond to XLA's async
collective start/done scheduling).

Exchange is dimension-ordered: x-axis slabs first, then y-axis slabs that
include the freshly-filled x-ring cells, which propagates corner (diagonal)
neighbors in two hops — the standard halo trick, and the same reason the
paper's agent migration needs no diagonal sends.

All slabs are fixed-shape SoA slices (see agent_soa.py): the "serialization"
of a slab is the identity function.  Optional delta encoding of slabs is
provided by core.delta and threaded through here.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.agent_soa import AgentSoA
from repro.core.delta import (
    DeltaConfig,
    Slab,
    decode_delta,
    decode_full,
    encode_delta,
    encode_full,
    payload_bytes,
)
from repro.core.grid import GridGeom

Array = jax.Array

# Re-exported for the engine and tests; the shim itself lives in the
# layer-neutral repro.compat so the LM stack need not import ABM modules.
from repro.compat import shard_map_compat  # noqa: E402,F401


class Comm:
    """Spatial communication abstraction over a (sx, sy) device mesh."""

    def shift(self, tree, axis: int, direction: int):
        """Move data one step along mesh axis; devices with no source get zeros
        (closed boundary) or wrap (toroidal)."""
        raise NotImplementedError

    def coords(self) -> Tuple[Array, Array]:
        raise NotImplementedError

    def linear_rank(self) -> Array:
        raise NotImplementedError

    def sum_over_all_ranks(self, x):
        """Paper §3.4 ``SumOverAllRanks`` analogue."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ShardComm(Comm):
    """Runs inside shard_map over mesh axes ``axis_names`` of shape
    ``mesh_shape``."""

    axis_names: Tuple[str, str]
    mesh_shape: Tuple[int, int]
    toroidal: bool

    def _perm(self, size: int, direction: int):
        if direction == +1:
            perm = [(i, i + 1) for i in range(size - 1)]
            if self.toroidal:
                perm.append((size - 1, 0))
        else:
            perm = [(i + 1, i) for i in range(size - 1)]
            if self.toroidal:
                perm.append((0, size - 1))
        return perm

    def shift(self, tree, axis: int, direction: int):
        size = self.mesh_shape[axis]
        name = self.axis_names[axis]
        if size == 1:
            if self.toroidal:
                return tree
            return jax.tree_util.tree_map(jnp.zeros_like, tree)
        perm = self._perm(size, direction)
        return jax.tree_util.tree_map(
            lambda x: jax.lax.ppermute(x, name, perm), tree
        )

    def coords(self) -> Tuple[Array, Array]:
        return (
            jax.lax.axis_index(self.axis_names[0]),
            jax.lax.axis_index(self.axis_names[1]),
        )

    def linear_rank(self) -> Array:
        cx, cy = self.coords()
        return cx * self.mesh_shape[1] + cy

    def sum_over_all_ranks(self, x):
        return jax.lax.psum(jax.lax.psum(x, self.axis_names[0]),
                            self.axis_names[1])


@dataclasses.dataclass(frozen=True)
class LocalComm(Comm):
    """Single-device oracle: 1x1 mesh."""

    toroidal: bool

    def shift(self, tree, axis: int, direction: int):
        if self.toroidal:
            return tree
        return jax.tree_util.tree_map(jnp.zeros_like, tree)

    def coords(self) -> Tuple[Array, Array]:
        z = jnp.int32(0)
        return z, z

    def linear_rank(self) -> Array:
        return jnp.int32(0)

    def sum_over_all_ranks(self, x):
        return x


# ---------------------------------------------------------------------------
# Slab extraction / insertion
# ---------------------------------------------------------------------------

def take_slab(soa: AgentSoA, axis: int, index: int) -> Slab:
    """Extract one cell-row/column (incl. valid mask) as an exchange slab."""
    if axis == 0:
        slab = {name: a[index] for name, a in soa.attrs.items()}
        slab["valid"] = soa.valid[index]
    else:
        slab = {name: a[:, index] for name, a in soa.attrs.items()}
        slab["valid"] = soa.valid[:, index]
    return slab


def put_slab(soa: AgentSoA, axis: int, index: int, slab: Slab) -> AgentSoA:
    attrs = dict(soa.attrs)
    if axis == 0:
        for name in attrs:
            attrs[name] = attrs[name].at[index].set(slab[name])
        valid = soa.valid.at[index].set(slab["valid"])
    else:
        for name in attrs:
            attrs[name] = attrs[name].at[:, index].set(slab[name])
        valid = soa.valid.at[:, index].set(slab["valid"])
    return AgentSoA(attrs=attrs, valid=valid)


def clear_slab_at(soa: AgentSoA, axis: int, index: int) -> AgentSoA:
    if axis == 0:
        valid = soa.valid.at[index].set(False)
    else:
        valid = soa.valid.at[:, index].set(False)
    return soa.replace(valid=valid)


# Directed edges for delta references: (axis, direction) keyed by name.
DIRS = {"xm": (0, -1), "xp": (0, +1), "ym": (1, -1), "yp": (1, +1)}


def _codec_send(slab, ref, cfg: DeltaConfig, full: bool):
    if not cfg.enabled or full:
        return encode_full(slab)
    return encode_delta(slab, ref, cfg)


def _codec_recv(payload, ref, cfg: DeltaConfig, full: bool):
    if not cfg.enabled or full:
        return decode_full(payload)
    return decode_delta(payload, ref, cfg)


def halo_exchange(
    geom: GridGeom,
    soa: AgentSoA,
    comm: Comm,
    refs: Dict[str, Slab],
    cfg: DeltaConfig,
    full: bool,
) -> Tuple[AgentSoA, Dict[str, Slab], Array]:
    """Rebuild the aura ring from neighbor devices' boundary cells.

    Returns (soa with ring filled, updated delta references, wire bytes).

    ``refs`` carries, for each directed edge d in DIRS, ``d + "_out"`` (what I
    last sent that way, receiver-reconstructed) and ``d + "_in"`` (what I last
    received from that way).  Closed-loop invariant: my ``xp_out`` equals my
    +x neighbor's ``xm_in``.
    """
    hx, hy = geom.local_shape
    new_refs = dict(refs)
    nbytes = 0

    def _exchange(soa, axis, src_index, dst_index, direction, out_key, in_key):
        nonlocal nbytes, new_refs
        slab = take_slab(soa, axis, src_index)
        payload, ref_out = _codec_send(slab, new_refs[out_key], cfg, full)
        new_refs[out_key] = ref_out
        nbytes_local = payload_bytes(payload)
        recv = comm.shift(payload, axis, direction)
        recon, ref_in = _codec_recv(recv, new_refs[in_key], cfg, full)
        new_refs[in_key] = ref_in
        return put_slab(soa, axis, dst_index, recon), nbytes_local

    # x axis: my east boundary -> +x neighbor's west ring, and vice versa.
    soa, b = _exchange(soa, 0, hx - 2, 0, +1, "xp_out", "xm_in")
    nbytes += b
    soa, b = _exchange(soa, 0, 1, hx - 1, -1, "xm_out", "xp_in")
    nbytes += b
    # y axis, full rows including x-ring cells -> corners propagate.
    soa, b = _exchange(soa, 1, hy - 2, 0, +1, "yp_out", "ym_in")
    nbytes += b
    soa, b = _exchange(soa, 1, 1, hy - 1, -1, "ym_out", "yp_in")
    nbytes += b
    return soa, new_refs, jnp.int32(nbytes)


def init_refs(geom: GridGeom, soa: AgentSoA) -> Dict[str, Slab]:
    """Zero-valued reference slabs for all eight directed edges."""
    hx, hy = geom.local_shape
    refs: Dict[str, Slab] = {}
    for d, (axis, _) in DIRS.items():
        proto = take_slab(soa, axis, 0 if axis == 0 else 0)
        zeros = {k: jnp.zeros_like(v) for k, v in proto.items()}
        refs[d + "_out"] = dict(zeros)
        refs[d + "_in"] = dict(zeros)
    return refs
