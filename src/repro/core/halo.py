"""Aura (halo) exchange and spatial communication primitives, N-dimensional.

The paper exchanges boundary-region agents between neighboring MPI ranks every
iteration with non-blocking point-to-point sends (§2.1, §2.4.3).  The TPU
analogue is ``jax.lax.ppermute`` along the axes of a spatial device mesh: a
neighbor-only collective that XLA schedules asynchronously and overlaps with
compute (the paper's speculative receives correspond to XLA's async
collective start/done scheduling).

Exchange is dimension-ordered over the Domain's ``ndim`` axes (``2 * ndim``
directed edges): axis-0 slabs first, then axis-1 slabs that include the
freshly-filled axis-0 ring cells, and so on — which propagates corner
(diagonal) neighbors across any subset of axes in at most ``ndim`` hops —
the standard halo trick, and the same reason the paper's agent migration
needs no diagonal sends.

All slabs are fixed-shape SoA slices (see agent_soa.py): the "serialization"
of a slab is the identity function.  Optional delta encoding of slabs is
provided by core.delta and threaded through here.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.agent_soa import AgentSoA
from repro.core.delta import (
    DeltaConfig,
    Slab,
    decode_delta,
    decode_full,
    encode_delta,
    encode_full,
    payload_bytes,
)
from repro.core.domain import AXIS_CHARS, Domain
from repro.core.grid import ring_index

Array = jax.Array

# Re-exported for the engine and tests; the shim itself lives in the
# layer-neutral repro.compat so the LM stack need not import ABM modules.
from repro.compat import shard_map_compat  # noqa: E402,F401


class Comm:
    """Spatial communication abstraction over an N-D device mesh."""

    def shift(self, tree, axis: int, direction: int):
        """Move data one step along mesh axis; devices with no source get zeros
        (closed boundary) or wrap (toroidal)."""
        raise NotImplementedError

    def coords(self) -> Tuple[Array, ...]:
        raise NotImplementedError

    def linear_rank(self) -> Array:
        raise NotImplementedError

    def sum_over_all_ranks(self, x):
        """Paper §3.4 ``SumOverAllRanks`` analogue."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ShardComm(Comm):
    """Runs inside shard_map over mesh axes ``axis_names`` of shape
    ``mesh_shape``; ``toroidal`` carries the per-axis boundary flags."""

    axis_names: Tuple[str, ...]
    mesh_shape: Tuple[int, ...]
    toroidal: Tuple[bool, ...]

    def _perm(self, size: int, direction: int, toroidal: bool):
        if direction == +1:
            perm = [(i, i + 1) for i in range(size - 1)]
            if toroidal:
                perm.append((size - 1, 0))
        else:
            perm = [(i + 1, i) for i in range(size - 1)]
            if toroidal:
                perm.append((0, size - 1))
        return perm

    def shift(self, tree, axis: int, direction: int):
        size = self.mesh_shape[axis]
        name = self.axis_names[axis]
        toroidal = self.toroidal[axis]
        if size == 1:
            if toroidal:
                return tree
            return jax.tree_util.tree_map(jnp.zeros_like, tree)
        perm = self._perm(size, direction, toroidal)
        return jax.tree_util.tree_map(
            lambda x: jax.lax.ppermute(x, name, perm), tree
        )

    def coords(self) -> Tuple[Array, ...]:
        return tuple(jax.lax.axis_index(n) for n in self.axis_names)

    def linear_rank(self) -> Array:
        r = jnp.int32(0)
        for c, m in zip(self.coords(), self.mesh_shape):
            r = r * m + c
        return r

    def sum_over_all_ranks(self, x):
        for name in self.axis_names:
            x = jax.lax.psum(x, name)
        return x


@dataclasses.dataclass(frozen=True)
class LocalComm(Comm):
    """Single-device oracle: an all-ones mesh."""

    toroidal: Tuple[bool, ...]

    def shift(self, tree, axis: int, direction: int):
        if self.toroidal[axis]:
            return tree
        return jax.tree_util.tree_map(jnp.zeros_like, tree)

    def coords(self) -> Tuple[Array, ...]:
        return tuple(jnp.int32(0) for _ in self.toroidal)

    def linear_rank(self) -> Array:
        return jnp.int32(0)

    def sum_over_all_ranks(self, x):
        return x


# ---------------------------------------------------------------------------
# Slab extraction / insertion
# ---------------------------------------------------------------------------

def take_slab(soa: AgentSoA, axis: int, index: int) -> Slab:
    """Extract one cell-hyperplane (incl. valid mask) as an exchange slab."""
    idx = ring_index(axis, index)
    slab = {name: a[idx] for name, a in soa.attrs.items()}
    slab["valid"] = soa.valid[idx]
    return slab


def put_slab(soa: AgentSoA, axis: int, index: int, slab: Slab) -> AgentSoA:
    idx = ring_index(axis, index)
    attrs = dict(soa.attrs)
    for name in attrs:
        attrs[name] = attrs[name].at[idx].set(slab[name])
    valid = soa.valid.at[idx].set(slab["valid"])
    return AgentSoA(attrs=attrs, valid=valid)


def clear_slab_at(soa: AgentSoA, axis: int, index: int) -> AgentSoA:
    valid = soa.valid.at[ring_index(axis, index)].set(False)
    return soa.replace(valid=valid)


def dirs_for(ndim: int) -> Dict[str, Tuple[int, int]]:
    """Directed edges for delta references: ``2 * ndim`` (axis, direction)
    pairs keyed ``"xm"/"xp"/"ym"/"yp"[/"zm"/"zp"]``."""
    out: Dict[str, Tuple[int, int]] = {}
    for axis in range(ndim):
        c = AXIS_CHARS[axis]
        out[c + "m"] = (axis, -1)
        out[c + "p"] = (axis, +1)
    return out


# Historical 2-D constant (kept for callers that predate N-D domains).
DIRS = dirs_for(2)


def _codec_send(slab, ref, cfg: DeltaConfig, full: bool):
    if not cfg.enabled or full:
        payload, new_ref = encode_full(slab)
        return payload, new_ref, jnp.int32(0)
    return encode_delta(slab, ref, cfg)


def _codec_recv(payload, ref, cfg: DeltaConfig, full: bool):
    if not cfg.enabled or full:
        return decode_full(payload)
    return decode_delta(payload, ref, cfg)


def halo_exchange(
    geom: Domain,
    soa: AgentSoA,
    comm: Comm,
    refs: Dict[str, Slab],
    cfg: DeltaConfig,
    full: bool,
    owned=None,
) -> Tuple[AgentSoA, Dict[str, Slab], Array, Array]:
    """Rebuild the aura ring from neighbor devices' boundary cells.

    Returns (soa with ring filled, updated delta references, wire bytes,
    codec overflow count).  The overflow count is the number of elements
    this device's sends saturated at the quantization range this exchange
    (always 0 under the adaptive scale; see :func:`encode_delta`) — the
    engine accumulates it so the driver can force a full refresh for
    segments that clipped.

    ``refs`` carries, for each directed edge d in ``dirs_for(ndim)``,
    ``d + "_out"`` (what I last sent that way, receiver-reconstructed) and
    ``d + "_in"`` (what I last received from that way).  Closed-loop
    invariant: my ``xp_out`` equals my +x neighbor's ``xm_in``.

    Under uneven ownership (``owned`` = per-axis owned widths, possibly
    traced) each device sends the *true* boundary hyperplane of its uneven
    block — the last owned cell ``owned[a]`` — and receives into its own
    aura ring at ``owned[a] + 1``; the low side is uniform (first owned
    cell is always local index 1).  Slab shapes stay static and identical
    across devices (full padded hyperplanes; slots beyond a sender's
    cross-axis owned widths are simply invalid), so ``ppermute`` and the
    per-edge delta references work unchanged.  Rectilinear partitions
    guarantee neighbors along an axis share their cross-axis widths, so
    sent boundary cells land aligned with the receiver's own grid.
    """
    shape = geom.local_shape
    new_refs = dict(refs)
    nbytes = 0
    overflow = jnp.int32(0)

    def _exchange(soa, axis, src_index, dst_index, direction, out_key, in_key):
        nonlocal nbytes, new_refs, overflow
        slab = take_slab(soa, axis, src_index)
        payload, ref_out, oflow = _codec_send(
            slab, new_refs[out_key], cfg, full)
        new_refs[out_key] = ref_out
        overflow = overflow + oflow
        nbytes_local = payload_bytes(payload)
        recv = comm.shift(payload, axis, direction)
        recon, ref_in = _codec_recv(recv, new_refs[in_key], cfg, full)
        new_refs[in_key] = ref_in
        return put_slab(soa, axis, dst_index, recon), nbytes_local

    # Dimension-ordered: each axis sends full hyperplanes including the
    # ring cells already filled by earlier axes -> corners propagate.
    for axis in range(geom.ndim):
        h = shape[axis]
        c = AXIS_CHARS[axis]
        if owned is None:
            hi_src, hi_dst = h - 2, h - 1
        else:
            w = jnp.asarray(owned[axis], jnp.int32)
            hi_src, hi_dst = w, w + 1
        # my high face -> +axis neighbor's low ring, and vice versa
        soa, b = _exchange(soa, axis, hi_src, 0, +1, c + "p_out", c + "m_in")
        nbytes += b
        soa, b = _exchange(soa, axis, 1, hi_dst, -1, c + "m_out", c + "p_in")
        nbytes += b
    return soa, new_refs, jnp.int32(nbytes), overflow


def init_refs(geom: Domain, soa: AgentSoA) -> Dict[str, Slab]:
    """Zero-valued reference slabs for all ``4 * ndim`` directed-edge refs.

    The proto slab for an edge along ``axis`` is that axis's face at index
    0 — any index would do (every hyperplane along one axis has the same
    shape); what matters is that the slab is taken along the *edge's own
    axis*, so refs for different axes get the differently-shaped slabs the
    exchange will actually send (tests pin these shapes per axis).
    """
    refs: Dict[str, Slab] = {}
    for d, (axis, _) in dirs_for(geom.ndim).items():
        proto = take_slab(soa, axis, 0)
        zeros = {k: jnp.zeros_like(v) for k, v in proto.items()}
        refs[d + "_out"] = dict(zeros)
        refs[d + "_in"] = dict(zeros)
    return refs
