"""Composable agent behaviors — the user-facing modeling API.

Mirrors the paper's three-step model structure (§1): define what an agent is
(an AgentSchema), define its behaviors (a Behavior: a pair-interaction kernel
plus a pointwise update), and define the initial condition (an initializer).
The same Behavior runs unchanged on one device or on a multi-pod mesh —
the paper's "seamless transition from a laptop to a supercomputer" (§3.4).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.agent_soa import AgentSchema, POS
from repro.core.neighbors import PairFn

Array = jax.Array

# update(attrs, valid, acc, key, params, dt) ->
#   (new_attrs, alive_mask, spawn_mask, child_attrs_or_None)
UpdateFn = Callable[..., Tuple[Dict[str, Array], Array, Array,
                               Optional[Dict[str, Array]]]]


@dataclasses.dataclass(frozen=True)
class Behavior:
    """A full agent behavior: local interaction + pointwise update."""

    schema: AgentSchema
    pair_fn: PairFn                      # neighbor contribution kernel
    pair_attrs: Tuple[str, ...]          # attrs the pair kernel reads
    update_fn: UpdateFn                  # pointwise state transition
    radius: float                        # max interaction distance
    params: dict = dataclasses.field(default_factory=dict)
    can_spawn: bool = False              # statically enables the spawn path
    acc_spec: Dict[str, Tuple[Tuple[int, ...], object]] = dataclasses.field(
        default_factory=dict
    )


# ---------------------------------------------------------------------------
# Standard mechanical interactions shared by the biology-flavoured sims.
# ---------------------------------------------------------------------------

def soft_repulsion_adhesion(attrs_i, attrs_j, disp, dist2, params):
    """BioDynaMo-style mechanical force: short-range soft-sphere repulsion plus
    type-aware adhesion within the interaction radius.

    Expects attrs to carry ``diameter`` (float) and ``ctype`` (int32).
    ``params``: repulsion, adhesion, same_type_only (0/1).
    """
    eps = jnp.float32(1e-6)
    dist = jnp.sqrt(dist2 + eps)
    unit = disp / dist[..., None]
    r_sum = 0.5 * (attrs_i["diameter"] + attrs_j["diameter"])
    overlap = r_sum - dist
    rep = jnp.where(overlap > 0, params["repulsion"] * overlap, 0.0)
    same = (attrs_i["ctype"] == attrs_j["ctype"]).astype(jnp.float32)
    gate = jnp.where(
        jnp.float32(params.get("same_type_only", 1.0)) > 0, same, 1.0
    )
    adh = jnp.where(overlap <= 0, params["adhesion"] * gate, 0.0)
    force = (rep - adh)[..., None] * unit  # + pushes apart, - pulls together
    return {"force": -force}  # force ON i points from j towards i


def displacement_update(attrs, valid, acc, key, params, dt):
    """Overdamped dynamics: dx = F * dt, speed-clamped to < one NSG cell."""
    f = acc["force"]
    max_step = jnp.float32(params["max_step"])
    norm = jnp.sqrt(jnp.sum(f * f, axis=-1, keepdims=True) + 1e-12)
    step = f * jnp.minimum(max_step / norm, dt)
    new = dict(attrs)
    new[POS] = attrs[POS] + jnp.where(valid[..., None], step, 0.0)
    alive = valid
    spawn = jnp.zeros_like(valid)
    return new, alive, spawn, None
