"""Composable agent behaviors — the user-facing modeling API.

Mirrors the paper's three-step model structure (§1): define what an agent is
(an AgentSchema), define its behaviors (a Behavior: a pair-interaction kernel
plus a pointwise update), and define the initial condition (an initializer).
The same Behavior runs unchanged on one device or on a multi-pod mesh —
the paper's "seamless transition from a laptop to a supercomputer" (§3.4).

Behaviors form a composition algebra (BioDynaMo attaches a *list* of
behaviors to each agent): :func:`compose` merges several behaviors into one
— schemas are unioned, every pair kernel runs over the same neighborhood
gather (each gated to its own radius), accumulator names are namespaced per
sub-behavior, and the pointwise updates chain in order, each seeing the
previous one's attribute writes.  ``compose(b)`` of a single behavior is
bit-exact with ``b`` itself, which is the property the parity tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.agent_soa import AgentSchema, POS
from repro.core.neighbors import PairFn

Array = jax.Array

# update(attrs, valid, acc, key, params, dt) ->
#   (new_attrs, alive_mask, spawn_mask, child_attrs_or_None)
UpdateFn = Callable[..., Tuple[Dict[str, Array], Array, Array,
                               Optional[Dict[str, Array]]]]


# eq=False: a Behavior hashes/compares by identity (its pair_fn/update_fn
# closures and params dict have no structural equality), which makes the
# enclosing frozen Engine hashable — the key for the compiled step-function
# caches in core.engine.
@dataclasses.dataclass(frozen=True, eq=False)
class Behavior:
    """A full agent behavior: local interaction + pointwise update."""

    schema: AgentSchema
    pair_fn: PairFn                      # neighbor contribution kernel
    pair_attrs: Tuple[str, ...]          # attrs the pair kernel reads
    update_fn: UpdateFn                  # pointwise state transition
    radius: float                        # max interaction distance
    params: dict = dataclasses.field(default_factory=dict)
    can_spawn: bool = False              # statically enables the spawn path
    acc_spec: Dict[str, Tuple[Tuple[int, ...], object]] = dataclasses.field(
        default_factory=dict
    )
    # Declared worst-case per-step displacement (world units at dt=1) for
    # the one-hop migration contract (analysis.contracts).  When None the
    # checker infers a bound from recognized params (max_step, sigma,
    # div_offset); declare it for custom kinematics the inference can't
    # see.  Purely advisory metadata — the engine never reads it.
    max_displacement: Optional[float] = None
    # Sub-behaviors this one was composed from (empty for leaves).  The
    # contract checker and hot-path lint walk this to analyze leaf kernels
    # instead of the synthesized compose() wrappers.
    children: Tuple["Behavior", ...] = ()

    # Behavior.stack(a, b, ...) — alias of compose(); bound as a class
    # attribute after compose() is defined below (not a dataclass field).


def _merge_schemas(behaviors: Tuple[Behavior, ...]) -> AgentSchema:
    spec: Dict[str, Tuple[Tuple[int, ...], object]] = {}
    for b in behaviors:
        for name, shape, dtype in b.schema.fields:
            if name in spec and spec[name] != (shape, dtype):
                raise ValueError(
                    f"compose: attribute {name!r} declared with conflicting "
                    f"specs {spec[name]} vs {(shape, dtype)}")
            spec[name] = (shape, dtype)
    return AgentSchema.create(spec)


def _broadcast_mask(mask: Array, like: Array) -> Array:
    while mask.ndim < like.ndim:
        mask = mask[..., None]
    return mask


def compose(*behaviors: Behavior) -> Behavior:
    """Merge several behaviors into one (BioDynaMo's per-agent behavior list).

    Semantics:
      * **schema** — union of the sub-schemas (conflicting attribute specs
        are an error).
      * **pair kernels** — all run over one neighborhood gather with the
        max radius; a sub-behavior with a smaller radius has its
        contributions gated to its own ``dist2 <= radius**2`` so composition
        never widens an interaction.  Accumulators are namespaced
        ``"b{i}.{name}"`` and un-namespaced before reaching each update.
      * **updates** — chained in order; update ``i`` sees the attribute
        writes of updates ``< i`` (accumulators were all computed from the
        *pre-update* state, exactly as in a monolithic behavior).  Alive
        masks AND together; spawn masks OR together with the later
        behavior's child winning contested slots.  Behavior 0 receives the
        step key unchanged (bit-exact single-behavior parity); behavior
        ``i>0`` receives ``fold_in(key, i)``.
      * **params** — each sub-kernel closes over its own params; the merged
        ``params`` dict (namespaced the same way) is carried for
        introspection only.
    """
    behs = tuple(behaviors)
    if not behs:
        raise ValueError("compose() needs at least one Behavior")
    for b in behs:
        if not isinstance(b, Behavior):
            raise TypeError(f"compose() takes Behaviors, got {type(b)!r}")

    schema = _merge_schemas(behs)
    radius = max(float(b.radius) for b in behs)
    pair_attrs = tuple(sorted({a for b in behs for a in b.pair_attrs}))
    can_spawn = any(b.can_spawn for b in behs)
    params = {f"b{i}.{k}": v
              for i, b in enumerate(behs) for k, v in b.params.items()}
    acc_spec = {f"b{i}.{k}": v
                for i, b in enumerate(behs) for k, v in b.acc_spec.items()}

    def pair(attrs_i, attrs_j, disp, dist2, _params):
        out: Dict[str, Array] = {}
        for i, b in enumerate(behs):
            sub = b.pair_fn(attrs_i, attrs_j, disp, dist2, b.params)
            gate = None
            if float(b.radius) < radius:
                gate = dist2 <= jnp.float32(float(b.radius) ** 2)
            for k, v in sub.items():
                if gate is not None:
                    v = jnp.where(_broadcast_mask(gate, v), v,
                                  jnp.zeros_like(v))
                out[f"b{i}.{k}"] = v
        return out

    def update(attrs, valid, acc, key, _params, dt):
        cur = dict(attrs)
        alive = valid
        spawn = jnp.zeros_like(valid)
        child: Optional[Dict[str, Array]] = None
        for i, b in enumerate(behs):
            pfx = f"b{i}."
            acc_i = {k[len(pfx):]: v for k, v in acc.items()
                     if k.startswith(pfx)}
            ki = key if i == 0 else jax.random.fold_in(key, i)
            cur, alive_i, spawn_i, child_i = b.update_fn(
                cur, valid, acc_i, ki, b.params, dt)
            cur = dict(cur)
            alive = alive & alive_i
            if b.can_spawn and child_i is not None:
                # complete the child to the union schema: attributes the
                # spawning behavior doesn't know about are inherited from
                # the parent's current state (the `child = dict(new)` idiom)
                child_i = {**cur, **child_i}
                if child is None:
                    child, spawn = child_i, spawn_i
                else:
                    child = {k: jnp.where(
                        _broadcast_mask(spawn_i, child_i[k]),
                        child_i[k], child[k]) for k in child}
                    spawn = spawn | spawn_i
        return cur, alive, spawn, child

    return Behavior(
        schema=schema, pair_fn=pair, pair_attrs=pair_attrs,
        update_fn=update, radius=radius, params=params,
        can_spawn=can_spawn, acc_spec=acc_spec, children=behs)


Behavior.stack = staticmethod(compose)


# ---------------------------------------------------------------------------
# Standard mechanical interactions shared by the biology-flavoured sims.
# ---------------------------------------------------------------------------

def soft_repulsion_adhesion(attrs_i, attrs_j, disp, dist2, params):
    """BioDynaMo-style mechanical force: short-range soft-sphere repulsion plus
    type-aware adhesion within the interaction radius.

    Expects attrs to carry ``diameter`` (float) and ``ctype`` (int32).
    ``params``: repulsion, adhesion, same_type_only (0/1).
    """
    eps = jnp.float32(1e-6)
    dist = jnp.sqrt(dist2 + eps)
    unit = disp / dist[..., None]
    r_sum = 0.5 * (attrs_i["diameter"] + attrs_j["diameter"])
    overlap = r_sum - dist
    rep = jnp.where(overlap > 0, params["repulsion"] * overlap, 0.0)
    same = (attrs_i["ctype"] == attrs_j["ctype"]).astype(jnp.float32)
    gate = jnp.where(
        jnp.float32(params.get("same_type_only", 1.0)) > 0, same, 1.0
    )
    adh = jnp.where(overlap <= 0, params["adhesion"] * gate, 0.0)
    force = (rep - adh)[..., None] * unit  # + pushes apart, - pulls together
    return {"force": -force}  # force ON i points from j towards i


def displacement_update(attrs, valid, acc, key, params, dt):
    """Overdamped dynamics: dx = F * dt, speed-clamped to < one NSG cell."""
    f = acc["force"]
    max_step = jnp.float32(params["max_step"])
    norm = jnp.sqrt(jnp.sum(f * f, axis=-1, keepdims=True) + 1e-12)
    step = f * jnp.minimum(max_step / norm, dt)
    new = dict(attrs)
    new[POS] = attrs[POS] + jnp.where(valid[..., None], step, 0.0)
    alive = valid
    spawn = jnp.zeros_like(valid)
    return new, alive, spawn, None
