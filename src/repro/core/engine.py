"""The distributed simulation engine: one iteration = aura update ->
neighbor interaction -> agent update -> agent migration (paper Figure 1).

State layout: every per-device quantity carries ``ndim`` leading device-mesh
dims (the Domain's ``mesh_shape``, all-ones locally inside shard_map), and
the agent SoA is sharded over its leading cell-grid dims.  A single uniform
``PartitionSpec("sx", "sy"[, "sz"])`` therefore shards the whole state, and
the same ``local_step`` body runs unchanged on one device (LocalComm) or on
an arbitrary spatial mesh (ShardComm inside shard_map) — the paper's
seamless laptop-to-supercomputer property (§3.4).  The whole spatial stack
loops over the Domain's axes, so 2-D sheets and 3-D tissues share every
code path.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent_soa import (
    AgentSoA,
    GID_COUNT,
    GID_RANK,
    POS,
    flat_view,
)
from repro.core.behaviors import Behavior
from repro.core.compile_cache import memoize
from repro.core.delta import (
    DeltaConfig, Slab, decode_migration, encode_migration,
)
from repro.core.domain import Domain, spatial_axis_names
from repro.core.grid import (
    bin_agents,
    bin_agents_jit,
    clear_ring,
    interior_mask,
    mask_unowned,
    owned_mask,
    ring_index,
)
from repro.core.halo import (
    Comm,
    LocalComm,
    ShardComm,
    halo_exchange,
    init_refs,
    shard_map_compat,
    take_slab,
)
from repro.core.guards import (
    GUARD_CONSERVATION,
    GUARD_DOMAIN,
    GUARD_NAN,
    GUARD_SLAB,
    NUM_GUARDS,
    GuardConfig,
    check_health,
    health_counts,
    nan_count,
    residency_counts,
)
from repro.core.neighbors import sweep_accumulate, sweep_accumulate_overlapped

Array = jax.Array


def _bcast(x, mesh_shape: Tuple[int, ...]) -> Array:
    """Broadcast a per-device value to the leading device-mesh dims."""
    x = jnp.asarray(x)
    return jnp.broadcast_to(
        x.reshape((1,) * len(mesh_shape) + x.shape),
        tuple(mesh_shape) + x.shape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimState:
    soa: AgentSoA                 # (*mesh*local grid, K, ...) globally
    refs: Dict[str, Slab]         # leading mesh_shape dims
    it: Array                     # mesh_shape int32
    key: Array                    # mesh_shape + (2,) uint32
    gid_counter: Array            # mesh_shape int32
    dropped: Array                # mesh_shape int32 cumulative overflow drops
    halo_bytes: Array             # mesh_shape int32 wire bytes of last aura update
    codec_overflow: Array         # mesh_shape int32 cumulative clipped deltas
    health: Array                 # mesh_shape + (NUM_GUARDS,) int32 cumulative
                                  # guard counters (core.guards)

    def tree_flatten(self):
        ref_keys = tuple(sorted(self.refs))
        ref_children = tuple(
            tuple(self.refs[k][f] for f in sorted(self.refs[k]))
            for k in ref_keys
        )
        ref_fields = tuple(tuple(sorted(self.refs[k])) for k in ref_keys)
        children = (self.soa, ref_children, self.it, self.key,
                    self.gid_counter, self.dropped, self.halo_bytes,
                    self.codec_overflow, self.health)
        return children, (ref_keys, ref_fields)

    @classmethod
    def tree_unflatten(cls, aux, children):
        ref_keys, ref_fields = aux
        (soa, ref_children, it, key, gidc, dropped, hbytes, coflow,
         health) = children
        refs = {
            k: dict(zip(fields, vals))
            for k, fields, vals in zip(ref_keys, ref_fields, ref_children)
        }
        return cls(soa=soa, refs=refs, it=it, key=key, gid_counter=gidc,
                   dropped=dropped, halo_bytes=hbytes, codec_overflow=coflow,
                   health=health)


@dataclasses.dataclass(frozen=True)
class Engine:
    geom: Domain
    behavior: Behavior
    delta_cfg: DeltaConfig = DeltaConfig(enabled=False)
    dt: float = 1.0
    # Dynamic load balancing (paper §2.4.5, core.reshard): when
    # rebalance_every > 0, Engine.run/drive checks the occupancy imbalance
    # at that cadence and re-shards past imbalance_threshold.
    rebalance_every: int = 0
    imbalance_threshold: float = 0.5
    # Interaction-sweep backend (core.neighbors.sweep_accumulate):
    # "auto" resolves to the tiled XLA sweep on CPU/GPU and the fused
    # Pallas kernel on TPU (2-D domains; 3-D always tiles);
    # "reference" | "tiled" | "pallas" force one.
    sweep_backend: str = "auto"
    # Communication hiding (core.neighbors.sweep_accumulate_overlapped):
    # the aura exchange is issued before the interior sweep and consumed
    # only by the boundary pass, so XLA overlaps the ppermute collectives
    # with interior compute.  "auto" (default) enables it exactly where a
    # wire exists — multi-device meshes — and keeps the single-dispatch
    # monolithic sweep on LocalComm, where there is nothing to hide;
    # "on" | "off" force it.  The split is pinned bit-exact against the
    # monolithic sweep (tests/test_sweep.py), so this knob never changes
    # results, only scheduling.
    overlap: str = "auto"
    # Construction-time contract gate (analysis.contracts.enforce):
    # "off" (default — the Simulation facade owns checking, and keeping
    # internally-built engines identical preserves compiled-step cache
    # hits), "warn" (emit a warning per error-severity finding), or
    # "error" (raise ContractError).
    check: str = "off"
    # Runtime health guards (core.guards): invariants fused into the
    # compiled step and accumulated into SimState.health.  The default
    # GuardConfig(policy="off") compiles them out entirely, so unguarded
    # engines trace byte-identical jaxprs to pre-guard builds.
    guards: GuardConfig = GuardConfig()

    def __post_init__(self):
        if self.overlap not in ("auto", "on", "off"):
            raise ValueError(
                f"overlap={self.overlap!r}; expected 'auto', 'on' or 'off'")
        if self.check != "off":
            from repro.analysis.contracts import enforce
            enforce(self, mode=self.check)

    # ------------------------------------------------------------------
    # Initialization (host side, numpy-friendly)
    # ------------------------------------------------------------------
    def init_state(
        self,
        positions: np.ndarray,          # (N, ndim) global positions
        attrs: Dict[str, np.ndarray],   # user attrs, (N, ...)
        seed: int = 0,
        *,
        gid_counters: Optional[np.ndarray] = None,  # per-rank spawn floors
        it0: int = 0,                   # starting iteration counter
        base_key: Optional[np.ndarray] = None,      # (2,) uint32 RNG root
    ) -> SimState:
        """Distributed initialization (paper §2.4.4): agents are created
        directly on their authoritative device — no mass migration.

        The re-shard / elastic-restore path (core.reshard) re-enters here
        with extra carry: when ``attrs`` contains the ``gid_rank`` /
        ``gid_count`` columns they are preserved verbatim instead of being
        re-issued, and per-rank spawn counters resume past both the largest
        carried id per rank and the optional ``gid_counters`` floors (so no
        id is ever issued twice, even across mesh-shape changes).  ``it0``
        seeds the iteration counter and ``base_key`` the RNG lineage: the
        per-device keys are split from ``fold_in(base_key, it0)`` rather
        than a fresh ``PRNGKey(seed)``."""
        geom = self.geom
        nd = geom.ndim
        mesh = geom.mesh_shape
        n_ranks = geom.n_devices
        schema = self.behavior.schema

        positions = np.asarray(positions)
        if positions.ndim != 2 or positions.shape[1] != nd:
            raise ValueError(
                f"positions have shape {positions.shape}; a {nd}-D domain "
                f"needs (N, {nd})")
        gsz = geom.domain_size
        if (positions < 0).any() or any(
                (positions[:, a] >= gsz[a]).any() for a in range(nd)):
            raise ValueError(
                f"initial positions outside the domain "
                f"{'x'.join(f'[0,{g})' for g in gsz)} — out-of-domain "
                "agents would land in the halo ring and be destroyed by "
                "the first aura rebuild")
        part = geom.partition
        if part is None:
            lens = [i * geom.cell_size for i in geom.interior]
            dev = [np.clip((positions[:, a] // lens[a]).astype(np.int64),
                           0, mesh[a] - 1) for a in range(nd)]
            origins = None
            owned_w = None
        else:
            # uneven ownership: route each agent to the device whose cut
            # slab contains its global cell along every axis
            cell_idx = [np.clip(
                (positions[:, a] // geom.cell_size).astype(np.int64),
                0, geom.global_cells[a] - 1) for a in range(nd)]
            dev = [np.clip(
                np.searchsorted(np.asarray(part.cuts[a]), cell_idx[a],
                                side="right") - 1,
                0, mesh[a] - 1) for a in range(nd)]
            # per-axis world-space slab starts, float64 -> float32 exactly
            # as Domain.device_origin computes them
            origins = [
                (np.asarray(part.cuts[a][:-1], np.float64)
                 * geom.cell_size).astype(np.float32) for a in range(nd)]
            owned_w = part.widths

        bin_fn = partial(bin_agents_jit, geom)

        carried_gids = GID_RANK in attrs and GID_COUNT in attrs
        if gid_counters is not None and not carried_gids:
            raise ValueError(
                "gid_counters floors require carried gid_rank/gid_count "
                "columns in attrs — fresh ids would start at 0 and collide "
                "with the historical ids the floors protect")
        counters_next = np.zeros((n_ranks,), dtype=np.int64)
        if carried_gids:
            g_rank = np.asarray(attrs[GID_RANK], np.int64)
            g_count = np.asarray(attrs[GID_COUNT], np.int64)
            in_range = (g_rank >= 0) & (g_rank < n_ranks)
            np.maximum.at(counters_next, g_rank[in_range],
                          g_count[in_range] + 1)
        if gid_counters is not None:
            floors = np.asarray(gid_counters, np.int64).ravel()
            if floors.size:
                # Counters are exact issuance trackers (> every id ever
                # issued by that rank, dead or alive), so the global max
                # floor bounds ALL historical ids — applying it to every
                # new rank keeps ids unique even when a smaller mesh
                # dropped some ranks' floors and their witnesses died
                # before a later re-expansion.
                counters_next = np.maximum(counters_next, floors.max())

        blocks: Dict[Tuple[int, ...], AgentSoA] = {}
        counters = np.zeros(mesh, dtype=np.int32)
        for coords in np.ndindex(*mesh):
            sel = np.ones(positions.shape[0], dtype=bool)
            for a in range(nd):
                sel &= dev[a] == coords[a]
            sel = np.flatnonzero(sel)
            n = sel.size
            lin = int(np.ravel_multi_index(coords, mesh))
            flat: Dict[str, jax.Array] = {}
            for name, (shape, dtype) in schema.all_specs(nd).items():
                if name == POS:
                    a = positions[sel].astype(np.float32)
                elif name == GID_RANK and not carried_gids:
                    a = np.full((n,), lin, dtype=np.int32)
                elif name == GID_COUNT and not carried_gids:
                    a = np.arange(n, dtype=np.int32)
                else:
                    a = np.asarray(attrs[name][sel], dtype=dtype)
                flat[name] = jnp.asarray(a)
            valid = jnp.ones((n,), jnp.bool_)
            if part is None:
                origin = jnp.asarray(
                    [coords[a] * lens[a] for a in range(nd)],
                    dtype=jnp.float32)
                soa, dropped = bin_fn(flat, valid, origin)
            else:
                origin = jnp.asarray(
                    [origins[a][coords[a]] for a in range(nd)],
                    dtype=jnp.float32)
                soa, dropped = bin_fn(
                    flat, valid, origin,
                    tuple(owned_w[a][coords[a]] for a in range(nd)))
            if int(dropped) != 0:
                raise ValueError(
                    f"cell capacity overflow at init on device {coords}: "
                    f"{int(dropped)} agents dropped; raise geom.cap"
                )
            counters[coords] = max(
                counters_next[lin], 0 if carried_gids else n)
            blocks[coords] = soa

        def blockcat(getter):
            def rec(prefix: Tuple[int, ...]):
                axis = len(prefix)
                if axis == nd:
                    return getter(blocks[prefix])
                return jnp.concatenate(
                    [rec(prefix + (i,)) for i in range(mesh[axis])],
                    axis=axis)
            return rec(())

        first = blocks[(0,) * nd]
        attrs_g = {
            name: blockcat(lambda b, n=name: b.attrs[n])
            for name in first.attrs
        }
        soa_g = AgentSoA(attrs=attrs_g, valid=blockcat(lambda b: b.valid))

        refs0 = init_refs(geom, first)
        refs_g = {
            d: {f: _bcast(v, mesh) for f, v in slab.items()}
            for d, slab in refs0.items()
        }

        if base_key is not None:
            root = jax.random.fold_in(
                jnp.asarray(base_key, jnp.uint32), it0)
        else:
            root = jax.random.PRNGKey(seed)
        keys = jax.random.split(root, n_ranks)
        keys = keys.reshape(mesh + (-1,))

        return SimState(
            soa=soa_g,
            refs=refs_g,
            it=jnp.full(mesh, it0, jnp.int32),
            key=keys,
            gid_counter=jnp.asarray(counters),
            dropped=jnp.zeros(mesh, jnp.int32),
            halo_bytes=jnp.zeros(mesh, jnp.int32),
            codec_overflow=jnp.zeros(mesh, jnp.int32),
            health=jnp.zeros(mesh + (NUM_GUARDS,), jnp.int32),
        )

    # ------------------------------------------------------------------
    # One iteration (runs per device; comm abstracts the mesh)
    # ------------------------------------------------------------------
    def local_step(self, state: SimState, comm: Comm, full_halo: bool
                   ) -> SimState:
        geom = self.geom
        beh = self.behavior
        nd = geom.ndim
        shape = geom.local_shape
        k = geom.cap
        tor = geom.toroidal

        coords = comm.coords()
        origin = geom.device_origin(coords)
        # Per-axis owned slab widths under uneven ownership (None on the
        # legacy equal split): every grid/halo/migration index below
        # resolves against the owned extent, so padding cells never bin
        # agents, never contribute pairs, and never emit halo slabs.
        owned = geom.owned_widths(coords)
        lrank = comm.linear_rank()

        idx0 = (0,) * nd
        soa = state.soa
        refs = {d: {f: v[idx0] for f, v in slab.items()}
                for d, slab in state.refs.items()}
        it = state.it[idx0]
        key = state.key[idx0]
        gidc = state.gid_counter[idx0]
        dropped = state.dropped[idx0]
        coflow = state.codec_overflow[idx0]
        health = state.health[idx0]

        # 0. Runtime health guards (core.guards): residency invariants are
        # read at step entry — the previous step's migration settled, so a
        # live owned agent outside the domain or its owned slab is
        # corruption, not motion in flight.  `g` accumulates this step's
        # trips and lands in the health word at repack.
        gcfg = self.guards
        if gcfg.enabled:
            own_cells = owned_mask(geom, owned) if owned is not None \
                else jnp.asarray(interior_mask(geom))
            g = jnp.zeros((NUM_GUARDS,), jnp.int32)
            if gcfg.domain or gcfg.slab:
                dom_bad, slab_bad = residency_counts(
                    geom, soa, origin, own_cells)
                if gcfg.domain:
                    g = g.at[GUARD_DOMAIN].add(dom_bad)
                if gcfg.slab:
                    g = g.at[GUARD_SLAB].add(slab_bad)

        # 1. Aura update (rebuilt from scratch each iteration, §2.2.1).
        # The pre-exchange SoA (ring invalidated) is kept alive: under the
        # overlapped sweep it is the interior pass's input buffer, so the
        # ppermute exchange below writes into what is effectively a double
        # buffer and nothing downstream of the interior pass waits on it.
        soa_pre = clear_ring(soa) if owned is None \
            else mask_unowned(soa, geom, owned)
        soa, refs, hbytes, oflow = halo_exchange(
            geom, soa_pre, comm, refs, self.delta_cfg, full_halo, owned
        )
        coflow = coflow + oflow

        # NaN/Inf are checked right after the exchange: a corrupted halo
        # receive is caught here, before it spreads into neighbors'
        # accumulators — under the overlapped sweep that means before the
        # boundary pass (the only consumer of the received ring) reads it.
        if gcfg.enabled and gcfg.nan:
            g = g.at[GUARD_NAN].add(nan_count(soa))

        # 2. Local interaction (backend-dispatched fused sweep).  With
        # overlap enabled the interior pass depends only on soa_pre, so
        # XLA schedules the exchange concurrently with it; the boundary
        # pass then overwrites the ring-adjacent faces from the exchanged
        # SoA (bit-exact vs the monolithic sweep at every owned cell).
        use_overlap = self.overlap == "on" or (
            self.overlap == "auto" and not isinstance(comm, LocalComm))
        if use_overlap:
            acc = sweep_accumulate_overlapped(
                geom, soa_pre, soa, beh.pair_fn, beh.pair_attrs,
                beh.radius, beh.params, backend=self.sweep_backend,
                owned=owned,
            )
        else:
            acc = sweep_accumulate(
                geom, soa, beh.pair_fn, beh.pair_attrs, beh.radius,
                beh.params, backend=self.sweep_backend,
            )

        # 3. Pointwise update on interior agents.  Under uneven ownership
        # the padded interior slice still contains this device's aura ring
        # (at owned[a] + 1 <= interior[a]): those slots hold neighbor
        # copies and must not be updated as residents, so the validity is
        # masked down to the owned cells before the update runs.
        isl = tuple(slice(1, h - 1) for h in shape)
        int_attrs = {n: a[isl] for n, a in soa.attrs.items()}
        int_valid = soa.valid[isl]
        if owned is not None:
            int_valid = int_valid & owned_mask(geom, owned)[isl][..., None]
        step_key = jax.random.fold_in(jax.random.fold_in(key, it), lrank)
        new_attrs, alive, spawn, child_attrs = beh.update_fn(
            int_attrs, int_valid, acc, step_key, beh.params, self.dt
        )
        new_valid = int_valid & alive

        # Per-axis boundary condition on positions: closed axes clamp
        # (toroidal axes wrap inside the migration exchange).
        lsz = jnp.asarray(geom.domain_size, jnp.float32)
        if not all(tor):
            eps = 1e-4 * geom.cell_size
            lo = np.asarray([-np.inf if t else eps for t in tor],
                            np.float32)
            hi = np.asarray(
                [np.inf if t else L - eps
                 for t, L in zip(tor, geom.domain_size)], np.float32)
            new_attrs[POS] = jnp.clip(new_attrs[POS], lo, hi)

        # 4. Flatten interior (+children) for re-binning.
        n_int = math.prod(geom.interior) * k
        flat = {n: a.reshape((n_int,) + a.shape[nd + 1:])
                for n, a in new_attrs.items()}
        fvalid = new_valid.reshape((n_int,))

        if beh.can_spawn:
            sflat = spawn.reshape((n_int,)) & fvalid
            n_spawn = jnp.sum(sflat.astype(jnp.int32))
            child = {n: a.reshape((n_int,) + a.shape[nd + 1:])
                     for n, a in child_attrs.items()}
            order = jnp.cumsum(sflat.astype(jnp.int32)) - 1
            child[GID_RANK] = jnp.full((n_int,), lrank, jnp.int32)
            child[GID_COUNT] = gidc + order
            gidc = gidc + n_spawn
            flat = {n: jnp.concatenate([flat[n], child[n]]) for n in flat}
            fvalid = jnp.concatenate([fvalid, sflat])

        # Conservation pre-count: every live agent (spawns included) about
        # to enter re-binning + migration, summed over the whole mesh.
        if gcfg.enabled and gcfg.conservation:
            pre_n = comm.sum_over_all_ranks(
                jnp.sum(fvalid, dtype=jnp.int32))

        soa2, d1 = bin_agents(geom, flat, fvalid, origin, owned)
        dropped = dropped + d1

        # 5. Agent migration: dimension-ordered ring exchange over all axes.
        soa3, d2, moflow = self._migrate(soa2, comm, origin, lsz, owned)
        dropped = dropped + d2
        coflow = coflow + moflow

        # Post-migration guard: the global ledger must balance up to the
        # capacity drops this step reported.  (GID uniqueness is checked
        # host-side in check_health — an XLA sort per step costs more
        # than every other guard combined, and duplicates cannot
        # self-heal, so control-point granularity loses nothing.)
        if gcfg.enabled and gcfg.conservation:
            live_owned = soa3.valid & own_cells[..., None]
            post_n = comm.sum_over_all_ranks(
                jnp.sum(live_owned, dtype=jnp.int32))
            lost = comm.sum_over_all_ranks(
                (d1 + d2).astype(jnp.int32))
            g = g.at[GUARD_CONSERVATION].add(
                jnp.abs(pre_n - post_n - lost))

        # 6. Repack per-device state.
        mesh = tuple(state.it.shape)
        new_refs = {
            d: {f: _bcast(v, mesh) for f, v in slab.items()}
            for d, slab in refs.items()
        }
        return SimState(
            soa=soa3,
            refs=new_refs,
            it=_bcast(it + 1, mesh),
            key=state.key,
            gid_counter=_bcast(gidc, mesh),
            dropped=_bcast(dropped, mesh),
            halo_bytes=_bcast(hbytes, mesh),
            codec_overflow=_bcast(coflow, mesh),
            health=_bcast(health + g if gcfg.enabled else health, mesh),
        )

    def _migrate(self, soa: AgentSoA, comm: Comm, origin: Array,
                 lsz: Array, owned=None
                 ) -> Tuple[AgentSoA, Array, Array]:
        """Dimension-ordered emigrant routing with one-pass re-binning.

        Axis-0 faces (incl. corner cells) are exchanged first.  Diagonal
        migrants arrive in the *later-axis ring cells* of the received
        slabs (their binning along every unshifted axis used the sender's
        — identical — origin), so instead of re-binning to rediscover
        them, each later axis's payload widens with the ring cells of
        every previously received slab, carrying corners forward directly:
        a received slab sits at a known coordinate (1 or h-2) along the
        axis it arrived on, and its forwarded cells are embedded at that
        coordinate in extra slot blocks of the next payload.  Everything —
        the face-cleared grid and all ``2 * ndim`` receives (forwarded
        rings invalidated) — then re-bins in a single argsort pass,
        cutting the sort-based binning passes per step from ``1 + ndim``
        (step re-bin + one per axis) to 2 (step re-bin + this one), in
        any dimensionality.

        Under uneven ownership (``owned`` set) the migration ring along
        axis ``a`` sits at the owned extent ``owned[a] + 1`` instead of the
        padded edge ``h - 1`` — both the emigrant faces taken here and the
        forwarded ring cells of pending slabs use that dynamic index
        (rectilinear cuts make it the same on every device of an axis row).
        The embedding coordinate of a forwarded block inside a widened
        payload is only a placement slot (everything re-bins by *position*
        in the final pass), so it stays at the static legacy coordinate.

        With ``delta_cfg.migration`` set (and the codec enabled) emigrant
        positions cross the wire as narrow fixed-point offsets from the
        sender's box center (delta.encode_migration) instead of raw f32 —
        returns the clip-overflow count as a third value so the driver
        can observe a violated ≤1 cell/step contract.
        """
        geom = self.geom
        nd = geom.ndim
        shape = geom.local_shape
        tor = geom.toroidal
        cfg = self.delta_cfg
        mig_q = cfg.migration if cfg.enabled else None
        moflow = jnp.int32(0)
        if mig_q is not None:
            # Static quantization frame: box center at origin + half the
            # padded extent, range covering that extent plus two cells of
            # ring/rounding slack on each side.
            half_ext = np.asarray(
                [(s - 2) * geom.cell_size / 2.0 for s in shape], np.float32)
            half_rng = half_ext + 2.0 * np.float32(geom.cell_size)
            center = origin.astype(jnp.float32) + half_ext

        def wrap_pos(slab: Slab) -> Slab:
            if not any(tor):
                return slab
            out = dict(slab)
            p = slab[POS]
            wrapped = jnp.mod(p, lsz)
            out[POS] = wrapped if all(tor) else jnp.where(
                jnp.asarray(tor), wrapped, p)
            return out

        def ship(slab: Slab, axis: int, dirn: int):
            """One ring hop of a widened face, through the position codec
            when configured (the codec's min-image offset + receiver-side
            mod subsumes wrap_pos)."""
            if mig_q is None:
                return comm.shift(wrap_pos(slab), axis, dirn), jnp.int32(0)
            enc, oflow = encode_migration(
                slab, POS, center, half_rng, cfg, lsz=lsz, toroidal=tor)
            return decode_migration(
                comm.shift(enc, axis, dirn), POS, half_rng, cfg,
                lsz=lsz, toroidal=tor), oflow

        def fl(slab: Slab):
            slab = dict(slab)
            v = slab.pop("valid")
            return ({n: a.reshape((-1,) + a.shape[v.ndim:])
                     for n, a in slab.items()},
                    v.reshape((-1,)))

        # Received slabs still carrying cells that need later-axis hops:
        # (slab, axis it arrived along, its fixed cell index on that axis).
        pending = []
        for a in range(nd):
            h = shape[a]
            # migration ring index along axis a: the padded edge on the
            # equal split, the owned extent + 1 under uneven ownership
            hi_idx = h - 1 if owned is None \
                else jnp.asarray(owned[a], jnp.int32) + 1
            grid_axes = [c for c in range(nd) if c != a]
            face_grid = tuple(shape[c] for c in grid_axes)

            out_m = take_slab(soa, a, 0)
            out_p = take_slab(soa, a, hi_idx)

            # Forward the axis-a ring cells of every pending slab inside
            # widened payloads, and invalidate them at their source.
            blocks_m, blocks_p, fwd = [], [], []
            for slab, b, fb in pending:
                p_axes = [c for c in range(nd) if c != b]
                ap = p_axes.index(a)
                lo = {n: v[ring_index(ap, 0)] for n, v in slab.items()}
                hi = {n: v[ring_index(ap, hi_idx)] for n, v in slab.items()}
                nv = slab["valid"].at[ring_index(ap, 0)].set(False) \
                                  .at[ring_index(ap, hi_idx)].set(False)
                fwd.append(({**slab, "valid": nv}, b, fb))
                bpos = grid_axes.index(b)
                blocks_m.append((lo, bpos, fb))
                blocks_p.append((hi, bpos, fb))
            pending = fwd

            def widen(face: Slab, blocks) -> Slab:
                if not blocks:
                    return face
                g = len(face_grid)
                out = {}
                for n, base in face.items():
                    trailing = base.shape[g + 1:]
                    parts = [base]
                    for blk, bpos, fb in blocks:
                        v = blk[n]
                        z = jnp.zeros(
                            face_grid + (v.shape[g - 1],) + trailing,
                            base.dtype)
                        parts.append(z.at[ring_index(bpos, fb)].set(v))
                    out[n] = jnp.concatenate(parts, axis=g)
                return out

            recv_p, of_p = ship(widen(out_p, blocks_p), a, +1)
            recv_m, of_m = ship(widen(out_m, blocks_m), a, -1)
            moflow = moflow + of_p + of_m

            v = soa.valid.at[ring_index(a, 0)].set(False) \
                         .at[ring_index(a, hi_idx)].set(False)
            soa = soa.replace(valid=v)
            # recv_p came from the -a neighbor -> sits at my a-cell 1;
            # recv_m from the +a neighbor -> my a-cell h-2.
            pending = pending + [(recv_p, a, 1), (recv_m, a, h - 2)]

        base_attrs, base_valid = flat_view(soa)
        parts = [fl(slab) for slab, _, _ in pending]
        cat = {n: jnp.concatenate([base_attrs[n]] + [p[0][n] for p in parts])
               for n in base_attrs}
        catv = jnp.concatenate([base_valid] + [p[1] for p in parts])
        binned, d = bin_agents(geom, cat, catv, origin, owned)
        return binned, d, moflow

    # ------------------------------------------------------------------
    # Compiled step factories
    # ------------------------------------------------------------------
    # All factories are memoized at module level on the engine value
    # (Engine is a hashable frozen dataclass; behaviors compare by
    # identity), so rebuilding an equivalent engine — a fresh Simulation
    # facade, a benchmark rerun — reuses the already-compiled executables
    # instead of re-tracing.

    def make_local_step(self):
        return _cached_local_step(self)

    def make_sharded_step(self, mesh,
                          axis_names: Optional[Tuple[str, ...]] = None):
        if axis_names is None:
            axis_names = spatial_axis_names(self.geom.ndim)
        return _cached_sharded_step(self, mesh, tuple(axis_names))

    def make_segment_runner(self, mesh=None,
                            axis_names: Optional[Tuple[str, ...]] = None):
        """Scan-fused driver: ``seg(state, n_steps, full_first=True)`` runs
        ``n_steps`` iterations in ONE compiled dispatch (a ``fori_loop``
        over the step body), eliminating the per-step Python/dispatch floor.

        ``full_first`` selects a full aura refresh for the segment's first
        step; the remaining steps use the delta path (callers align
        segments with the refresh schedule so no interior step needs a
        full refresh).  With delta encoding disabled every step is full
        and ``full_first`` is ignored.  ``n_steps`` is a *dynamic* loop
        bound — one executable covers every segment length.
        """
        if axis_names is None:
            axis_names = spatial_axis_names(self.geom.ndim)
        return _cached_segment_runner(self, mesh, tuple(axis_names))

    def _segment_body(self, comm, full_first: bool):
        """Per-device segment: first step optionally full, rest delta."""
        delta_on = self.delta_cfg.enabled

        def seg(state: SimState, n_steps: Array) -> SimState:
            if not delta_on:
                return jax.lax.fori_loop(
                    0, n_steps,
                    lambda i, s: self.local_step(s, comm, True), state)
            rest = n_steps
            if full_first:
                state = self.local_step(state, comm, True)
                rest = n_steps - 1
            return jax.lax.fori_loop(
                0, rest, lambda i, s: self.local_step(s, comm, False), state)

        return seg

    def drive(self, state: SimState, n_steps: int, step_fn=None,
              rebalancer=None, collect=None, mesh=None, fault_plan=None):
        """Low-level driver: delta refresh schedule + dynamic load balancing.

        Prefer :class:`repro.core.simulation.Simulation` — the facade owns
        this loop and keeps ``sim.engine``/``sim.state`` consistent across
        re-shards, so callers never juggle the returned engine themselves.

        Default path (no ``step_fn``, no ``collect``): steps run through
        the scan-fused segment runner, one compiled dispatch per
        refresh-interval/rebalance-cadence segment.  Passing an explicit
        ``step_fn`` or a per-step ``collect`` falls back to one dispatch
        per step (both need host control between steps).  ``mesh`` selects
        the sharded segment runner for multi-device geometries.

        At the rebalancer's cadence the occupancy imbalance is checked and,
        past the threshold, the state is mass-migrated onto a better mesh
        (core.reshard); the step/segment function is rebuilt for the new
        geometry and the next aura exchange is forced to a full refresh
        (the re-shard zeroed the delta references).  Returns
        ``(engine, state, series)`` — the engine differs from ``self``
        after a re-shard.
        """
        eng = self
        if rebalancer is None and self.rebalance_every > 0:
            from repro.core.reshard import Rebalancer
            rebalancer = Rebalancer(every=self.rebalance_every,
                                    threshold=self.imbalance_threshold)
        r = max(int(self.delta_cfg.refresh_interval), 1)
        force_full = False
        # Fixed-scale delta codec can clip (adaptive scale never does):
        # watch the accumulated overflow counter at every host control
        # point and force a full refresh whenever any device clipped, so
        # a saturated delta corrupts at most one segment of auras.
        track_clip = (self.delta_cfg.enabled
                      and self.delta_cfg.scale is not None)
        clip_mark = codec_overflow_count(state) if track_clip else 0
        # Runtime health guards read at the same control points; the mark
        # pattern mirrors the clip tracker (check_health handles counter
        # resets from re-shards).  fault_plan (distributed.chaos) keys its
        # faults on the absolute engine iteration, so segment boundaries
        # must land on pending fault steps.
        track_health = self.guards.enabled
        hmark = health_counts(state) if track_health else None
        it0 = int(jnp.max(state.it)) if fault_plan is not None else 0

        if step_fn is None and mesh is None:
            # No step function and no explicit mesh: derive the mesh from
            # the geometry so a multi-device engine never silently runs
            # through LocalComm (zero-filled halo shifts).
            mesh = _mesh_for(eng)

        if step_fn is None and collect is None:
            # Scan-fused path: segment boundaries at refresh-interval and
            # rebalance-cadence ticks (the only host-side control points).
            seg_fn = eng.make_segment_runner(mesh)
            i = 0
            while i < n_steps:
                if rebalancer is not None and rebalancer.due(i):
                    eng, state, resharded = rebalancer.maybe_reshard(
                        eng, state)
                    if resharded:
                        mesh = _mesh_for(eng)
                        seg_fn = eng.make_segment_runner(mesh)
                        force_full = True
                if fault_plan is not None:
                    state, fired = fault_plan.fire(eng, state, it0 + i)
                    if fired:
                        force_full = True
                nxt = n_steps
                if rebalancer is not None and rebalancer.every > 0:
                    e = rebalancer.every
                    nxt = min(nxt, (i // e + 1) * e)
                    if getattr(rebalancer, "_pending", None) is not None:
                        # deferred snapshot in flight: its plan lands on
                        # the next iteration, so run exactly one step
                        nxt = min(nxt, i + 1)
                if eng.delta_cfg.enabled:
                    nxt = min(nxt, (i // r + 1) * r)
                if fault_plan is not None:
                    nf = fault_plan.next_step(after=it0 + i)
                    if nf is not None:
                        nxt = min(nxt, max(nf - it0, i + 1))
                full = force_full or (not eng.delta_cfg.enabled) \
                    or (i % r == 0)
                state = seg_fn(state, nxt - i, full_first=full)
                force_full = False
                if track_clip:
                    cnt = codec_overflow_count(state)
                    if cnt > clip_mark:
                        force_full = True
                        clip_mark = cnt
                if track_health:
                    hmark, _ = check_health(eng.guards, state, hmark)
                i = nxt
            return eng, state, []

        if step_fn is None:
            step_fn = eng.make_local_step() if mesh is None \
                else eng.make_sharded_step(mesh)
        series = []
        for i in range(n_steps):
            if rebalancer is not None and rebalancer.due(i):
                eng, state, resharded = rebalancer.maybe_reshard(eng, state)
                if resharded:
                    step_fn = rebalancer.make_step(eng)
                    force_full = True
            if fault_plan is not None:
                state, fired = fault_plan.fire(eng, state, it0 + i)
                if fired:
                    force_full = True
            full = force_full or (not self.delta_cfg.enabled) or (i % r == 0)
            state = step_fn(state, full_halo=full)
            force_full = False
            if track_clip:
                cnt = codec_overflow_count(state)
                if cnt > clip_mark:
                    force_full = True
                    clip_mark = cnt
            if track_health:
                hmark, _ = check_health(eng.guards, state, hmark)
            if collect is not None:
                series.append(collect(state))
        return eng, state, series

    def run(self, state: SimState, n_steps: int, step_fn=None,
            rebalancer=None) -> SimState:
        """Legacy convenience driver (shim path).  Prefer
        :class:`repro.core.simulation.Simulation`, whose ``sim.engine`` /
        ``sim.state`` always match after a re-shard; here the final state
        may live on a different mesh than ``self``, so a rebalance without
        an explicit rebalancer handle triggers the stale-engine warning."""
        had_handle = rebalancer is not None
        eng, state, _ = self.drive(state, n_steps, step_fn=step_fn,
                                   rebalancer=rebalancer)
        warn_if_stale_engine(self, eng, had_handle)
        return state


# ---------------------------------------------------------------------------
# Compiled step/segment caches (module level so structurally-equal engines
# share executables across Engine/Simulation instances).  Backed by the
# bounded + instrumented core.compile_cache registry: a long-lived server
# must not leak executables, and its hit/miss/evict counters are reported
# (repro.core.compile_cache.cache_stats / the scenario server's stats()).
# ---------------------------------------------------------------------------

def _mesh_for(engine: "Engine"):
    """Spatial mesh for an engine's geometry (None on a single device)."""
    if engine.geom.n_devices == 1:
        return None
    from repro.launch.mesh import make_abm_mesh  # deferred: device state
    return make_abm_mesh(engine.geom.mesh_shape)


@memoize("engine.local_step", maxsize=64)
def _cached_local_step(engine: "Engine"):
    comm = LocalComm(toroidal=engine.geom.toroidal)

    @partial(jax.jit, static_argnames=("full_halo",))
    def step(state: SimState, full_halo: bool = True) -> SimState:
        return engine.local_step(state, comm, full_halo)

    return step


def _shard_comm(engine: "Engine", axis_names: Tuple[str, ...]):
    """(ShardComm, PartitionSpec) pair shared by every sharded factory, so
    the per-step and fused paths cannot diverge in their sharding setup."""
    from jax.sharding import PartitionSpec as P

    comm = ShardComm(
        axis_names=axis_names,
        mesh_shape=engine.geom.mesh_shape,
        toroidal=engine.geom.toroidal,
    )
    return comm, P(*axis_names)


@memoize("engine.sharded_step", maxsize=64)
def _cached_sharded_step(engine: "Engine", mesh,
                         axis_names: Tuple[str, ...]):
    comm, spec = _shard_comm(engine, axis_names)

    def body(state: SimState, full_halo: bool) -> SimState:
        return engine.local_step(state, comm, full_halo)

    def make(full_halo: bool):
        f = partial(body, full_halo=full_halo)
        return jax.jit(
            shard_map_compat(f, mesh=mesh, in_specs=spec, out_specs=spec)
        )

    step_full = make(True)
    step_delta = make(False)

    def step(state: SimState, full_halo: bool = True) -> SimState:
        return step_full(state) if full_halo else step_delta(state)

    return step


@memoize("engine.segment_runner", maxsize=64)
def _cached_segment_runner(engine: "Engine", mesh,
                           axis_names: Tuple[str, ...]):
    if mesh is None:
        comm = LocalComm(toroidal=engine.geom.toroidal)
        seg_t = jax.jit(engine._segment_body(comm, True))
        seg_f = jax.jit(engine._segment_body(comm, False))
    else:
        from jax.sharding import PartitionSpec as P

        comm, spec = _shard_comm(engine, axis_names)

        def wrap(full_first: bool):
            # n_steps rides along fully replicated (in_specs P()).
            return jax.jit(shard_map_compat(
                engine._segment_body(comm, full_first), mesh=mesh,
                in_specs=(spec, P()), out_specs=spec))

        seg_t = wrap(True)
        seg_f = wrap(False)

    def seg(state: SimState, n_steps: int, full_first: bool = True
            ) -> SimState:
        n = jnp.int32(n_steps)
        return seg_t(state, n) if full_first else seg_f(state, n)

    return seg


def warn_if_stale_engine(old: "Engine", new: "Engine",
                         had_handle: bool) -> None:
    """Shim-only guard (legacy ``Engine.run`` / ``sims.common.run_sim``):
    warn when a driver discards a re-sharded engine the caller has no handle
    to.  Facade users never hit this — ``Simulation`` swaps its own engine
    in place, so no in-repo caller can observe a stale handle."""
    if new is not old and not had_handle:
        import warnings
        warnings.warn(
            f"a re-shard moved the state to mesh {new.geom.mesh_shape}; "
            f"the engine you hold (mesh {old.geom.mesh_shape}) no longer "
            "matches it — migrate to repro.core.Simulation, whose "
            "sim.engine/sim.state stay consistent across re-shards",
            stacklevel=3)


def total_agents(state: SimState) -> int:
    return int(jnp.sum(state.soa.valid))


def codec_overflow_count(state: SimState) -> int:
    """Largest per-device cumulative clipped-delta count (host-side read;
    each device counts only its own sends, so the max — not the sum — is
    the monotone 'did anyone clip since the mark' signal)."""
    return int(jnp.max(state.codec_overflow))
