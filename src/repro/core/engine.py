"""The distributed simulation engine: one iteration = aura update ->
neighbor interaction -> agent update -> agent migration (paper Figure 1).

State layout: every per-device quantity carries two leading device-mesh dims
``(mx, my)`` (size (1,1) locally inside shard_map), and the agent SoA is
sharded over its first two (cell-grid) dims.  A single uniform
``PartitionSpec("sx", "sy")`` therefore shards the whole state, and the same
``local_step`` body runs unchanged on one device (LocalComm) or on an
arbitrary spatial mesh (ShardComm inside shard_map) — the paper's seamless
laptop-to-supercomputer property (§3.4).
"""

from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent_soa import (
    AgentSoA,
    AgentSchema,
    GID_COUNT,
    GID_RANK,
    POS,
    flat_view,
)
from repro.core.behaviors import Behavior
from repro.core.delta import DeltaConfig, Slab
from repro.core.grid import GridGeom, bin_agents, bin_agents_jit, clear_ring
from repro.core.halo import (
    Comm,
    LocalComm,
    ShardComm,
    halo_exchange,
    init_refs,
    shard_map_compat,
    take_slab,
)
from repro.core.neighbors import sweep_accumulate

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimState:
    soa: AgentSoA                 # (mx*hx, my*hy, K, ...) globally
    refs: Dict[str, Slab]         # leading (mx, my)
    it: Array                     # (mx, my) int32
    key: Array                    # (mx, my, 2) uint32
    gid_counter: Array            # (mx, my) int32
    dropped: Array                # (mx, my) int32 cumulative overflow drops
    halo_bytes: Array             # (mx, my) int32 wire bytes of last aura update

    def tree_flatten(self):
        ref_keys = tuple(sorted(self.refs))
        ref_children = tuple(
            tuple(self.refs[k][f] for f in sorted(self.refs[k]))
            for k in ref_keys
        )
        ref_fields = tuple(tuple(sorted(self.refs[k])) for k in ref_keys)
        children = (self.soa, ref_children, self.it, self.key,
                    self.gid_counter, self.dropped, self.halo_bytes)
        return children, (ref_keys, ref_fields)

    @classmethod
    def tree_unflatten(cls, aux, children):
        ref_keys, ref_fields = aux
        soa, ref_children, it, key, gidc, dropped, hbytes = children
        refs = {
            k: dict(zip(fields, vals))
            for k, fields, vals in zip(ref_keys, ref_fields, ref_children)
        }
        return cls(soa=soa, refs=refs, it=it, key=key, gid_counter=gidc,
                   dropped=dropped, halo_bytes=hbytes)


@dataclasses.dataclass(frozen=True)
class Engine:
    geom: GridGeom
    behavior: Behavior
    delta_cfg: DeltaConfig = DeltaConfig(enabled=False)
    dt: float = 1.0
    # Dynamic load balancing (paper §2.4.5, core.reshard): when
    # rebalance_every > 0, Engine.run/drive checks the occupancy imbalance
    # at that cadence and re-shards past imbalance_threshold.
    rebalance_every: int = 0
    imbalance_threshold: float = 0.5
    # Interaction-sweep backend (core.neighbors.sweep_accumulate):
    # "auto" resolves to the tiled XLA sweep on CPU/GPU and the fused
    # Pallas kernel on TPU; "reference" | "tiled" | "pallas" force one.
    sweep_backend: str = "auto"

    # ------------------------------------------------------------------
    # Initialization (host side, numpy-friendly)
    # ------------------------------------------------------------------
    def init_state(
        self,
        positions: np.ndarray,          # (N, 2) global positions
        attrs: Dict[str, np.ndarray],   # user attrs, (N, ...)
        seed: int = 0,
        *,
        gid_counters: Optional[np.ndarray] = None,  # per-rank spawn floors
        it0: int = 0,                   # starting iteration counter
        base_key: Optional[np.ndarray] = None,      # (2,) uint32 RNG root
    ) -> SimState:
        """Distributed initialization (paper §2.4.4): agents are created
        directly on their authoritative device — no mass migration.

        The re-shard / elastic-restore path (core.reshard) re-enters here
        with extra carry: when ``attrs`` contains the ``gid_rank`` /
        ``gid_count`` columns they are preserved verbatim instead of being
        re-issued, and per-rank spawn counters resume past both the largest
        carried id per rank and the optional ``gid_counters`` floors (so no
        id is ever issued twice, even across mesh-shape changes).  ``it0``
        seeds the iteration counter and ``base_key`` the RNG lineage: the
        per-device keys are split from ``fold_in(base_key, it0)`` rather
        than a fresh ``PRNGKey(seed)``."""
        geom = self.geom
        mx, my = geom.mesh_shape
        ix, iy = geom.interior
        hx, hy = geom.local_shape
        schema = self.behavior.schema

        gx, gy = geom.domain_size
        if (positions < 0).any() or (positions[:, 0] >= gx).any() or (
                positions[:, 1] >= gy).any():
            raise ValueError(
                f"initial positions outside the domain [0,{gx})x[0,{gy}) — "
                "out-of-domain agents would land in the halo ring and be "
                "destroyed by the first aura rebuild")
        lx = ix * geom.cell_size
        ly = iy * geom.cell_size
        dev_x = np.clip((positions[:, 0] // lx).astype(np.int64), 0, mx - 1)
        dev_y = np.clip((positions[:, 1] // ly).astype(np.int64), 0, my - 1)

        bin_fn = partial(bin_agents_jit, geom)

        carried_gids = GID_RANK in attrs and GID_COUNT in attrs
        if gid_counters is not None and not carried_gids:
            raise ValueError(
                "gid_counters floors require carried gid_rank/gid_count "
                "columns in attrs — fresh ids would start at 0 and collide "
                "with the historical ids the floors protect")
        counters_next = np.zeros((mx * my,), dtype=np.int64)
        if carried_gids:
            g_rank = np.asarray(attrs[GID_RANK], np.int64)
            g_count = np.asarray(attrs[GID_COUNT], np.int64)
            in_range = (g_rank >= 0) & (g_rank < mx * my)
            np.maximum.at(counters_next, g_rank[in_range],
                          g_count[in_range] + 1)
        if gid_counters is not None:
            floors = np.asarray(gid_counters, np.int64).ravel()
            if floors.size:
                # Counters are exact issuance trackers (> every id ever
                # issued by that rank, dead or alive), so the global max
                # floor bounds ALL historical ids — applying it to every
                # new rank keeps ids unique even when a smaller mesh
                # dropped some ranks' floors and their witnesses died
                # before a later re-expansion.
                counters_next = np.maximum(counters_next, floors.max())

        blocks = []
        counters = np.zeros((mx, my), dtype=np.int32)
        for cx in range(mx):
            row = []
            for cy in range(my):
                sel = np.flatnonzero((dev_x == cx) & (dev_y == cy))
                n = sel.size
                flat: Dict[str, jax.Array] = {}
                for name, (shape, dtype) in schema.all_specs().items():
                    if name == POS:
                        a = positions[sel].astype(np.float32)
                    elif name == GID_RANK and not carried_gids:
                        a = np.full((n,), cx * my + cy, dtype=np.int32)
                    elif name == GID_COUNT and not carried_gids:
                        a = np.arange(n, dtype=np.int32)
                    else:
                        a = np.asarray(attrs[name][sel], dtype=dtype)
                    flat[name] = jnp.asarray(a)
                valid = jnp.ones((n,), jnp.bool_)
                origin = jnp.asarray(
                    [cx * lx, cy * ly], dtype=jnp.float32
                )
                soa, dropped = bin_fn(flat, valid, origin)
                if int(dropped) != 0:
                    raise ValueError(
                        f"cell capacity overflow at init on device ({cx},{cy}): "
                        f"{int(dropped)} agents dropped; raise geom.cap"
                    )
                counters[cx, cy] = max(
                    counters_next[cx * my + cy],
                    0 if carried_gids else n)
                row.append(soa)
            blocks.append(row)

        def blockcat(getter):
            return jnp.concatenate(
                [jnp.concatenate([getter(b) for b in row], axis=1)
                 for row in blocks],
                axis=0,
            )

        attrs_g = {
            name: blockcat(lambda b, n=name: b.attrs[n])
            for name in blocks[0][0].attrs
        }
        soa_g = AgentSoA(attrs=attrs_g, valid=blockcat(lambda b: b.valid))

        refs0 = init_refs(geom, blocks[0][0])
        refs_g = {
            d: {f: jnp.broadcast_to(v[None, None], (mx, my) + v.shape)
                for f, v in slab.items()}
            for d, slab in refs0.items()
        }

        if base_key is not None:
            root = jax.random.fold_in(
                jnp.asarray(base_key, jnp.uint32), it0)
        else:
            root = jax.random.PRNGKey(seed)
        keys = jax.random.split(root, mx * my)
        keys = keys.reshape(mx, my, -1)

        return SimState(
            soa=soa_g,
            refs=refs_g,
            it=jnp.full((mx, my), it0, jnp.int32),
            key=keys,
            gid_counter=jnp.asarray(counters),
            dropped=jnp.zeros((mx, my), jnp.int32),
            halo_bytes=jnp.zeros((mx, my), jnp.int32),
        )

    # ------------------------------------------------------------------
    # One iteration (runs per device; comm abstracts the mesh)
    # ------------------------------------------------------------------
    def local_step(self, state: SimState, comm: Comm, full_halo: bool
                   ) -> SimState:
        geom = self.geom
        beh = self.behavior
        hx, hy = geom.local_shape
        ix, iy = geom.interior
        k = geom.cap
        toroidal = geom.boundary == "toroidal"

        cx, cy = comm.coords()
        origin = geom.device_origin((cx, cy))
        lrank = comm.linear_rank()

        soa = state.soa
        refs = {d: {f: v[0, 0] for f, v in slab.items()}
                for d, slab in state.refs.items()}
        it = state.it[0, 0]
        key = state.key[0, 0]
        gidc = state.gid_counter[0, 0]
        dropped = state.dropped[0, 0]

        # 1. Aura update (rebuilt from scratch each iteration, §2.2.1).
        soa = clear_ring(soa)
        soa, refs, hbytes = halo_exchange(
            geom, soa, comm, refs, self.delta_cfg, full_halo
        )

        # 2. Local interaction (backend-dispatched fused sweep).
        acc = sweep_accumulate(
            geom, soa, beh.pair_fn, beh.pair_attrs, beh.radius, beh.params,
            backend=self.sweep_backend,
        )

        # 3. Pointwise update on interior agents.
        int_attrs = {n: a[1:hx - 1, 1:hy - 1] for n, a in soa.attrs.items()}
        int_valid = soa.valid[1:hx - 1, 1:hy - 1]
        step_key = jax.random.fold_in(jax.random.fold_in(key, it), lrank)
        new_attrs, alive, spawn, child_attrs = beh.update_fn(
            int_attrs, int_valid, acc, step_key, beh.params, self.dt
        )
        new_valid = int_valid & alive

        # Boundary condition on positions.
        lxy = jnp.asarray(geom.domain_size, jnp.float32)
        if geom.boundary == "closed":
            eps = jnp.float32(1e-4) * geom.cell_size
            new_attrs[POS] = jnp.clip(new_attrs[POS], eps, lxy - eps)

        # 4. Flatten interior (+children) for re-binning.
        n_int = ix * iy * k
        flat = {n: a.reshape((n_int,) + a.shape[3:])
                for n, a in new_attrs.items()}
        fvalid = new_valid.reshape((n_int,))

        if beh.can_spawn:
            sflat = spawn.reshape((n_int,)) & fvalid
            n_spawn = jnp.sum(sflat.astype(jnp.int32))
            child = {n: a.reshape((n_int,) + a.shape[3:])
                     for n, a in child_attrs.items()}
            order = jnp.cumsum(sflat.astype(jnp.int32)) - 1
            child[GID_RANK] = jnp.full((n_int,), lrank, jnp.int32)
            child[GID_COUNT] = gidc + order
            gidc = gidc + n_spawn
            flat = {n: jnp.concatenate([flat[n], child[n]]) for n in flat}
            fvalid = jnp.concatenate([fvalid, sflat])

        soa2, d1 = bin_agents(geom, flat, fvalid, origin)
        dropped = dropped + d1

        # 5. Agent migration: dimension-ordered ring exchange (x then y).
        soa3, d2 = self._migrate(soa2, comm, origin, toroidal, lxy)
        dropped = dropped + d2

        # 6. Repack per-device state.
        mxmy = state.it.shape
        new_refs = {
            d: {f: jnp.broadcast_to(v[None, None], mxmy + v.shape)
                for f, v in slab.items()}
            for d, slab in refs.items()
        }
        return SimState(
            soa=soa3,
            refs=new_refs,
            it=jnp.broadcast_to((it + 1)[None, None], mxmy),
            key=state.key,
            gid_counter=jnp.broadcast_to(gidc[None, None], mxmy),
            dropped=jnp.broadcast_to(dropped[None, None], mxmy),
            halo_bytes=jnp.broadcast_to(hbytes[None, None], mxmy),
        )

    def _migrate(self, soa: AgentSoA, comm: Comm, origin: Array,
                 toroidal: bool, lxy: Array) -> Tuple[AgentSoA, Array]:
        """Dimension-ordered emigrant routing with one-pass re-binning.

        x faces (rows 0 / hx-1, incl. corner cells) are exchanged first.
        Diagonal migrants arrive in the *y-ring cells* of the received x
        slabs (their y-binning used the sender's — identical — y origin),
        so instead of re-binning to rediscover them, the y payload widens
        by 2K slots carrying those corners forward directly: extra slot
        block rows 1 / hx-2 hold the agents that entered at x-cells 1 /
        hx-2.  Everything — the face-cleared grid, both x receives (corners
        invalidated) and both widened y receives — then re-bins in a single
        argsort pass, cutting the sort-based binning passes per step from
        3 (step re-bin + one per axis) to 2 (step re-bin + this one).
        """
        geom = self.geom
        hx, hy = geom.local_shape
        k = geom.cap

        def wrap_pos(slab: Slab) -> Slab:
            if not toroidal:
                return slab
            out = dict(slab)
            out[POS] = jnp.mod(slab[POS], lxy)
            return out

        def fl(slab: Slab):
            slab = dict(slab)
            v = slab.pop("valid")
            return ({n: a.reshape((-1,) + a.shape[2:])
                     for n, a in slab.items()},
                    v.reshape((-1,)))

        # x phase: emigrant rows, corner cells included.
        out_m = wrap_pos(take_slab(soa, 0, 0))
        out_p = wrap_pos(take_slab(soa, 0, hx - 1))
        recv_p = comm.shift(out_p, 0, +1)  # from -x neighbor -> my x-cell 1
        recv_m = comm.shift(out_m, 0, -1)  # from +x neighbor -> x-cell hx-2
        v = soa.valid.at[0].set(False).at[hx - 1].set(False)
        soa = soa.replace(valid=v)

        # y phase: own y-face columns + forwarded corners from the x
        # receives.  recv slab cell j sits at my y-cell j, so cells 0 and
        # hy-1 are exactly the diagonal migrants still needing a y hop.
        def widen(col: Slab, fwd_p: Slab, fwd_m: Slab) -> Slab:
            out = {}
            for n, a in col.items():
                extra = jnp.zeros((hx, 2 * k) + a.shape[2:], a.dtype)
                extra = extra.at[1, :k].set(fwd_p[n])
                extra = extra.at[hx - 2, k:].set(fwd_m[n])
                out[n] = jnp.concatenate([a, extra], axis=1)
            return out

        def at_cell(slab: Slab, j: int) -> Slab:
            return {n: a[j] for n, a in slab.items()}

        yout_m = wrap_pos(widen(take_slab(soa, 1, 0),
                                at_cell(recv_p, 0), at_cell(recv_m, 0)))
        yout_p = wrap_pos(widen(take_slab(soa, 1, hy - 1),
                                at_cell(recv_p, hy - 1),
                                at_cell(recv_m, hy - 1)))
        yrecv_p = comm.shift(yout_p, 1, +1)
        yrecv_m = comm.shift(yout_m, 1, -1)

        # The y faces were sent; the x-receive corners were forwarded.
        v = soa.valid.at[:, 0].set(False).at[:, hy - 1].set(False)
        soa = soa.replace(valid=v)
        recv_p = dict(recv_p)
        recv_m = dict(recv_m)
        for slab in (recv_p, recv_m):
            slab["valid"] = slab["valid"].at[0].set(False) \
                                         .at[hy - 1].set(False)

        base_attrs, base_valid = flat_view(soa)
        parts = [fl(recv_p), fl(recv_m), fl(yrecv_p), fl(yrecv_m)]
        cat = {n: jnp.concatenate([base_attrs[n]] + [p[0][n] for p in parts])
               for n in base_attrs}
        catv = jnp.concatenate([base_valid] + [p[1] for p in parts])
        return bin_agents(geom, cat, catv, origin)

    # ------------------------------------------------------------------
    # Compiled step factories
    # ------------------------------------------------------------------
    # All factories are memoized at module level on the engine value
    # (Engine is a hashable frozen dataclass; behaviors compare by
    # identity), so rebuilding an equivalent engine — a fresh Simulation
    # facade, a benchmark rerun — reuses the already-compiled executables
    # instead of re-tracing.

    def make_local_step(self):
        return _cached_local_step(self)

    def make_sharded_step(self, mesh, axis_names: Tuple[str, str] = ("sx", "sy")):
        return _cached_sharded_step(self, mesh, axis_names)

    def make_segment_runner(self, mesh=None,
                            axis_names: Tuple[str, str] = ("sx", "sy")):
        """Scan-fused driver: ``seg(state, n_steps, full_first=True)`` runs
        ``n_steps`` iterations in ONE compiled dispatch (a ``fori_loop``
        over the step body), eliminating the per-step Python/dispatch floor.

        ``full_first`` selects a full aura refresh for the segment's first
        step; the remaining steps use the delta path (callers align
        segments with the refresh schedule so no interior step needs a
        full refresh).  With delta encoding disabled every step is full
        and ``full_first`` is ignored.  ``n_steps`` is a *dynamic* loop
        bound — one executable covers every segment length.
        """
        return _cached_segment_runner(self, mesh, axis_names)

    def _segment_body(self, comm, full_first: bool):
        """Per-device segment: first step optionally full, rest delta."""
        delta_on = self.delta_cfg.enabled

        def seg(state: SimState, n_steps: Array) -> SimState:
            if not delta_on:
                return jax.lax.fori_loop(
                    0, n_steps,
                    lambda i, s: self.local_step(s, comm, True), state)
            rest = n_steps
            if full_first:
                state = self.local_step(state, comm, True)
                rest = n_steps - 1
            return jax.lax.fori_loop(
                0, rest, lambda i, s: self.local_step(s, comm, False), state)

        return seg

    def drive(self, state: SimState, n_steps: int, step_fn=None,
              rebalancer=None, collect=None, mesh=None):
        """Low-level driver: delta refresh schedule + dynamic load balancing.

        Prefer :class:`repro.core.simulation.Simulation` — the facade owns
        this loop and keeps ``sim.engine``/``sim.state`` consistent across
        re-shards, so callers never juggle the returned engine themselves.

        Default path (no ``step_fn``, no ``collect``): steps run through
        the scan-fused segment runner, one compiled dispatch per
        refresh-interval/rebalance-cadence segment.  Passing an explicit
        ``step_fn`` or a per-step ``collect`` falls back to one dispatch
        per step (both need host control between steps).  ``mesh`` selects
        the sharded segment runner for multi-device geometries.

        At the rebalancer's cadence the occupancy imbalance is checked and,
        past the threshold, the state is mass-migrated onto a better mesh
        (core.reshard); the step/segment function is rebuilt for the new
        geometry and the next aura exchange is forced to a full refresh
        (the re-shard zeroed the delta references).  Returns
        ``(engine, state, series)`` — the engine differs from ``self``
        after a re-shard.
        """
        eng = self
        if rebalancer is None and self.rebalance_every > 0:
            from repro.core.reshard import Rebalancer
            rebalancer = Rebalancer(every=self.rebalance_every,
                                    threshold=self.imbalance_threshold)
        r = max(int(self.delta_cfg.refresh_interval), 1)
        force_full = False

        if step_fn is None and mesh is None:
            # No step function and no explicit mesh: derive the mesh from
            # the geometry so a multi-device engine never silently runs
            # through LocalComm (zero-filled halo shifts).
            mesh = _mesh_for(eng)

        if step_fn is None and collect is None:
            # Scan-fused path: segment boundaries at refresh-interval and
            # rebalance-cadence ticks (the only host-side control points).
            seg_fn = eng.make_segment_runner(mesh)
            i = 0
            while i < n_steps:
                if rebalancer is not None and rebalancer.due(i):
                    eng, state, resharded = rebalancer.maybe_reshard(
                        eng, state)
                    if resharded:
                        mesh = _mesh_for(eng)
                        seg_fn = eng.make_segment_runner(mesh)
                        force_full = True
                nxt = n_steps
                if rebalancer is not None and rebalancer.every > 0:
                    e = rebalancer.every
                    nxt = min(nxt, (i // e + 1) * e)
                if eng.delta_cfg.enabled:
                    nxt = min(nxt, (i // r + 1) * r)
                full = force_full or (not eng.delta_cfg.enabled) \
                    or (i % r == 0)
                state = seg_fn(state, nxt - i, full_first=full)
                force_full = False
                i = nxt
            return eng, state, []

        if step_fn is None:
            step_fn = eng.make_local_step() if mesh is None \
                else eng.make_sharded_step(mesh)
        series = []
        for i in range(n_steps):
            if rebalancer is not None and rebalancer.due(i):
                eng, state, resharded = rebalancer.maybe_reshard(eng, state)
                if resharded:
                    step_fn = rebalancer.make_step(eng)
                    force_full = True
            full = force_full or (not self.delta_cfg.enabled) or (i % r == 0)
            state = step_fn(state, full_halo=full)
            force_full = False
            if collect is not None:
                series.append(collect(state))
        return eng, state, series

    def run(self, state: SimState, n_steps: int, step_fn=None,
            rebalancer=None) -> SimState:
        """Legacy convenience driver (shim path).  Prefer
        :class:`repro.core.simulation.Simulation`, whose ``sim.engine`` /
        ``sim.state`` always match after a re-shard; here the final state
        may live on a different mesh than ``self``, so a rebalance without
        an explicit rebalancer handle triggers the stale-engine warning."""
        had_handle = rebalancer is not None
        eng, state, _ = self.drive(state, n_steps, step_fn=step_fn,
                                   rebalancer=rebalancer)
        warn_if_stale_engine(self, eng, had_handle)
        return state


# ---------------------------------------------------------------------------
# Compiled step/segment caches (module level so structurally-equal engines
# share executables across Engine/Simulation instances)
# ---------------------------------------------------------------------------

def _mesh_for(engine: "Engine"):
    """Spatial mesh for an engine's geometry (None on 1x1)."""
    if engine.geom.mesh_shape == (1, 1):
        return None
    from repro.launch.mesh import make_abm_mesh  # deferred: device state
    return make_abm_mesh(engine.geom.mesh_shape)


@functools.lru_cache(maxsize=64)
def _cached_local_step(engine: "Engine"):
    comm = LocalComm(toroidal=engine.geom.boundary == "toroidal")

    @partial(jax.jit, static_argnames=("full_halo",))
    def step(state: SimState, full_halo: bool = True) -> SimState:
        return engine.local_step(state, comm, full_halo)

    return step


def _shard_comm(engine: "Engine", axis_names: Tuple[str, str]):
    """(ShardComm, PartitionSpec) pair shared by every sharded factory, so
    the per-step and fused paths cannot diverge in their sharding setup."""
    from jax.sharding import PartitionSpec as P

    comm = ShardComm(
        axis_names=axis_names,
        mesh_shape=engine.geom.mesh_shape,
        toroidal=engine.geom.boundary == "toroidal",
    )
    return comm, P(*axis_names)


@functools.lru_cache(maxsize=64)
def _cached_sharded_step(engine: "Engine", mesh,
                         axis_names: Tuple[str, str]):
    comm, spec = _shard_comm(engine, axis_names)

    def body(state: SimState, full_halo: bool) -> SimState:
        return engine.local_step(state, comm, full_halo)

    def make(full_halo: bool):
        f = partial(body, full_halo=full_halo)
        return jax.jit(
            shard_map_compat(f, mesh=mesh, in_specs=spec, out_specs=spec)
        )

    step_full = make(True)
    step_delta = make(False)

    def step(state: SimState, full_halo: bool = True) -> SimState:
        return step_full(state) if full_halo else step_delta(state)

    return step


@functools.lru_cache(maxsize=64)
def _cached_segment_runner(engine: "Engine", mesh,
                           axis_names: Tuple[str, str]):
    if mesh is None:
        comm = LocalComm(toroidal=engine.geom.boundary == "toroidal")
        seg_t = jax.jit(engine._segment_body(comm, True))
        seg_f = jax.jit(engine._segment_body(comm, False))
    else:
        from jax.sharding import PartitionSpec as P

        comm, spec = _shard_comm(engine, axis_names)

        def wrap(full_first: bool):
            # n_steps rides along fully replicated (in_specs P()).
            return jax.jit(shard_map_compat(
                engine._segment_body(comm, full_first), mesh=mesh,
                in_specs=(spec, P()), out_specs=spec))

        seg_t = wrap(True)
        seg_f = wrap(False)

    def seg(state: SimState, n_steps: int, full_first: bool = True
            ) -> SimState:
        n = jnp.int32(n_steps)
        return seg_t(state, n) if full_first else seg_f(state, n)

    return seg


def warn_if_stale_engine(old: "Engine", new: "Engine",
                         had_handle: bool) -> None:
    """Shim-only guard (legacy ``Engine.run`` / ``sims.common.run_sim``):
    warn when a driver discards a re-sharded engine the caller has no handle
    to.  Facade users never hit this — ``Simulation`` swaps its own engine
    in place, so no in-repo caller can observe a stale handle."""
    if new is not old and not had_handle:
        import warnings
        warnings.warn(
            f"a re-shard moved the state to mesh {new.geom.mesh_shape}; "
            f"the engine you hold (mesh {old.geom.mesh_shape}) no longer "
            "matches it — migrate to repro.core.Simulation, whose "
            "sim.engine/sim.state stay consistent across re-shards",
            stacklevel=3)


def total_agents(state: SimState) -> int:
    return int(jnp.sum(state.soa.valid))
