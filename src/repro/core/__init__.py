"""TeraAgent core: the paper's contribution as composable JAX modules.

Public API:
  AgentSchema / AgentSoA   — SoA agent container (TeraAgent IO analogue)
  GridGeom                 — partitioning grid + neighbor-search grid
  Behavior                 — model definition (pair kernel + update)
  Engine / SimState        — distributed simulation engine
  DeltaConfig              — delta-encoded aura exchange (paper §2.3)
  Rebalancer               — dynamic load balancing runtime (paper §2.4.5)
"""

from repro.core.agent_soa import AgentSchema, AgentSoA, GID_COUNT, GID_RANK, POS
from repro.core.behaviors import Behavior
from repro.core.delta import DeltaConfig
from repro.core.engine import Engine, SimState, total_agents
from repro.core.grid import GridGeom
from repro.core.reshard import Rebalancer

__all__ = [
    "AgentSchema", "AgentSoA", "GID_COUNT", "GID_RANK", "POS",
    "Behavior", "DeltaConfig", "Engine", "SimState", "GridGeom",
    "Rebalancer", "total_agents",
]
