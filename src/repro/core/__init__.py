"""TeraAgent core: the paper's contribution as composable JAX modules.

Public API:
  Simulation               — user-facing facade: owns engine, mesh, state,
                             re-shard runtime, scheduled operations,
                             checkpoints (paper §3.4 usability claim)
  Rebalance / Checkpoint   — facade policy knobs
  AgentSchema / AgentSoA   — SoA agent container (TeraAgent IO analogue)
  Domain                   — N-D spatial spec: partitioning grid +
                             neighbor-search grid + per-axis boundaries
                             (2-D sheets and 3-D tissues; docs/domains.md)
  Partition                — per-axis cut positions for uneven box-granular
                             ownership (padded per-device grids + masked
                             halo; docs/load_balancing.md)
  GridGeom                 — DEPRECATED 2-D constructor shim over Domain
  Behavior / compose       — model definition (pair kernel + update) and
                             the behavior-stacking composition algebra
  operations               — scheduled-op helpers (SumOverAllRanks family)
  Engine / SimState        — distributed simulation engine (low-level)
  DeltaConfig              — delta-encoded aura exchange (paper §2.3)
  Rebalancer               — dynamic load balancing runtime (paper §2.4.5)
  GuardConfig / HealthReport / HealthError
                           — runtime health guards fused into the step
                             (docs/resilience.md)
  Ensemble / EnsembleState — vmapped many-config runner: R parameter
                             points of one family per dispatch
                             (docs/serving.md)
  cache_stats              — hit/miss/evict counters for every bounded
                             compile cache in the process
"""

from repro.core import operations
from repro.core.agent_soa import AgentSchema, AgentSoA, GID_COUNT, GID_RANK, POS
from repro.core.behaviors import Behavior, compose
from repro.core.compile_cache import cache_stats
from repro.core.delta import DeltaConfig
from repro.core.domain import Domain, Partition
from repro.core.engine import Engine, SimState, total_agents
from repro.core.ensemble import Ensemble, EnsembleState, ensemble_health_counts
from repro.core.grid import GridGeom
from repro.core.guards import (
    GUARD_NAMES,
    GuardConfig,
    HealthError,
    HealthReport,
    health_counts,
)
from repro.core.reshard import Rebalancer
from repro.core.simulation import Checkpoint, Rebalance, Simulation

__all__ = [
    "AgentSchema", "AgentSoA", "GID_COUNT", "GID_RANK", "POS",
    "Behavior", "compose", "Checkpoint", "DeltaConfig", "Domain", "Engine",
    "Ensemble", "EnsembleState",
    "GUARD_NAMES", "GuardConfig", "HealthError", "HealthReport",
    "Partition", "SimState", "GridGeom", "Rebalance", "Rebalancer",
    "Simulation",
    "cache_stats", "ensemble_health_counts", "health_counts", "operations",
    "total_agents",
]
