"""The ``Simulation`` facade — one object that owns mesh, re-shard,
operations, and checkpoints.

The paper's headline usability claim is the seamless laptop-to-supercomputer
model API (§3.4); BioDynaMo realizes it with a ``Simulation`` object owning
the resource manager plus lists of per-agent behaviors and scheduled
operations.  This module is that object for the TPU engine:

    sim = Simulation(
        dict(interior=(8, 8), mesh_shape=(2, 2), cap=48),
        [mechanics_behavior, sir_behavior],          # composed automatically
        dt=0.1,
        rebalance=Rebalance(every=5, threshold=0.3, weighted=True),
        checkpoint=Checkpoint("ckpts", every=50),
    )
    sim.init(positions, attrs, seed=0)
    sim.every(1, operations.agent_count)
    sim.run(100)
    sim.series["agent_count"], sim.engine, sim.state   # always consistent

``sim.engine`` / ``sim.state`` / ``sim.mesh`` always reflect the
post-re-shard world: when the scheduled rebalance operation mass-migrates
the state onto a better mesh, the facade rebuilds its step function and
device mesh in place — there is no stale engine handle for a caller to
hold, which retires the ``warn_if_stale_engine`` contract for facade users
(the shims ``sims.common.make_engine``/``run_sim`` keep it for legacy code).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.behaviors import Behavior, compose
from repro.core.delta import DeltaConfig
from repro.core.domain import Domain
from repro.core.engine import (
    Engine,
    SimState,
    codec_overflow_count,
    total_agents,
)
from repro.core.guards import GuardConfig, as_guard_config, check_health, \
    health_counts
from repro.core.operations import Operation, checkpoint_op
from repro.core.reshard import Rebalancer, estimate_device_runtimes

# Geometry defaults applied when the first argument is a kwargs dict
# (mirrors the historical sims.common.make_engine defaults; an all-ones
# mesh_shape broadcasts to the interior's dimensionality, so a 3-D
# ``interior`` alone is enough to get a 3-D single-device Domain).
_GEOM_DEFAULTS = dict(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                      cap=24, boundary="closed")


@dataclasses.dataclass(frozen=True)
class Rebalance:
    """Dynamic load balancing policy for the facade (paper §2.4.5).

    ``weighted=True`` feeds ``Rebalancer.runtimes`` from a measured signal:
    at the rebalance cadence the facade times the step immediately before
    the check (host wall clock, synchronized with ``block_until_ready``) and
    attributes it per device by measured pair-interaction work
    (``reshard.estimate_device_runtimes``) — so a device full of densely
    clustered agents weighs more than one with the same count spread out.
    Weighted checks are deferred until a measurement exists, so the first
    one runs at iteration ``every`` rather than 0 (unweighted checks keep
    the iteration-0 check, matching ``Engine.drive``).

    ``ownership`` selects what a triggered re-shard may realize:
    ``"equal"`` keeps the historical equal-split mesh factorizations;
    ``"rcb"`` lets the planner cut box-granular *uneven* rectilinear
    partitions (padded per-device grids + masked halo exchange,
    docs/load_balancing.md), closing the gap to the reported RCB bound on
    clustered densities.

    ``transport`` picks the mass-migration path for applied re-shards
    (``"auto"`` takes the zero-host-bytes device-to-device collective
    whenever the device count is unchanged; ``"host"`` forces the legacy
    flatten round trip).  ``defer=True`` makes each rebalance check
    two-phase: the occupancy snapshot starts an async device-to-host copy
    at the due tick and the old mesh keeps stepping while the plan builds;
    the decision (and any migration) lands one step later.
    """

    every: int = 10
    threshold: float = 0.5
    min_gain: float = 1.5
    weighted: bool = False
    ownership: str = "equal"
    transport: str = "auto"
    defer: bool = False


@dataclasses.dataclass
class _RebalanceOp(Operation):
    """The scheduled rebalance check.  With a deferred (async-snapshot)
    plan pending on the rebalancer, the op is due on *every* tick so the
    plan+apply phase lands one step after the snapshot — the segment
    scheduler then also breaks fusion there, keeping the landing tick a
    host control point."""

    rb: Optional[Rebalancer] = None

    def due(self, tick: int) -> bool:
        if self.rb is not None and self.rb._pending is not None:
            return True
        return super().due(tick)


@dataclasses.dataclass(frozen=True)
class Checkpoint:
    """Scheduled logical ABM checkpoints (``checkpoint.save_abm``): mesh-
    independent, restorable onto any device count via
    ``elastic.elastic_restore_abm``."""

    dir: str
    every: int = 100
    keep: int = 3


class Simulation:
    """Single owner of engine, mesh, state, step function, and rebalancer.

    Args:
      geom: a :class:`repro.core.Domain` (2-D or 3-D, per-axis boundaries),
        or a dict of Domain kwargs (defaults: ``cell_size=2.0,
        interior=(8, 8), mesh_shape=(1, 1), cap=24, boundary="closed"``).
        The deprecated ``GridGeom`` shim also lands here (it returns a
        ``Domain``).
      behaviors: one :class:`Behavior` or a sequence — sequences are merged
        with :func:`repro.core.behaviors.compose`.
      mesh: an explicit spatial device mesh; by default one is built
        lazily via ``launch.mesh.make_abm_mesh`` whenever the Domain spans
        more than one device (and rebuilt after every re-shard).
      delta: optional :class:`DeltaConfig` for delta-encoded aura exchange.
      dt: integration step.
      rebalance: a :class:`Rebalance` policy, an int shorthand for
        ``Rebalance(every=n)``, or None.
      checkpoint: a :class:`Checkpoint` spec, a directory-path shorthand
        for ``Checkpoint(dir)``, or None.
      sweep_backend: interaction-sweep backend
        (``"auto" | "reference" | "tiled" | "pallas"``, see
        docs/performance.md); ``"auto"`` picks the tiled XLA sweep on
        CPU/GPU and the Pallas kernel on TPU.
      overlap: communication hiding (``"auto" | "on" | "off"``, see
        docs/performance.md): split the sweep into an interior pass that
        runs concurrently with the ``ppermute`` aura exchange and a
        boundary pass that consumes it.  ``"auto"`` enables the split
        exactly where a wire exists (multi-device meshes).  Results are
        pinned bit-exact against the monolithic sweep, so the knob only
        changes scheduling.
      check: construction-time contract gate (docs/contracts.md).
        ``"error"`` (default) raises :class:`repro.analysis.ContractError`
        on any error-severity finding — e.g. a ``Behavior.radius`` larger
        than ``cell_size``, which would silently drop interacting pairs;
        ``"warn"`` demotes those to warnings; ``"off"`` skips the gate.
        ``sim.validate()`` runs the full simcheck suite (contracts +
        jaxpr audit + hot-path lint) on demand.
      guards: runtime health guards (docs/resilience.md): a
        :class:`repro.core.guards.GuardConfig`, a policy-string shorthand
        (``"warn"`` | ``"error"``), or None (off — the default compiles
        the guards out entirely).  Guard counters are read at the same
        host control points as the codec-overflow word; under
        ``"error"`` a trip raises :class:`repro.core.guards.HealthError`,
        which a supervised run (``run(supervised=...)``) rolls back on.
    """

    def __init__(self, geom: Union[Domain, Dict[str, Any]],
                 behaviors: Union[Behavior, Sequence[Behavior]], *,
                 mesh=None, delta: Optional[DeltaConfig] = None,
                 dt: float = 1.0,
                 rebalance: Union[Rebalance, int, None] = None,
                 checkpoint: Union[Checkpoint, str, None] = None,
                 sweep_backend: str = "auto",
                 overlap: str = "auto",
                 check: str = "error",
                 guards: Union[GuardConfig, str, None] = None):
        if isinstance(geom, dict):
            geom = Domain(**{**_GEOM_DEFAULTS, **geom})
        if isinstance(behaviors, Behavior):
            behavior = behaviors
        else:
            behs = tuple(behaviors)
            behavior = behs[0] if len(behs) == 1 else compose(*behs)
        # The engine is built ungated (check="off") and the facade runs the
        # gate itself: internally-built engines stay structurally identical
        # to pre-gate ones, so the module-level compiled-step caches keyed
        # on the engine value never split.
        self.engine: Engine = Engine(
            geom=geom, behavior=behavior,
            delta_cfg=delta or DeltaConfig(enabled=False), dt=dt,
            sweep_backend=sweep_backend, overlap=overlap,
            guards=as_guard_config(guards))
        self._check = check
        from repro.analysis.contracts import enforce
        enforce(self.engine, mode=check)
        self.state: Optional[SimState] = None
        self.series: Dict[str, List[Any]] = {}
        self._mesh = mesh
        self._step_fn: Optional[Callable] = None   # set -> per-step loop
        self._seg_fn: Optional[Callable] = None    # scan-fused segment runner
        self._ticks = 0          # step counter across run() calls
        self._force_full = False  # next aura exchange must be a full refresh
        self._last_step_s: Optional[float] = None  # weighted-rebalance sample
        self._ops: List[Operation] = []

        if isinstance(rebalance, int):
            rebalance = Rebalance(every=rebalance)
        self._weighted = bool(rebalance and rebalance.weighted)
        self.rebalancer: Optional[Rebalancer] = None
        if rebalance is not None and rebalance.every > 0:
            self.rebalancer = Rebalancer(
                every=rebalance.every, threshold=rebalance.threshold,
                min_gain=rebalance.min_gain,
                ownership=rebalance.ownership,
                transport=rebalance.transport, defer=rebalance.defer)
            self._ops.append(_RebalanceOp(
                fn=Simulation._maybe_rebalance, every=rebalance.every,
                name="rebalance", pre=True, record=False,
                rb=self.rebalancer))

        if isinstance(checkpoint, str):
            checkpoint = Checkpoint(dir=checkpoint)
        if checkpoint is not None:
            self._ops.append(Operation(
                fn=checkpoint_op(checkpoint.dir, keep=checkpoint.keep),
                every=checkpoint.every, name="checkpoint", record=False))

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def geom(self) -> Domain:
        return self.engine.geom

    @property
    def behavior(self) -> Behavior:
        return self.engine.behavior

    @property
    def mesh(self):
        """The live spatial device mesh (None on a single-device geometry).
        Always matches ``self.engine.geom.mesh_shape``, also right after a
        re-shard."""
        if self.engine.geom.n_devices == 1:
            return None
        if (self._mesh is None
                or self._mesh.devices.shape != self.engine.geom.mesh_shape):
            from repro.launch.mesh import make_abm_mesh  # deferred: devices
            self._mesh = make_abm_mesh(self.engine.geom.mesh_shape)
        return self._mesh

    @property
    def iteration(self) -> int:
        """The engine iteration counter (survives re-shards and restores)."""
        if self.state is None:
            return 0
        return int(np.max(np.asarray(self.state.it)))

    def n_agents(self) -> int:
        return total_agents(self.state)

    def validate(self, *, jaxpr: bool = True):
        """Full simcheck suite over this simulation: static contracts
        (stencil soundness, one-hop migration, aura sufficiency, codec
        headroom, partition validity), hot-path lint of every leaf
        behavior function, and — unless ``jaxpr=False`` — a jaxpr audit of
        the traced step runner (ppermute permutation validity, host syncs,
        dtype drift, cache-key stability).  Returns a
        :class:`repro.analysis.Report`; see docs/contracts.md for the
        catalogue.  Purely static — runs no simulation steps and costs
        nothing on the hot path."""
        from repro.analysis import (
            Report,
            check_engine,
            lint_behavior,
        )
        rep = Report()
        rep.extend(check_engine(self.engine))
        rep.extend(lint_behavior(self.behavior))
        if jaxpr:
            from repro.analysis import audit_engine
            rep.extend(audit_engine(self.engine))
        return rep

    # ------------------------------------------------------------------
    # Setup
    # ------------------------------------------------------------------
    def init(self, positions: np.ndarray, attrs: Dict[str, np.ndarray],
             seed: int = 0, **kwargs) -> "Simulation":
        """Distributed initialization (Engine.init_state) through the
        facade; returns self for chaining."""
        self.state = self.engine.init_state(positions, attrs, seed=seed,
                                            **kwargs)
        self._step_fn = None
        self._seg_fn = None
        return self

    def with_state(self, engine: Engine, state: SimState) -> "Simulation":
        """Adopt an existing (engine, state) pair — e.g. from
        ``elastic.elastic_restore_abm`` — keeping facade ownership of the
        mesh, step function, and scheduled operations."""
        self.engine = engine
        self.state = state
        self._step_fn = None
        self._seg_fn = None
        self._force_full = True
        return self

    def every(self, n: int, op: Callable, *, name: Optional[str] = None,
              pre: bool = False, record: bool = True) -> "Simulation":
        """Schedule ``op(sim)`` every ``n`` iterations (BioDynaMo's
        scheduled-operation list).  Non-None results are appended to
        ``self.series[name]``.  Returns self for chaining."""
        self._ops.append(Operation(
            fn=op, every=n, pre=pre, record=record,
            name=name or getattr(op, "__name__", f"op{len(self._ops)}")))
        return self

    # ------------------------------------------------------------------
    # Running
    # ------------------------------------------------------------------
    def _make_step(self) -> Callable:
        if self.engine.geom.n_devices == 1:
            return self.engine.make_local_step()
        return self.engine.make_sharded_step(self.mesh)

    def _make_seg(self) -> Callable:
        mesh = None if self.engine.geom.n_devices == 1 else self.mesh
        return self.engine.make_segment_runner(mesh)

    def _maybe_rebalance(self) -> None:
        rb = self.rebalancer
        if self._weighted:
            if self._last_step_s is None:
                # weighted checks only run on a fresh measurement; the
                # first sampled step lands right before the next due tick
                return
            rb.runtimes = estimate_device_runtimes(
                self.engine.geom, self.state, self._last_step_s)
        eng, state, resharded = rb.maybe_reshard(self.engine, self.state)
        if resharded:
            # the one place a re-shard surfaces: the facade swaps its own
            # engine/state/step/mesh, so callers never see a stale handle
            self.engine, self.state = eng, state
            self._step_fn = self._make_step() if self._step_fn else None
            self._seg_fn = None
            self._force_full = True
            # a narrower uneven slab can invalidate the one-hop contract
            # mid-run: re-gate the swapped-in geometry at the caller's mode
            if self._check != "off":
                from repro.analysis.contracts import enforce
                enforce(self.engine, mode=self._check)

    def _fused_span(self, tick: int, remaining: int, ops) -> int:
        """Longest segment starting at ``tick`` with no host-side control
        point in its interior: no pre-op due at an interior tick, no
        post-op due before the segment's last step, no delta full-refresh
        boundary past the first step, and no weighted-rebalance timing
        sample (which needs a single-step dispatch to measure)."""
        delta = self.engine.delta_cfg
        r = max(int(delta.refresh_interval), 1)
        rb = self.rebalancer
        weighted = self._weighted and rb is not None
        if weighted and rb.due(tick + 1):
            return 1  # this step is the timing sample: run it alone
        n = 1
        while n < remaining:
            t = tick + n
            if any(op.pre and op.due(t) for op in ops):
                break
            if any((not op.pre) and op.due(t - 1) for op in ops):
                break
            if delta.enabled and t % r == 0:
                break
            if weighted and rb.due(t + 1):
                break
            n += 1
        return n

    def run(self, steps: int,
            collect: Optional[Callable[[SimState], Any]] = None,
            fused: bool = True, fault_plan=None,
            supervised=None) -> "Simulation":
        """Drive ``steps`` iterations: scheduled pre-ops (re-shard checks),
        the compiled step honoring the delta refresh schedule, scheduled
        post-ops (reducers, checkpoints).  ``collect(state)`` is a
        convenience alias for ``sim.every(1, ...)`` recording under
        ``"collect"``.  Returns self.

        Steps between host-side control points (scheduled ops, refresh
        boundaries, rebalance checks) are fused into one compiled dispatch
        by the engine's segment runner; a per-step op (``every=1``) keeps
        the historical one-dispatch-per-step cadence.  ``fused=False``
        forces one dispatch per step (overhead benchmarks pin the
        dispatch cost with it).

        ``fault_plan`` (distributed.chaos.FaultPlan) injects scheduled
        faults at their absolute iterations; segments break at pending
        fault steps.  ``supervised`` (a launch.supervise.Supervised
        policy, or a checkpoint-directory shorthand) delegates the whole
        run to the supervisor: periodic verified checkpoints, and
        rollback-with-retry when a guard trips or the run raises —
        see docs/resilience.md.
        """
        if self.state is None:
            raise RuntimeError("Simulation.run() before init(): call "
                               "sim.init(positions, attrs) first")
        if supervised is not None:
            from repro.launch.supervise import Supervised, Supervisor
            if isinstance(supervised, str):
                supervised = Supervised(dir=supervised)
            if collect is not None:
                raise ValueError(
                    "collect= is not supported under supervised runs "
                    "(a rollback would double-record); use scheduled "
                    "ops via sim.every(...)")
            Supervisor(self, supervised, fault_plan=fault_plan).run(
                int(steps), fused=fused)
            return self
        ops = list(self._ops)
        if collect is not None:
            ops.append(Operation(fn=lambda sim: collect(sim.state),
                                 every=1, name="collect"))
        per_step = (self._step_fn is not None) or not fused
        if per_step and self._step_fn is None:
            self._step_fn = self._make_step()
        if not per_step and self._seg_fn is None:
            self._seg_fn = self._make_seg()
        delta = self.engine.delta_cfg
        refresh = max(int(delta.refresh_interval), 1)
        rb = self.rebalancer
        # Fixed-scale delta codec clip fallback (see Engine.drive): when
        # any device's cumulative clipped-delta count grows, the clipped
        # reconstruction is stale — force the next aura exchange full.
        track_clip = delta.enabled and delta.scale is not None
        clip_mark = codec_overflow_count(self.state) if track_clip else 0
        # Runtime health guards read at the same control points (the mark
        # pattern handles counter resets across re-shards/restores); the
        # check runs BEFORE post-ops so a scheduled checkpoint can never
        # capture state a guard just flagged.
        track_health = self.engine.guards.enabled
        hmark = health_counts(self.state) if track_health else None
        it0 = self.iteration if fault_plan is not None else 0

        done = 0
        while done < int(steps):
            tick = self._ticks
            for op in ops:
                if op.pre and op.due(tick):
                    self._run_op(op)
            if not per_step and self._seg_fn is None:
                self._seg_fn = self._make_seg()   # a pre-op re-sharded
            if fault_plan is not None:
                self.state, fired = fault_plan.fire(
                    self.engine, self.state, it0 + done)
                if fired:
                    self._force_full = True
            n = 1 if per_step else self._fused_span(
                tick, int(steps) - done, ops)
            if fault_plan is not None and not per_step:
                nf = fault_plan.next_step(after=it0 + done)
                if nf is not None:
                    n = max(1, min(n, nf - (it0 + done)))
            full = (self._force_full or not delta.enabled
                    or tick % refresh == 0)
            self._force_full = False
            # sample wall time for the step right before a weighted
            # rebalance check so the runtimes signal is one step fresh
            sample = (self._weighted and rb is not None and n == 1
                      and rb.due(tick + 1))
            t0 = time.perf_counter() if sample else 0.0
            if per_step:
                self.state = self._step_fn(self.state, full_halo=full)
            else:
                self.state = self._seg_fn(self.state, n, full_first=full)
            if sample:
                jax.block_until_ready(self.state.soa.valid)
                self._last_step_s = time.perf_counter() - t0
            if track_clip:
                cnt = codec_overflow_count(self.state)
                if cnt > clip_mark:
                    self._force_full = True
                    clip_mark = cnt
            if track_health:
                hmark, _ = check_health(self.engine.guards, self.state,
                                        hmark)
            for t in range(tick, tick + n):
                for op in ops:
                    if not op.pre and op.due(t):
                        self._run_op(op)
            self._ticks += n
            done += n
        return self

    def _run_op(self, op: Operation) -> None:
        value = op.fn(self)
        if op.record and value is not None:
            self.series.setdefault(op.name, []).append(value)

    def step(self) -> "Simulation":
        """Single iteration through the full scheduled pipeline."""
        return self.run(1)

    # ------------------------------------------------------------------
    # Checkpointing (on demand; scheduled saves go through Checkpoint)
    # ------------------------------------------------------------------
    def save(self, ckpt_dir: str, keep: int = 3) -> str:
        """One logical ABM checkpoint of the current engine+state."""
        from repro.distributed.checkpoint import save_abm
        return save_abm(ckpt_dir, self.iteration, self.engine, self.state,
                        keep=keep)

    @classmethod
    def restore(cls, ckpt_dir: str,
                behaviors: Union[Behavior, Sequence[Behavior]], *,
                step: Optional[int] = None,
                n_devices: Optional[int] = None,
                delta: Optional[DeltaConfig] = None,
                dt: Optional[float] = None,
                rebalance: Union[Rebalance, int, None] = None,
                checkpoint: Union[Checkpoint, str, None] = None,
                ownership: Optional[str] = None,
                check: str = "error",
                guards: Union[GuardConfig, str, None] = None,
                ) -> "Simulation":
        """Elastic restore: rebuild a facade from a logical checkpoint onto
        the current (possibly different) device count.  ``ownership``
        selects how the new device count is cut (``"equal"`` | ``"rcb"``);
        ``None`` keeps the checkpointed run's ownership mode."""
        from repro.distributed.elastic import elastic_restore_abm
        if not isinstance(behaviors, Behavior):
            behs = tuple(behaviors)
            behaviors = behs[0] if len(behs) == 1 else compose(*behs)
        engine, state, _ = elastic_restore_abm(
            ckpt_dir, behaviors, step=step, n_devices=n_devices,
            delta_cfg=delta, dt=dt, ownership=ownership)
        engine = dataclasses.replace(engine,
                                     guards=as_guard_config(guards))
        sim = cls(engine.geom, behaviors, delta=delta or engine.delta_cfg,
                  dt=engine.dt, rebalance=rebalance, checkpoint=checkpoint,
                  check=check, guards=guards)
        return sim.with_state(engine, state)
