"""Partitioning grid + uniform neighbor-search grid (NSG) with capacity-bounded binning.

Mirrors the paper's two-level decomposition (§2.1, §2.4.1):

* The **partitioning grid** divides the global simulation space into mutually-
  exclusive boxes, one block of boxes per device (MPI rank analogue).  The
  partitioning-box length is a configurable multiple of the NSG cell length
  (the paper's memory/granularity trade-off parameter).
* The **NSG** is a uniform grid whose cell edge is >= the maximum interaction
  radius, so neighbor search visits only the 3^D cell neighborhood.  BioDynaMo
  found a uniform grid beats trees for these workloads; we keep that choice.

All of this is expressed over an N-dimensional :class:`repro.core.domain.Domain`
(2-D sheets and 3-D tissues run through the same code paths): cell ids are
``ravel_multi_index``-style mixed-radix folds over the per-axis coordinates,
and ring handling loops over axes instead of naming them.

The binning pass replaces the paper's incremental NSG update: instead of
pointer-chasing updates we re-scatter agents into their (possibly new) cells
with a sort-based, capacity-bounded scatter — O(N log N) with fully static
shapes, the XLA-friendly formulation of "incremental add/remove/move".
"""

from __future__ import annotations

import math
import warnings
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent_soa import AgentSoA, POS, flat_view
from repro.core.domain import Domain

Array = jax.Array


def GridGeom(
    cell_size: float,
    interior: Tuple[int, int],
    mesh_shape: Tuple[int, int] = (1, 1),
    cap: int = 24,
    boundary: Union[str, Tuple[str, ...]] = "closed",
    box_factor: int = 1,
) -> Domain:
    """DEPRECATED 2-D constructor shim: build a :class:`Domain` from the
    historical ``GridGeom`` signature.  Use ``Domain`` directly — it takes
    the same keywords plus per-axis boundaries and 3-D interiors."""
    warnings.warn(
        "GridGeom is deprecated — use repro.core.Domain(cell_size=..., "
        "interior=..., mesh_shape=..., cap=..., boundary=...) which also "
        "supports 3-D interiors and per-axis boundary conditions",
        DeprecationWarning, stacklevel=2)
    return Domain(cell_size=cell_size, interior=interior,
                  mesh_shape=mesh_shape, cap=cap, boundary=boundary,
                  box_factor=box_factor)


def cell_of(geom: Domain, pos: Array, origin: Array,
            owned=None) -> Array:
    """Map world positions (N, ndim) to local cell coordinates (N, ndim)
    including the halo offset.

    Interior cells are [1, i_a] per axis; ring cells (0 or i_a + 1) hold
    agents that have left the device's region and must migrate.  Under
    uneven ownership ``owned`` carries the device's per-axis owned slab
    widths and the clamp resolves against the *owned* extent instead: the
    high migration ring sits at ``owned[a] + 1`` and padding cells beyond
    it never bin agents.
    """
    rel = (pos - origin[None, :]) / jnp.float32(geom.cell_size)
    c = jnp.floor(rel).astype(jnp.int32) + 1
    shape = geom.local_shape
    if owned is None:
        return jnp.stack(
            [jnp.clip(c[:, a], 0, shape[a] - 1) for a in range(geom.ndim)],
            axis=1)
    return jnp.stack(
        [jnp.clip(c[:, a], 0, jnp.asarray(owned[a], jnp.int32) + 1)
         for a in range(geom.ndim)],
        axis=1)


def ravel_cells(geom: Domain, cells: Array) -> Array:
    """Mixed-radix fold of per-axis cell coordinates (N, ndim) into flat
    row-major cell ids (N,) — ``ravel_multi_index`` over the local grid."""
    shape = geom.local_shape
    cid = cells[:, 0]
    for a in range(1, geom.ndim):
        cid = cid * shape[a] + cells[:, a]
    return cid


def bin_agents(
    geom: Domain,
    attrs: Dict[str, Array],
    valid: Array,
    origin: Array,
    owned=None,
) -> Tuple[AgentSoA, Array]:
    """Capacity-bounded scatter of flat agents (N, ...) into the local
    cell-slot grid ``local_shape + (K, ...)``.

    Returns the binned SoA and the number of agents dropped due to cell
    overflow (must be asserted == 0 by callers at configuration time; tests
    enforce this — it is the analogue of the paper's fixed transmission
    buffers being sized correctly).  ``owned`` (per-axis owned widths)
    switches the clamp to the uneven-ownership contract of
    :func:`cell_of`.
    """
    shape = geom.local_shape
    cap = geom.cap
    n = valid.shape[0]

    cell_id = ravel_cells(geom, cell_of(geom, attrs[POS], origin, owned))
    n_cells = math.prod(shape)
    # Invalid agents sort to a sentinel bucket past the last cell.
    key = jnp.where(valid, cell_id, n_cells)
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]

    # Rank of each agent within its cell run.
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_key[1:] != sorted_key[:-1]]
    )
    start_idx = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, jnp.int32(-1))
    )
    rank = idx - start_idx

    ok = (sorted_key < n_cells) & (rank < cap)
    dropped = jnp.sum((sorted_key < n_cells) & (rank >= cap))
    slot = jnp.where(ok, sorted_key * cap + rank, n_cells * cap)  # sentinel slot

    total = n_cells * cap
    out_attrs = {}
    for name, a in attrs.items():
        src = a[order]
        tgt = jnp.zeros((total + 1,) + a.shape[1:], dtype=a.dtype)
        tgt = tgt.at[slot].set(src)
        out_attrs[name] = tgt[:total].reshape(shape + (cap,) + a.shape[1:])
    v = jnp.zeros((total + 1,), jnp.bool_).at[slot].set(ok)
    soa = AgentSoA(attrs=out_attrs, valid=v[:total].reshape(shape + (cap,)))
    return soa, dropped


# Compiled binning entry point: Domain is a hashable frozen dataclass, so
# jit caches one executable per (geometry, input shapes) across *all*
# callers — the per-call ``jax.jit(partial(bin_agents, geom))`` idiom this
# replaces recompiled on every fresh closure.
bin_agents_jit = jax.jit(bin_agents, static_argnames=("geom",))


def rebin(geom: Domain, soa: AgentSoA, origin: Array,
          owned=None) -> Tuple[AgentSoA, Array]:
    attrs, valid = flat_view(soa)
    return bin_agents(geom, attrs, valid, origin, owned)


def interior_mask(geom: Domain) -> np.ndarray:
    m = np.zeros(geom.local_shape, dtype=bool)
    m[(slice(1, -1),) * geom.ndim] = True
    return m


def owned_mask(geom: Domain, owned) -> Array:
    """Boolean (local_shape) mask of this device's *owned* cells under
    uneven ownership: local cells ``[1, owned[a]]`` per axis.  Ring cells
    (index 0 and ``owned[a] + 1``) and padding cells (beyond the ring) are
    False.  ``owned`` entries may be traced scalars (from ``comm.coords``).
    """
    shape = geom.local_shape
    nd = geom.ndim
    m = jnp.ones((), jnp.bool_)
    for a, h in enumerate(shape):
        i = jnp.arange(h, dtype=jnp.int32).reshape(
            (h,) + (1,) * (nd - a - 1))
        w = jnp.asarray(owned[a], jnp.int32)
        m = m & (i >= 1) & (i <= w)
    return jnp.broadcast_to(m, shape)


def mask_unowned(soa: AgentSoA, geom: Domain, owned) -> AgentSoA:
    """Uneven-ownership analogue of :func:`clear_ring`: invalidate every
    slot outside the owned region — the rebuilt-from-scratch aura ring at
    ``owned[a] + 1`` / 0 *and* the padding cells beyond it, which must
    never hold agents."""
    m = owned_mask(geom, owned)
    return soa.replace(valid=soa.valid & m[..., None])


def ring_index(axis: int, index) -> Tuple:
    """Indexing tuple selecting one cell-hyperplane along a grid axis."""
    return (slice(None),) * axis + (index,)


def clear_ring(soa: AgentSoA) -> AgentSoA:
    """Invalidate all halo-ring slots (aura is rebuilt from scratch each
    iteration, exactly as in the paper §2.2.1 'Deallocation')."""
    v = soa.valid
    for axis in range(v.ndim - 1):   # every grid axis; last dim is the slot
        v = v.at[ring_index(axis, 0)].set(False)
        v = v.at[ring_index(axis, -1)].set(False)
    return soa.replace(valid=v)
