"""Partitioning grid + uniform neighbor-search grid (NSG) with capacity-bounded binning.

Mirrors the paper's two-level decomposition (§2.1, §2.4.1):

* The **partitioning grid** divides the global simulation space into mutually-
  exclusive boxes, one block of boxes per device (MPI rank analogue).  The
  partitioning-box length is a configurable multiple of the NSG cell length
  (the paper's memory/granularity trade-off parameter).
* The **NSG** is a uniform grid whose cell edge is >= the maximum interaction
  radius, so neighbor search visits only the 3x3 cell neighborhood.  BioDynaMo
  found a uniform grid beats trees for these workloads; we keep that choice.

The binning pass replaces the paper's incremental NSG update: instead of
pointer-chasing updates we re-scatter agents into their (possibly new) cells
with a sort-based, capacity-bounded scatter — O(N log N) with fully static
shapes, the XLA-friendly formulation of "incremental add/remove/move".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent_soa import AgentSoA, POS, flat_view, from_flat

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GridGeom:
    """Static geometry of one device's local grid.

    Attributes:
      cell_size: NSG cell edge length (>= max interaction radius).
      interior: (ix, iy) interior cell counts per device.
      mesh_shape: (mx, my) spatial device mesh.
      cap: per-cell slot capacity K.
      boundary: "closed" | "toroidal" — SpaceBoundaryCondition analogue.
      box_factor: partitioning-box length as a multiple of the NSG cell
        (paper §2.4.1); load-balancing granularity only.
    """

    cell_size: float
    interior: Tuple[int, int]
    mesh_shape: Tuple[int, int]
    cap: int
    boundary: str = "closed"
    box_factor: int = 1

    @property
    def local_shape(self) -> Tuple[int, int]:
        return self.interior[0] + 2, self.interior[1] + 2  # + halo ring

    @property
    def global_cells(self) -> Tuple[int, int]:
        return (
            self.interior[0] * self.mesh_shape[0],
            self.interior[1] * self.mesh_shape[1],
        )

    @property
    def domain_size(self) -> Tuple[float, float]:
        gx, gy = self.global_cells
        return gx * self.cell_size, gy * self.cell_size

    @property
    def box_grid(self) -> Tuple[int, int]:
        """Global partitioning-box grid (paper §2.4.1): the granularity at
        which the load-balance planners reason, ``box_factor`` NSG cells per
        box edge."""
        gx, gy = self.global_cells
        if gx % self.box_factor or gy % self.box_factor:
            raise ValueError(
                f"box_factor {self.box_factor} must divide the global cell "
                f"grid {(gx, gy)}")
        return gx // self.box_factor, gy // self.box_factor

    def with_mesh_shape(self, mesh_shape: Tuple[int, int]) -> "GridGeom":
        """Same global domain re-partitioned over a different device mesh —
        the geometry half of a re-shard (core.reshard).  The global cell grid
        is invariant; only the per-device interior block changes."""
        gx, gy = self.global_cells
        mx, my = mesh_shape
        if gx % mx or gy % my:
            raise ValueError(
                f"mesh {mesh_shape} does not divide the global cell grid "
                f"{(gx, gy)}")
        return dataclasses.replace(
            self, mesh_shape=(mx, my), interior=(gx // mx, gy // my))

    def device_origin(self, coords: Tuple[Array, Array]) -> Array:
        """World-space origin of the device's interior region."""
        ox = coords[0] * self.interior[0] * self.cell_size
        oy = coords[1] * self.interior[1] * self.cell_size
        return jnp.stack([ox, oy]).astype(jnp.float32)


def cell_of(geom: GridGeom, pos: Array, origin: Array) -> Tuple[Array, Array]:
    """Map world positions (N, 2) to local cell coordinates incl. halo offset.

    Interior cells are [1, ix] x [1, iy]; ring cells (0 or ix+1 / iy+1) hold
    agents that have left the device's region and must migrate.
    """
    rel = (pos - origin[None, :]) / jnp.float32(geom.cell_size)
    c = jnp.floor(rel).astype(jnp.int32) + 1
    hx, hy = geom.local_shape
    cx = jnp.clip(c[:, 0], 0, hx - 1)
    cy = jnp.clip(c[:, 1], 0, hy - 1)
    return cx, cy


def bin_agents(
    geom: GridGeom,
    attrs: Dict[str, Array],
    valid: Array,
    origin: Array,
) -> Tuple[AgentSoA, Array]:
    """Capacity-bounded scatter of flat agents (N, ...) into (hx, hy, K, ...).

    Returns the binned SoA and the number of agents dropped due to cell
    overflow (must be asserted == 0 by callers at configuration time; tests
    enforce this — it is the analogue of the paper's fixed transmission
    buffers being sized correctly).
    """
    hx, hy = geom.local_shape
    cap = geom.cap
    n = valid.shape[0]

    cx, cy = cell_of(geom, attrs[POS], origin)
    cell_id = cx * hy + cy
    n_cells = hx * hy
    # Invalid agents sort to a sentinel bucket past the last cell.
    key = jnp.where(valid, cell_id, n_cells)
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]

    # Rank of each agent within its cell run.
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_key[1:] != sorted_key[:-1]]
    )
    start_idx = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, jnp.int32(-1))
    )
    rank = idx - start_idx

    ok = (sorted_key < n_cells) & (rank < cap)
    dropped = jnp.sum((sorted_key < n_cells) & (rank >= cap))
    slot = jnp.where(ok, sorted_key * cap + rank, n_cells * cap)  # sentinel slot

    total = n_cells * cap
    out_attrs = {}
    for name, a in attrs.items():
        src = a[order]
        tgt = jnp.zeros((total + 1,) + a.shape[1:], dtype=a.dtype)
        tgt = tgt.at[slot].set(src)
        out_attrs[name] = tgt[:total].reshape((hx, hy, cap) + a.shape[1:])
    v = jnp.zeros((total + 1,), jnp.bool_).at[slot].set(ok)
    soa = AgentSoA(attrs=out_attrs, valid=v[:total].reshape((hx, hy, cap)))
    return soa, dropped


# Compiled binning entry point: GridGeom is a hashable frozen dataclass, so
# jit caches one executable per (geometry, input shapes) across *all*
# callers — the per-call ``jax.jit(partial(bin_agents, geom))`` idiom this
# replaces recompiled on every fresh closure.
bin_agents_jit = jax.jit(bin_agents, static_argnames=("geom",))


def rebin(geom: GridGeom, soa: AgentSoA, origin: Array) -> Tuple[AgentSoA, Array]:
    attrs, valid = flat_view(soa)
    return bin_agents(geom, attrs, valid, origin)


def interior_mask(geom: GridGeom) -> np.ndarray:
    hx, hy = geom.local_shape
    m = np.zeros((hx, hy), dtype=bool)
    m[1:-1, 1:-1] = True
    return m


def clear_ring(soa: AgentSoA) -> AgentSoA:
    """Invalidate all halo-ring slots (aura is rebuilt from scratch each
    iteration, exactly as in the paper §2.2.1 'Deallocation')."""
    v = soa.valid
    v = v.at[0, :, :].set(False)
    v = v.at[-1, :, :].set(False)
    v = v.at[:, 0, :].set(False)
    v = v.at[:, -1, :].set(False)
    return soa.replace(valid=v)
