"""Neighbor iteration over the uniform NSG (pure-jnp reference path).

For each interior cell, gathers the 3x3 cell neighborhood into a (9*K,) slot
axis and applies a broadcastable pair kernel between the cell's K agents and
the 9K candidates, masking invalid slots, self-pairs (by global ID), and
pairs beyond the interaction radius.  This is the oracle for the Pallas
``neighbor_interaction`` kernel in repro.kernels.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.agent_soa import AgentSoA, GID_COUNT, GID_RANK, POS
from repro.core.grid import GridGeom

Array = jax.Array

OFFSETS = [(-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 0), (0, 1),
           (1, -1), (1, 0), (1, 1)]

# pair_fn(attrs_i, attrs_j, disp, dist2, params) -> dict of contributions,
# each broadcastable over the pair axes (..., K, 9K) with trailing dims.
PairFn = Callable[[Dict[str, Array], Dict[str, Array], Array, Array, dict],
                  Dict[str, Array]]


def gather_neighborhood(geom: GridGeom, soa: AgentSoA, names: Tuple[str, ...]):
    """Stack the 9-cell neighborhood of every interior cell.

    Returns (self_attrs, nbr_attrs, self_valid, nbr_valid) where self arrays
    have shape (ix, iy, K, ...) and nbr arrays (ix, iy, 9K, ...).
    """
    hx, hy = geom.local_shape
    ix, iy = geom.interior
    k = geom.cap
    need = set(names) | {POS, GID_RANK, GID_COUNT}

    self_attrs = {n: soa.attrs[n][1:hx - 1, 1:hy - 1] for n in need}
    self_valid = soa.valid[1:hx - 1, 1:hy - 1]

    nbr_attrs: Dict[str, Array] = {}
    for n in need:
        a = soa.attrs[n]
        slabs = [a[1 + dx:hx - 1 + dx, 1 + dy:hy - 1 + dy] for dx, dy in OFFSETS]
        stacked = jnp.stack(slabs, axis=2)  # (ix, iy, 9, K, ...)
        nbr_attrs[n] = stacked.reshape((ix, iy, 9 * k) + a.shape[3:])
    v = soa.valid
    slabs = [v[1 + dx:hx - 1 + dx, 1 + dy:hy - 1 + dy] for dx, dy in OFFSETS]
    nbr_valid = jnp.stack(slabs, axis=2).reshape((ix, iy, 9 * k))
    return self_attrs, nbr_attrs, self_valid, nbr_valid


def min_image(disp: Array, geom: GridGeom) -> Array:
    if geom.boundary != "toroidal":
        return disp
    lx, ly = geom.domain_size
    box = jnp.asarray([lx, ly], dtype=disp.dtype)
    return disp - box * jnp.round(disp / box)


def pair_accumulate(
    geom: GridGeom,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
) -> Dict[str, Array]:
    """Sum pair-kernel contributions over each interior agent's neighbors.

    Returns a dict of accumulators with shape (ix, iy, K, *trailing).
    """
    self_a, nbr_a, self_v, nbr_v = gather_neighborhood(geom, soa, pair_attrs)

    # Broadcast views: i -> (..., K, 1, t), j -> (..., 1, 9K, t)
    def bi(a):
        return a[:, :, :, None]

    def bj(a):
        return a[:, :, None, :]

    attrs_i = {n: bi(a) for n, a in self_a.items()}
    attrs_j = {n: bj(a) for n, a in nbr_a.items()}

    disp = min_image(attrs_j[POS] - attrs_i[POS], geom)  # (ix,iy,K,9K,2)
    dist2 = jnp.sum(disp * disp, axis=-1)

    same = (attrs_i[GID_RANK][..., ] == attrs_j[GID_RANK]) & (
        attrs_i[GID_COUNT] == attrs_j[GID_COUNT]
    )
    mask = (
        bi(self_v)
        & bj(nbr_v)
        & ~same
        & (dist2 <= jnp.float32(radius * radius))
    )

    contribs = pair_fn(attrs_i, attrs_j, disp, dist2, params)

    out: Dict[str, Array] = {}
    for name, c in contribs.items():
        m = mask
        while m.ndim < c.ndim:
            m = m[..., None]
        out[name] = jnp.sum(jnp.where(m, c, jnp.zeros_like(c)), axis=3)
    return out
