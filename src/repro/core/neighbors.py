"""Neighbor iteration over the uniform NSG — the engine's interaction sweep.

Three interchangeable backends compute the same per-agent accumulator sums
(selected per engine via ``Engine.sweep_backend`` / the ``Simulation``
``sweep_backend`` kwarg, see docs/performance.md), over 2-D or 3-D domains
(the cell neighborhood is the ``3**ndim`` offset stencil of the Domain):

* ``"reference"`` — :func:`pair_accumulate`: gathers the 3^D cell
  neighborhood of every interior cell into a (3^D K,) slot axis and applies
  the pair kernel over the full (K, 3^D K) pair block.  Simple, obviously
  correct, and the parity oracle for the other two — but it materializes a
  3^D-times copy of every attribute per sweep.
* ``"tiled"`` — :func:`pair_accumulate_tiled`: loops over the 3^D cell
  offsets with (K, K) pair tiles built from plain array *slices*, so no
  neighborhood gather is ever materialized and XLA fuses each tile's
  slice->compute->mask chain.  This is the fast path on CPU/GPU backends.
* ``"pallas"`` — the generic Pallas kernel factory in
  :mod:`repro.kernels.neighbor_interaction`: the gather stays in XLA (cheap
  data movement), and one VMEM-resident program per block of cells evaluates
  the full pair block with VPU-vectorized masked arithmetic — the TPU path
  for 2-D *and* 3-D domains (the factory flattens the cell grid, so the
  27-offset stencil only widens the neighborhood slab to 27K).

All backends share the masking semantics: invalid slots, self-pairs (by
global id), and pairs beyond the interaction radius contribute zero.
``tiled`` agrees with ``reference`` to float ulp (XLA fuses the two graphs
differently, so FMA contraction can differ in the last bit); integer-valued
accumulators (counts) agree exactly.  ``pallas`` agrees within the usual
kernel tolerance.  tests/test_sweep.py pins all three for every bundled sim
behavior and for composed stacks; tests/test_domain.py pins the 3-D parity.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.agent_soa import AgentSoA, GID_COUNT, GID_RANK, POS
from repro.core.domain import Domain

Array = jax.Array


def offsets_for(ndim: int) -> Tuple[Tuple[int, ...], ...]:
    """The 3^ndim cell-offset stencil, in row-major (reference) order."""
    return tuple(itertools.product((-1, 0, 1), repeat=ndim))


# Historical 2-D constant (row-major order matches offsets_for(2)).
OFFSETS = list(offsets_for(2))

SWEEP_BACKENDS = ("reference", "tiled", "pallas")

# pair_fn(attrs_i, attrs_j, disp, dist2, params) -> dict of contributions,
# each broadcastable over the pair axes (..., K, 3^D K) with trailing dims.
PairFn = Callable[[Dict[str, Array], Dict[str, Array], Array, Array, dict],
                  Dict[str, Array]]


def resolve_sweep_backend(backend: str = "auto", ndim: int = 2) -> str:
    """Resolve the ``"auto"`` sweep backend for the current JAX backend:
    the fused Pallas kernel on TPU (2-D *and* 3-D domains — the kernel
    factory flattens cell blocks, so the ``3**ndim`` stencil only changes
    the neighborhood slab width), the tiled XLA sweep everywhere else.

    ``ndim`` is kept for call-site compatibility: resolution has been
    dimension-independent since the factory gained 3-D blocks (it would
    matter again only if a dimensionality ever lost its kernel path)."""
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "tiled"
    if backend not in SWEEP_BACKENDS:
        raise ValueError(
            f"unknown sweep backend {backend!r}; expected 'auto' or one of "
            f"{SWEEP_BACKENDS}")
    return backend


def _interior(geom: Domain):
    return tuple(slice(1, h - 1) for h in geom.local_shape)


def gather_neighborhood(geom: Domain, soa: AgentSoA, names: Tuple[str, ...]):
    """Stack the 3^D-cell neighborhood of every interior cell.

    Returns (self_attrs, nbr_attrs, self_valid, nbr_valid) where self arrays
    have shape (*interior, K, ...) and nbr arrays (*interior, 3^D K, ...).
    """
    shape = geom.local_shape
    interior = geom.interior
    nd = geom.ndim
    k = geom.cap
    offs = offsets_for(nd)
    need = set(names) | {POS, GID_RANK, GID_COUNT}
    isl = _interior(geom)

    def off_slice(off):
        return tuple(slice(1 + o, h - 1 + o) for o, h in zip(off, shape))

    self_attrs = {n: soa.attrs[n][isl] for n in need}
    self_valid = soa.valid[isl]

    nbr_attrs: Dict[str, Array] = {}
    for n in need:
        a = soa.attrs[n]
        slabs = [a[off_slice(off)] for off in offs]
        stacked = jnp.stack(slabs, axis=nd)  # (*interior, 3^D, K, ...)
        nbr_attrs[n] = stacked.reshape(
            interior + (len(offs) * k,) + a.shape[nd + 1:])
    v = soa.valid
    slabs = [v[off_slice(off)] for off in offs]
    nbr_valid = jnp.stack(slabs, axis=nd).reshape(
        interior + (len(offs) * k,))
    return self_attrs, nbr_attrs, self_valid, nbr_valid


def min_image(disp: Array, geom: Domain) -> Array:
    """Per-axis minimum-image convention: wrap displacement components of
    toroidal axes only."""
    tor = geom.toroidal
    if not any(tor):
        return disp
    box = jnp.asarray(geom.domain_size, dtype=disp.dtype)
    wrapped = disp - box * jnp.round(disp / box)
    if all(tor):
        return wrapped
    return jnp.where(jnp.asarray(tor), wrapped, disp)


def pair_accumulate(
    geom: Domain,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
) -> Dict[str, Array]:
    """Sum pair-kernel contributions over each interior agent's neighbors.

    Returns a dict of accumulators with shape (*interior, K, *trailing).
    """
    nd = geom.ndim
    self_a, nbr_a, self_v, nbr_v = gather_neighborhood(geom, soa, pair_attrs)

    # Broadcast views: i -> (..., K, 1, t), j -> (..., 1, 3^D K, t)
    def bi(a):
        return jnp.expand_dims(a, nd + 1)

    def bj(a):
        return jnp.expand_dims(a, nd)

    attrs_i = {n: bi(a) for n, a in self_a.items()}
    attrs_j = {n: bj(a) for n, a in nbr_a.items()}

    disp = min_image(attrs_j[POS] - attrs_i[POS], geom)  # (..., K, 3^D K, D)
    dist2 = jnp.sum(disp * disp, axis=-1)

    same = (attrs_i[GID_RANK] == attrs_j[GID_RANK]) & (
        attrs_i[GID_COUNT] == attrs_j[GID_COUNT]
    )
    mask = (
        bi(self_v)
        & bj(nbr_v)
        & ~same
        & (dist2 <= jnp.float32(radius * radius))
    )

    contribs = pair_fn(attrs_i, attrs_j, disp, dist2, params)

    out: Dict[str, Array] = {}
    for name, c in contribs.items():
        m = mask
        while m.ndim < c.ndim:
            m = m[..., None]
        out[name] = jnp.sum(jnp.where(m, c, jnp.zeros_like(c)), axis=nd + 1)
    return out


def pair_accumulate_tiled(
    geom: Domain,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
) -> Dict[str, Array]:
    """Offset-tiled sweep: 3^D (*interior, K, K) pair tiles instead of one
    (*interior, K, 3^D K) block over a materialized neighborhood gather.

    Every neighbor view is a plain slice of the resident SoA, so XLA fuses
    slice -> pair math -> mask per tile with no gather copies; the per-tile
    contributions are stacked along the j axis in the reference's offset
    order and reduced with the same single ``sum`` so the accumulation
    order matches :func:`pair_accumulate` exactly (agreement is to float
    ulp — fusion differences can flip the last bit of FMA chains).
    """
    shape = geom.local_shape
    nd = geom.ndim
    need = set(pair_attrs) | {POS, GID_RANK, GID_COUNT}
    isl = _interior(geom)

    # i views: (*interior, K, 1, t)
    attrs_i = {n: jnp.expand_dims(soa.attrs[n][isl], nd + 1) for n in need}
    vi = jnp.expand_dims(soa.valid[isl], nd + 1)
    r2 = jnp.float32(radius * radius)

    tiles: Dict[str, list] = {}
    for off in offsets_for(nd):
        osl = tuple(slice(1 + o, h - 1 + o) for o, h in zip(off, shape))
        # j views for this offset: (*interior, 1, K, t) slices — no copies
        nbr = {n: jnp.expand_dims(soa.attrs[n][osl], nd) for n in need}
        nv = jnp.expand_dims(soa.valid[osl], nd)
        disp = min_image(nbr[POS] - attrs_i[POS], geom)  # (..., K, K, D)
        dist2 = jnp.sum(disp * disp, axis=-1)
        same = (attrs_i[GID_RANK] == nbr[GID_RANK]) & (
            attrs_i[GID_COUNT] == nbr[GID_COUNT])
        mask = vi & nv & ~same & (dist2 <= r2)
        contribs = pair_fn(attrs_i, nbr, disp, dist2, params)
        for name, c in contribs.items():
            m = mask
            while m.ndim < c.ndim:
                m = m[..., None]
            tiles.setdefault(name, []).append(
                jnp.where(m, c, jnp.zeros_like(c)))

    out: Dict[str, Array] = {}
    for name, parts in tiles.items():
        # (*interior,K,K,t) tiles -> (*interior,K,3^D,K,t) ->
        # (*interior,K,3^D K,t): the j axis ends up in the reference's
        # offset-major order before the one-shot reduction.
        shape_b = jnp.broadcast_shapes(*[p.shape for p in parts])
        parts = [jnp.broadcast_to(p, shape_b) for p in parts]
        stacked = jnp.stack(parts, axis=nd + 1)
        flat = stacked.reshape(
            shape_b[:nd + 1] + (len(parts) * shape_b[nd + 1],)
            + shape_b[nd + 2:])
        out[name] = jnp.sum(flat, axis=nd + 1)
    return out


def pair_accumulate_pallas(
    geom: Domain,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
    *,
    block_cells: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Dict[str, Array]:
    """Pallas-kernel sweep (2-D and 3-D domains): XLA builds the
    neighborhood gather (pure data movement), then one fused kernel program
    per block of cells evaluates every pair kernel for its (BC, K) x
    (BC, 3^D K) slabs in VMEM — the kernel factory flattens the interior
    cell grid, so dimensionality only changes the neighborhood slab width
    (9K -> 27K) and the ``pos`` trailing dim.

    ``interpret=None`` auto-detects from the JAX backend
    (``kernels.ops.use_interpret``); on TPU the same kernel compiles to
    Mosaic.
    """
    import math as _math

    from repro.kernels import ops as kops

    nd = geom.ndim
    k = geom.cap
    c = _math.prod(geom.interior)
    nk = (3 ** nd) * k
    self_a, nbr_a, self_v, nbr_v = gather_neighborhood(geom, soa, pair_attrs)
    flat_i = {n: a.reshape((c, k) + a.shape[nd + 1:])
              for n, a in self_a.items()}
    flat_j = {n: a.reshape((c, nk) + a.shape[nd + 1:])
              for n, a in nbr_a.items()}
    tor = geom.toroidal
    box = (tuple(L if t else None
                 for L, t in zip(geom.domain_size, tor))
           if any(tor) else None)
    acc = kops.neighborhood_pair_sweep(
        flat_i, flat_j, self_v.reshape((c, k)), nbr_v.reshape((c, nk)),
        pair_fn=pair_fn, radius=radius, params=params, box=box,
        block_cells=block_cells, interpret=interpret)
    return {n: a.reshape(geom.interior + (k,) + a.shape[2:])
            for n, a in acc.items()}


def sweep_accumulate(
    geom: Domain,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
    *,
    backend: str = "reference",
) -> Dict[str, Array]:
    """Backend-dispatched neighborhood sweep (the engine's entry point)."""
    backend = resolve_sweep_backend(backend, geom.ndim)
    if backend == "reference":
        return pair_accumulate(geom, soa, pair_fn, pair_attrs, radius, params)
    if backend == "tiled":
        return pair_accumulate_tiled(
            geom, soa, pair_fn, pair_attrs, radius, params)
    return pair_accumulate_pallas(
        geom, soa, pair_fn, pair_attrs, radius, params)


# ---------------------------------------------------------------------------
# Overlapped interior/boundary split (communication hiding)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _SlabGeom:
    """Domain stand-in for a face slab: every backend reads exactly these
    attributes, so the unmodified sweep machinery runs on a sub-block of
    the local grid (the 3-plane band around a boundary hyperplane)."""
    local_shape: Tuple[int, ...]
    interior: Tuple[int, ...]
    ndim: int
    cap: int
    toroidal: Tuple[bool, ...]
    domain_size: Tuple[float, ...]


def _slab_soa(soa: AgentSoA, starts, lengths) -> AgentSoA:
    """Dynamic-slice a grid-aligned sub-block out of the SoA (``starts``
    may be traced along the uneven-ownership axis)."""
    nd = len(lengths)
    st = [jnp.asarray(s, jnp.int32) for s in starts]

    def sl(a):
        full = st + [jnp.int32(0)] * (a.ndim - nd)
        size = tuple(lengths) + a.shape[nd:]
        return jax.lax.dynamic_slice(a, full, size)

    return AgentSoA(attrs={n: sl(v) for n, v in soa.attrs.items()},
                    valid=sl(soa.valid))


def _sweep_dispatch(geom, soa, pair_fn, pair_attrs, radius, params, backend):
    if backend == "reference":
        return pair_accumulate(geom, soa, pair_fn, pair_attrs, radius, params)
    if backend == "tiled":
        return pair_accumulate_tiled(
            geom, soa, pair_fn, pair_attrs, radius, params)
    return pair_accumulate_pallas(
        geom, soa, pair_fn, pair_attrs, radius, params)


def _face_sweep(
    geom: Domain,
    soa_post: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
    backend: str,
    axis: int,
    face_idx,
) -> Dict[str, Array]:
    """Recompute the accumulators of the 1-thick interior hyperplane at
    local index ``face_idx`` along ``axis`` from the post-exchange SoA.

    The 3-plane band ``[face_idx - 1, face_idx + 1]`` along ``axis`` (full
    padded extent on every other axis) is the complete 3^D stencil support
    of the face, so the unmodified backend sweep over the band — with the
    band's own 1-plane "interior" — evaluates exactly the per-cell
    reduction the monolithic sweep would, restricted to the face.
    ``face_idx`` may be traced (the uneven-ownership boundary sits at the
    device's owned extent)."""
    nd = geom.ndim
    shape = geom.local_shape
    starts = [0] * nd
    starts[axis] = (face_idx - 1 if isinstance(face_idx, int)
                    else jnp.asarray(face_idx, jnp.int32) - 1)
    lengths = list(shape)
    lengths[axis] = 3
    band = _slab_soa(soa_post, starts, lengths)
    vgeom = _SlabGeom(
        local_shape=tuple(lengths),
        interior=tuple(h - 2 for h in lengths),
        ndim=nd, cap=geom.cap, toroidal=geom.toroidal,
        domain_size=geom.domain_size)
    return _sweep_dispatch(
        vgeom, band, pair_fn, pair_attrs, radius, params, backend)


def sweep_accumulate_overlapped(
    geom: Domain,
    soa_pre: AgentSoA,
    soa_post: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
    *,
    backend: str = "reference",
    owned=None,
) -> Dict[str, Array]:
    """Interior/boundary split sweep for communication hiding.

    ``soa_pre`` is the SoA *before* the aura exchange (ring invalidated by
    ``clear_ring``/``mask_unowned``) and ``soa_post`` the SoA after it.
    The interior pass runs the full monolithic sweep on ``soa_pre`` — it
    has no data dependence on the exchange, so XLA schedules the
    ``ppermute`` collectives concurrently with it.  Deep cells (local
    index ``[2, h-3]`` per axis) never read a ring hyperplane, and the
    exchange writes *only* ring hyperplanes, so their interior-pass values
    are bit-exact already.  The boundary pass then recomputes each
    ring-adjacent face (index 1, and ``h-2`` — or the owned extent under
    uneven ownership) from ``soa_post`` and *overwrites* those acc planes.
    The overwrite is idempotent at corners: every face writes a cell's
    full correct value, so overlapping faces agree and nothing double
    counts.  Per backend the result matches the monolithic sweep on
    ``soa_post`` bit-for-bit at every owned cell (and at every interior
    cell on the equal split, where the faces cover all ring-adjacent
    planes).
    """
    backend = resolve_sweep_backend(backend, geom.ndim)
    acc = _sweep_dispatch(
        geom, soa_pre, pair_fn, pair_attrs, radius, params, backend)
    nd = geom.ndim
    for axis in range(nd):
        lo = 1
        hi = (geom.local_shape[axis] - 2 if owned is None
              else jnp.asarray(owned[axis], jnp.int32))
        faces = [lo, hi]
        for face_idx in faces:
            facc = _face_sweep(
                geom, soa_post, pair_fn, pair_attrs, radius, params,
                backend, axis, face_idx)
            starts = [0] * nd
            starts[axis] = (face_idx - 1 if isinstance(face_idx, int)
                            else jnp.asarray(face_idx, jnp.int32) - 1)
            new_acc = {}
            for name, a in acc.items():
                st = [jnp.asarray(s, jnp.int32) for s in starts]
                st = st + [jnp.int32(0)] * (a.ndim - nd)
                new_acc[name] = jax.lax.dynamic_update_slice(
                    a, facc[name].astype(a.dtype), st)
            acc = new_acc
    return acc
