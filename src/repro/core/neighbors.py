"""Neighbor iteration over the uniform NSG — the engine's interaction sweep.

Three interchangeable backends compute the same per-agent accumulator sums
(selected per engine via ``Engine.sweep_backend`` / the ``Simulation``
``sweep_backend`` kwarg, see docs/performance.md):

* ``"reference"`` — :func:`pair_accumulate`: gathers the 3x3 cell
  neighborhood of every interior cell into a (9K,) slot axis and applies the
  pair kernel over the full (K, 9K) pair block.  Simple, obviously correct,
  and the parity oracle for the other two — but it materializes a 9x copy of
  every attribute per sweep.
* ``"tiled"`` — :func:`pair_accumulate_tiled`: loops over the nine cell
  offsets with (K, K) pair tiles built from plain array *slices*, so no 9x
  neighborhood gather is ever materialized and XLA fuses each tile's
  slice->compute->mask chain.  This is the fast path on CPU/GPU backends.
* ``"pallas"`` — the generic Pallas kernel factory in
  :mod:`repro.kernels.neighbor_interaction`: the gather stays in XLA (cheap
  data movement), and one VMEM-resident program per block of cells evaluates
  the full pair block with VPU-vectorized masked arithmetic — the TPU path.

All backends share the masking semantics: invalid slots, self-pairs (by
global id), and pairs beyond the interaction radius contribute zero.
``tiled`` agrees with ``reference`` to float ulp (XLA fuses the two graphs
differently, so FMA contraction can differ in the last bit); integer-valued
accumulators (counts) agree exactly.  ``pallas`` agrees within the usual
kernel tolerance.  tests/test_sweep.py pins all three for every bundled sim
behavior and for composed stacks.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.agent_soa import AgentSoA, GID_COUNT, GID_RANK, POS
from repro.core.grid import GridGeom

Array = jax.Array

OFFSETS = [(-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 0), (0, 1),
           (1, -1), (1, 0), (1, 1)]

SWEEP_BACKENDS = ("reference", "tiled", "pallas")

# pair_fn(attrs_i, attrs_j, disp, dist2, params) -> dict of contributions,
# each broadcastable over the pair axes (..., K, 9K) with trailing dims.
PairFn = Callable[[Dict[str, Array], Dict[str, Array], Array, Array, dict],
                  Dict[str, Array]]


def resolve_sweep_backend(backend: str = "auto") -> str:
    """Resolve the ``"auto"`` sweep backend for the current JAX backend:
    the Pallas kernel on TPU, the tiled XLA sweep everywhere else."""
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "tiled"
    if backend not in SWEEP_BACKENDS:
        raise ValueError(
            f"unknown sweep backend {backend!r}; expected 'auto' or one of "
            f"{SWEEP_BACKENDS}")
    return backend


def gather_neighborhood(geom: GridGeom, soa: AgentSoA, names: Tuple[str, ...]):
    """Stack the 9-cell neighborhood of every interior cell.

    Returns (self_attrs, nbr_attrs, self_valid, nbr_valid) where self arrays
    have shape (ix, iy, K, ...) and nbr arrays (ix, iy, 9K, ...).
    """
    hx, hy = geom.local_shape
    ix, iy = geom.interior
    k = geom.cap
    need = set(names) | {POS, GID_RANK, GID_COUNT}

    self_attrs = {n: soa.attrs[n][1:hx - 1, 1:hy - 1] for n in need}
    self_valid = soa.valid[1:hx - 1, 1:hy - 1]

    nbr_attrs: Dict[str, Array] = {}
    for n in need:
        a = soa.attrs[n]
        slabs = [a[1 + dx:hx - 1 + dx, 1 + dy:hy - 1 + dy] for dx, dy in OFFSETS]
        stacked = jnp.stack(slabs, axis=2)  # (ix, iy, 9, K, ...)
        nbr_attrs[n] = stacked.reshape((ix, iy, 9 * k) + a.shape[3:])
    v = soa.valid
    slabs = [v[1 + dx:hx - 1 + dx, 1 + dy:hy - 1 + dy] for dx, dy in OFFSETS]
    nbr_valid = jnp.stack(slabs, axis=2).reshape((ix, iy, 9 * k))
    return self_attrs, nbr_attrs, self_valid, nbr_valid


def min_image(disp: Array, geom: GridGeom) -> Array:
    if geom.boundary != "toroidal":
        return disp
    lx, ly = geom.domain_size
    box = jnp.asarray([lx, ly], dtype=disp.dtype)
    return disp - box * jnp.round(disp / box)


def pair_accumulate(
    geom: GridGeom,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
) -> Dict[str, Array]:
    """Sum pair-kernel contributions over each interior agent's neighbors.

    Returns a dict of accumulators with shape (ix, iy, K, *trailing).
    """
    self_a, nbr_a, self_v, nbr_v = gather_neighborhood(geom, soa, pair_attrs)

    # Broadcast views: i -> (..., K, 1, t), j -> (..., 1, 9K, t)
    def bi(a):
        return jnp.expand_dims(a, 3)

    def bj(a):
        return jnp.expand_dims(a, 2)

    attrs_i = {n: bi(a) for n, a in self_a.items()}
    attrs_j = {n: bj(a) for n, a in nbr_a.items()}

    disp = min_image(attrs_j[POS] - attrs_i[POS], geom)  # (ix,iy,K,9K,2)
    dist2 = jnp.sum(disp * disp, axis=-1)

    same = (attrs_i[GID_RANK] == attrs_j[GID_RANK]) & (
        attrs_i[GID_COUNT] == attrs_j[GID_COUNT]
    )
    mask = (
        bi(self_v)
        & bj(nbr_v)
        & ~same
        & (dist2 <= jnp.float32(radius * radius))
    )

    contribs = pair_fn(attrs_i, attrs_j, disp, dist2, params)

    out: Dict[str, Array] = {}
    for name, c in contribs.items():
        m = mask
        while m.ndim < c.ndim:
            m = m[..., None]
        out[name] = jnp.sum(jnp.where(m, c, jnp.zeros_like(c)), axis=3)
    return out


def pair_accumulate_tiled(
    geom: GridGeom,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
) -> Dict[str, Array]:
    """Offset-tiled sweep: nine (ix, iy, K, K) pair tiles instead of one
    (ix, iy, K, 9K) block over a materialized 9x gather.

    Every neighbor view is a plain slice of the resident SoA, so XLA fuses
    slice -> pair math -> mask per tile with no gather copies; the per-tile
    contributions are stacked along the j axis in the reference's offset
    order and reduced with the same single ``sum`` so the accumulation
    order matches :func:`pair_accumulate` exactly (agreement is to float
    ulp — fusion differences can flip the last bit of FMA chains).
    """
    hx, hy = geom.local_shape
    need = set(pair_attrs) | {POS, GID_RANK, GID_COUNT}

    # i views: (ix, iy, K, 1, t)
    attrs_i = {n: jnp.expand_dims(soa.attrs[n][1:hx - 1, 1:hy - 1], 3)
               for n in need}
    vi = jnp.expand_dims(soa.valid[1:hx - 1, 1:hy - 1], 3)
    r2 = jnp.float32(radius * radius)

    tiles: Dict[str, list] = {}
    for dx, dy in OFFSETS:
        # j views for this offset: (ix, iy, 1, K, t) slices — no copies
        nbr = {n: jnp.expand_dims(
            soa.attrs[n][1 + dx:hx - 1 + dx, 1 + dy:hy - 1 + dy], 2)
            for n in need}
        nv = jnp.expand_dims(
            soa.valid[1 + dx:hx - 1 + dx, 1 + dy:hy - 1 + dy], 2)
        disp = min_image(nbr[POS] - attrs_i[POS], geom)   # (ix,iy,K,K,2)
        dist2 = jnp.sum(disp * disp, axis=-1)
        same = (attrs_i[GID_RANK] == nbr[GID_RANK]) & (
            attrs_i[GID_COUNT] == nbr[GID_COUNT])
        mask = vi & nv & ~same & (dist2 <= r2)
        contribs = pair_fn(attrs_i, nbr, disp, dist2, params)
        for name, c in contribs.items():
            m = mask
            while m.ndim < c.ndim:
                m = m[..., None]
            tiles.setdefault(name, []).append(
                jnp.where(m, c, jnp.zeros_like(c)))

    out: Dict[str, Array] = {}
    for name, parts in tiles.items():
        # (ix,iy,K,K,t) tiles -> (ix,iy,K,9,K,t) -> (ix,iy,K,9K,t): the j
        # axis ends up in the reference's offset-major order before the
        # one-shot reduction.
        shape = jnp.broadcast_shapes(*[p.shape for p in parts])
        parts = [jnp.broadcast_to(p, shape) for p in parts]
        stacked = jnp.stack(parts, axis=3)
        flat = stacked.reshape(
            shape[:3] + (len(parts) * shape[3],) + shape[4:])
        out[name] = jnp.sum(flat, axis=3)
    return out


def pair_accumulate_pallas(
    geom: GridGeom,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
    *,
    block_cells: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Dict[str, Array]:
    """Pallas-kernel sweep: XLA builds the neighborhood gather (pure data
    movement), then one fused kernel program per block of cells evaluates
    every pair kernel for its (BC, K) x (BC, 9K) slabs in VMEM.

    ``interpret=None`` auto-detects from the JAX backend
    (``kernels.ops.use_interpret``); on TPU the same kernel compiles to
    Mosaic.
    """
    from repro.kernels import ops as kops

    ix, iy = geom.interior
    k = geom.cap
    c = ix * iy
    self_a, nbr_a, self_v, nbr_v = gather_neighborhood(geom, soa, pair_attrs)
    flat_i = {n: a.reshape((c, k) + a.shape[3:]) for n, a in self_a.items()}
    flat_j = {n: a.reshape((c, 9 * k) + a.shape[3:])
              for n, a in nbr_a.items()}
    box = geom.domain_size if geom.boundary == "toroidal" else None
    acc = kops.neighborhood_pair_sweep(
        flat_i, flat_j, self_v.reshape((c, k)), nbr_v.reshape((c, 9 * k)),
        pair_fn=pair_fn, radius=radius, params=params, box=box,
        block_cells=block_cells, interpret=interpret)
    return {n: a.reshape((ix, iy, k) + a.shape[2:]) for n, a in acc.items()}


def sweep_accumulate(
    geom: GridGeom,
    soa: AgentSoA,
    pair_fn: PairFn,
    pair_attrs: Tuple[str, ...],
    radius: float,
    params: dict,
    *,
    backend: str = "reference",
) -> Dict[str, Array]:
    """Backend-dispatched neighborhood sweep (the engine's entry point)."""
    backend = resolve_sweep_backend(backend)
    if backend == "reference":
        return pair_accumulate(geom, soa, pair_fn, pair_attrs, radius, params)
    if backend == "tiled":
        return pair_accumulate_tiled(
            geom, soa, pair_fn, pair_attrs, radius, params)
    return pair_accumulate_pallas(
        geom, soa, pair_fn, pair_attrs, radius, params)
