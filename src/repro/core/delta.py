"""Delta encoding of iterative exchanges (paper §2.3), TPU-adapted.

The paper's observation: agent attributes change only gradually between
iterations, so sender/receiver pairs keep a shared *reference* message and
transmit only the (compressed) difference, refreshing the reference at regular
intervals.

TPU adaptation (DESIGN.md §2): byte-granular, branchy LZ4 has no TPU analogue,
and static shapes rule out dynamically-sized packed payloads.  The TPU-native
form of "compress the delta" is **precision narrowing of the temporal
derivative**: float attributes are transmitted as int8/int16 quantized deltas
against the reference with a per-slab scale.  Because the delta of a slowly-
varying signal is small, narrow fixed-point holds it with bounded error, and
the closed-loop reference update (both sides set ``ref <- ref + dequant(q)``)
gives error feedback: quantization error is re-encoded next iteration instead
of accumulating.

The paper's agent-reordering stage (match message order to reference order)
is unnecessary here: SoA cell-slot layout is slot-stable across iterations, so
sender/receiver alignment is free — this is recorded as a hardware-adaptation
win in DESIGN.md.

Bytes on the wire are static and exact: f32 full refresh = 4 B/elem, int16
delta = 2 B/elem, int8 delta = 1 B/elem (plus one f32 scale per slab), so the
steady-state reduction at refresh interval R is ``4R / (4 + (R-1)*q)`` — e.g.
3.56x for int8 at R=16, matching the paper's reported 1.1-3.5x delta gain.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.compile_cache import memoize

Array = jax.Array

# A "slab" is a pytree (dict) of arrays: the unit of halo exchange.
Slab = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    enabled: bool = True
    qdtype: Any = jnp.int8        # int8 or int16 quantized delta payload
    refresh_interval: int = 16    # full f32 send every R iterations
    # Fixed quantization scale (units per quantum).  None (default) derives
    # the scale per slab from max |delta| — never clips, costs one f32 on
    # the wire per slab.  A fixed scale drops that f32 and makes the
    # codec-headroom contract statically checkable, but *can* saturate at
    # the qdtype range; encode_delta counts those clipped elements so the
    # exchange can fall back to a full refresh.
    scale: Any = None
    # Migration-payload position codec (None = raw f32 migration).  Set to
    # an int dtype (int16) to transmit emigrant positions as fixed-point
    # offsets from the sender's device center: migration slabs have no
    # temporal reference (slots churn every hop), but positions within a
    # slab span at most the sender's padded local box, so a static scale
    # covering that box + one ring of slack holds them with bounded error
    # (half_range / iinfo(migration).max per axis).  Positions only — the
    # remaining float attrs ride raw.
    migration: Any = None


def _is_float(a: Array) -> bool:
    return jnp.issubdtype(a.dtype, jnp.floating)


def encode_full(slab: Slab) -> Tuple[Slab, Slab]:
    """Full refresh: payload is the raw slab; new reference = slab."""
    return slab, slab


def decode_full(payload: Slab) -> Tuple[Slab, Slab]:
    return payload, payload


def encode_delta(
    slab: Slab, ref: Slab, cfg: DeltaConfig
) -> Tuple[Slab, Slab, Array]:
    """Quantized-delta encode float attrs; pass-through the rest.

    Returns (payload, new_reference, overflow_count).  new_reference equals
    the receiver-side reconstruction (closed loop).  overflow_count is an
    int32 scalar: how many elements saturated the quantization range
    *before* clipping.  With the default adaptive scale it is always 0 (the
    scale is derived from max |delta|); with a fixed ``cfg.scale`` a fast
    transient can exceed ``scale * qmax`` and the clipped reconstruction is
    silently wrong unless the caller reacts (the aura exchange falls back
    to a full refresh on the next segment boundary).
    """
    qinfo = jnp.iinfo(cfg.qdtype)
    qmax = jnp.float32(qinfo.max)
    payload: Slab = {}
    new_ref: Slab = {}
    overflow = jnp.int32(0)
    for name, x in slab.items():
        r = ref[name]
        if _is_float(x):
            delta = (x - r).astype(jnp.float32)
            if cfg.scale is None:
                scale = jnp.maximum(jnp.max(jnp.abs(delta)), 1e-30) / qmax
            else:
                scale = jnp.float32(cfg.scale)
            qf = jnp.round(delta / scale)
            overflow = overflow + jnp.sum(
                (qf > qinfo.max) | (qf < qinfo.min), dtype=jnp.int32
            )
            q = jnp.clip(qf, qinfo.min, qinfo.max).astype(cfg.qdtype)
            payload[name] = q
            payload[name + "/scale"] = scale.astype(jnp.float32)
            new_ref[name] = (r.astype(jnp.float32) + q.astype(jnp.float32) * scale
                             ).astype(x.dtype)
        else:
            payload[name] = x
            new_ref[name] = x
    return payload, new_ref, overflow


def decode_delta(payload: Slab, ref: Slab, cfg: DeltaConfig) -> Tuple[Slab, Slab]:
    """Receiver-side inverse of :func:`encode_delta`."""
    out: Slab = {}
    for name, q in payload.items():
        if name.endswith("/scale"):
            continue
        r = ref[name]
        if name + "/scale" in payload:
            scale = payload[name + "/scale"]
            x = (r.astype(jnp.float32) + q.astype(jnp.float32) * scale).astype(
                r.dtype
            )
        else:
            x = q
        out[name] = x
    return out, dict(out)


@memoize("delta.payload_bytes", maxsize=256)
def _spec_bytes(spec: Tuple[Tuple[str, Tuple[int, ...]], ...]) -> int:
    return sum(int(jnp.dtype(d).itemsize) * math.prod(s) for d, s in spec)


def payload_bytes(payload: Slab) -> int:
    """Exact static wire bytes of a payload pytree.

    The per-spec total is memoized on :mod:`repro.core.compile_cache`
    (payloads carry a handful of distinct (dtype, shape) signatures per
    run, but the exchange accounts bytes every directed edge of every
    traced step)."""
    spec = tuple(sorted(
        (str(a.dtype), tuple(a.shape))
        for a in jax.tree_util.tree_leaves(payload)))
    return _spec_bytes(spec)


def encode_migration(slab: Slab, pos_name: str, center: Array,
                     half_range, cfg: DeltaConfig,
                     lsz=None, toroidal=()) -> Tuple[Slab, Array]:
    """Quantize the position entry of a migration payload (paper §2.3
    applied to the *spatial* rather than temporal redundancy: an emigrant
    sits within one ring of the sender's local box, so its offset from the
    box center is small and a narrow fixed-point encoding holds it).

    ``center`` is the sender's (nd,) reference point; it rides the payload
    under ``pos_name + "/center"`` so the receiver dequantizes against the
    sender's frame (device origins differ, and under uneven ownership not
    even uniformly).  ``half_range`` is the static per-axis quantization
    range (± around center); on toroidal axes the offset is min-image
    wrapped with period ``lsz`` first, so a migrant crossing the periodic
    seam still encodes as a small offset.  Returns ``(payload,
    overflow_count)`` — overflow counts coordinates that saturated the
    range before clipping (impossible while agents honor the ≤1 cell/step
    migration contract, counted so the driver can see violations).
    """
    qinfo = jnp.iinfo(cfg.migration)
    qmax = jnp.float32(qinfo.max)
    scale = jnp.asarray(half_range, jnp.float32) / qmax       # (nd,)
    p = slab[pos_name].astype(jnp.float32)
    d = p - center
    if any(toroidal):
        L = jnp.asarray(lsz, jnp.float32)
        d = jnp.where(jnp.asarray(toroidal), d - L * jnp.round(d / L), d)
    qf = jnp.round(d / scale)
    oob = (qf > qinfo.max) | (qf < qinfo.min)
    if "valid" in slab:
        # Dead slots carry stale coordinates from arbitrary frames; their
        # payload bytes are discarded at re-binning, so only live slots
        # count toward the contract-violation tally.
        oob = oob & slab["valid"][..., None]
    overflow = jnp.sum(oob, dtype=jnp.int32)
    out = dict(slab)
    out[pos_name] = jnp.clip(qf, qinfo.min, qinfo.max).astype(cfg.migration)
    out[pos_name + "/center"] = center.astype(jnp.float32)
    return out, overflow


def decode_migration(payload: Slab, pos_name: str, half_range,
                     cfg: DeltaConfig, lsz=None, toroidal=()) -> Slab:
    """Receiver-side inverse of :func:`encode_migration`: reconstruct
    positions in the sender's frame, then wrap toroidal axes back into the
    fundamental domain (the closed-loop mod that ``wrap_pos`` used to
    apply pre-send now lands here, after dequantization)."""
    qinfo = jnp.iinfo(cfg.migration)
    scale = jnp.asarray(half_range, jnp.float32) / jnp.float32(qinfo.max)
    out = dict(payload)
    center = out.pop(pos_name + "/center")
    p = center + out[pos_name].astype(jnp.float32) * scale
    if any(toroidal):
        L = jnp.asarray(lsz, jnp.float32)
        p = jnp.where(jnp.asarray(toroidal), jnp.mod(p, L), p)
    out[pos_name] = p
    return out


def zeros_like_slab(slab_spec: Slab) -> Slab:
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in slab_spec.items()}
