"""Delta encoding of iterative exchanges (paper §2.3), TPU-adapted.

The paper's observation: agent attributes change only gradually between
iterations, so sender/receiver pairs keep a shared *reference* message and
transmit only the (compressed) difference, refreshing the reference at regular
intervals.

TPU adaptation (DESIGN.md §2): byte-granular, branchy LZ4 has no TPU analogue,
and static shapes rule out dynamically-sized packed payloads.  The TPU-native
form of "compress the delta" is **precision narrowing of the temporal
derivative**: float attributes are transmitted as int8/int16 quantized deltas
against the reference with a per-slab scale.  Because the delta of a slowly-
varying signal is small, narrow fixed-point holds it with bounded error, and
the closed-loop reference update (both sides set ``ref <- ref + dequant(q)``)
gives error feedback: quantization error is re-encoded next iteration instead
of accumulating.

The paper's agent-reordering stage (match message order to reference order)
is unnecessary here: SoA cell-slot layout is slot-stable across iterations, so
sender/receiver alignment is free — this is recorded as a hardware-adaptation
win in DESIGN.md.

Bytes on the wire are static and exact: f32 full refresh = 4 B/elem, int16
delta = 2 B/elem, int8 delta = 1 B/elem (plus one f32 scale per slab), so the
steady-state reduction at refresh interval R is ``4R / (4 + (R-1)*q)`` — e.g.
3.56x for int8 at R=16, matching the paper's reported 1.1-3.5x delta gain.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# A "slab" is a pytree (dict) of arrays: the unit of halo exchange.
Slab = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    enabled: bool = True
    qdtype: Any = jnp.int8        # int8 or int16 quantized delta payload
    refresh_interval: int = 16    # full f32 send every R iterations
    # Fixed quantization scale (units per quantum).  None (default) derives
    # the scale per slab from max |delta| — never clips, costs one f32 on
    # the wire per slab.  A fixed scale drops that f32 and makes the
    # codec-headroom contract statically checkable, but *can* saturate at
    # the qdtype range; encode_delta counts those clipped elements so the
    # exchange can fall back to a full refresh.
    scale: Any = None


def _is_float(a: Array) -> bool:
    return jnp.issubdtype(a.dtype, jnp.floating)


def encode_full(slab: Slab) -> Tuple[Slab, Slab]:
    """Full refresh: payload is the raw slab; new reference = slab."""
    return slab, slab


def decode_full(payload: Slab) -> Tuple[Slab, Slab]:
    return payload, payload


def encode_delta(
    slab: Slab, ref: Slab, cfg: DeltaConfig
) -> Tuple[Slab, Slab, Array]:
    """Quantized-delta encode float attrs; pass-through the rest.

    Returns (payload, new_reference, overflow_count).  new_reference equals
    the receiver-side reconstruction (closed loop).  overflow_count is an
    int32 scalar: how many elements saturated the quantization range
    *before* clipping.  With the default adaptive scale it is always 0 (the
    scale is derived from max |delta|); with a fixed ``cfg.scale`` a fast
    transient can exceed ``scale * qmax`` and the clipped reconstruction is
    silently wrong unless the caller reacts (the aura exchange falls back
    to a full refresh on the next segment boundary).
    """
    qinfo = jnp.iinfo(cfg.qdtype)
    qmax = jnp.float32(qinfo.max)
    payload: Slab = {}
    new_ref: Slab = {}
    overflow = jnp.int32(0)
    for name, x in slab.items():
        r = ref[name]
        if _is_float(x):
            delta = (x - r).astype(jnp.float32)
            if cfg.scale is None:
                scale = jnp.maximum(jnp.max(jnp.abs(delta)), 1e-30) / qmax
            else:
                scale = jnp.float32(cfg.scale)
            qf = jnp.round(delta / scale)
            overflow = overflow + jnp.sum(
                (qf > qinfo.max) | (qf < qinfo.min), dtype=jnp.int32
            )
            q = jnp.clip(qf, qinfo.min, qinfo.max).astype(cfg.qdtype)
            payload[name] = q
            payload[name + "/scale"] = scale.astype(jnp.float32)
            new_ref[name] = (r.astype(jnp.float32) + q.astype(jnp.float32) * scale
                             ).astype(x.dtype)
        else:
            payload[name] = x
            new_ref[name] = x
    return payload, new_ref, overflow


def decode_delta(payload: Slab, ref: Slab, cfg: DeltaConfig) -> Tuple[Slab, Slab]:
    """Receiver-side inverse of :func:`encode_delta`."""
    out: Slab = {}
    for name, q in payload.items():
        if name.endswith("/scale"):
            continue
        r = ref[name]
        if name + "/scale" in payload:
            scale = payload[name + "/scale"]
            x = (r.astype(jnp.float32) + q.astype(jnp.float32) * scale).astype(
                r.dtype
            )
        else:
            x = q
        out[name] = x
    return out, dict(out)


def payload_bytes(payload: Slab) -> int:
    """Exact static wire bytes of a payload pytree."""
    import math

    total = 0
    for a in jax.tree_util.tree_leaves(payload):
        total += int(jnp.dtype(a.dtype).itemsize) * math.prod(a.shape)
    return total


def zeros_like_slab(slab_spec: Slab) -> Slab:
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in slab_spec.items()}
