"""Load balancing (paper §2.4.5): global RCB and diffusive planners.

TPU adaptation (DESIGN.md §2): XLA's static shapes make per-iteration dynamic
ownership an anti-pattern, so load balancing is applied at *re-shard
boundaries*: the planners run on the host over the (tiny) per-box occupancy
histogram, emit a new ownership/mesh plan, and the engine re-initializes from
the flattened agent state (the checkpoint path doubles as the mass-migration
path — the paper notes global RCB "might lead to a new partitioning that
differs substantially ... causing mass migrations" (§2.4.5); here that cost
is paid exactly once per re-shard and is also what makes the engine
**elastic**: the same path restores a checkpoint onto a different device
count after a node failure).

Two planners, matching the paper:

* ``plan_rcb``     — recursive coordinate bisection over the weighted
                     occupancy histogram (Zoltan2-RCB analogue).
* ``plan_diffusive`` — neighboring partitions exchange boundary box columns;
                     partitions slower than the local average cede boxes to
                     faster neighbors.

Both return ownership maps (box -> device) plus an imbalance metric; tests
assert the imbalance strictly improves on skewed densities.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


def imbalance(loads: np.ndarray) -> float:
    """max/mean - 1; 0 is perfect balance."""
    m = float(np.mean(loads))
    if m <= 0:
        return 0.0
    return float(np.max(loads)) / m - 1.0


def device_loads(ownership: np.ndarray, weights: np.ndarray,
                 n_devices: int) -> np.ndarray:
    loads = np.zeros((n_devices,), dtype=np.float64)
    np.add.at(loads, ownership.ravel(), weights.ravel())
    return loads


# ---------------------------------------------------------------------------
# Global: recursive coordinate bisection (RCB)
# ---------------------------------------------------------------------------

def plan_rcb(weights: np.ndarray, n_devices: int) -> np.ndarray:
    """Partition an N-D weight histogram into ``n_devices`` contiguous
    hyper-rectangles by recursive coordinate bisection.

    Args:
      weights: per-partitioning-box weight over the Domain's box grid
        (agent count, optionally scaled by last-iteration runtime, as in
        the paper) — 2-D or 3-D.
      n_devices: number of devices; must be a power of two.

    Returns:
      ownership: int32 box -> device map, same shape as ``weights``.
    """
    if n_devices & (n_devices - 1):
        raise ValueError("RCB requires a power-of-two device count")
    nd = weights.ndim
    ownership = np.zeros(weights.shape, dtype=np.int32)

    def split(bounds, dev0, ndev):
        region = tuple(slice(lo, hi) for lo, hi in bounds)
        if ndev == 1:
            ownership[region] = dev0
            return
        lens = [hi - lo for lo, hi in bounds]
        # Bisect the longest axis (ties -> lowest axis) at the weighted
        # median.
        ax = int(np.argmax(lens))
        w = weights[region]
        prof = w.sum(axis=tuple(a for a in range(nd) if a != ax))
        half = prof.sum() / 2.0
        cut = int(np.searchsorted(np.cumsum(prof), half)) + 1
        cut = max(1, min(lens[ax] - 1, cut))
        lo, hi = bounds[ax]
        b1 = list(bounds)
        b1[ax] = (lo, lo + cut)
        b2 = list(bounds)
        b2[ax] = (lo + cut, hi)
        split(tuple(b1), dev0, ndev // 2)
        split(tuple(b2), dev0 + ndev // 2, ndev // 2)

    split(tuple((0, s) for s in weights.shape), 0, n_devices)
    return ownership


# ---------------------------------------------------------------------------
# Diffusive: neighbor column exchange
# ---------------------------------------------------------------------------

def plan_diffusive(
    widths: np.ndarray, col_weights: np.ndarray, runtimes: np.ndarray
) -> np.ndarray:
    """One diffusive step over a 1D chain of partitions owning contiguous
    box-column ranges (paper: "ranks whose runtime exceeds the local average
    send boxes to neighbors that were faster").

    Args:
      widths: (D,) number of box columns owned by each device (sum = BX).
      col_weights: (BX,) weight per box column.
      runtimes: (D,) last-iteration runtime per device.

    Returns:
      new widths (D,), each >= 1, sum preserved.
    """
    d = len(widths)
    widths = widths.astype(np.int64).copy()
    for i in range(d - 1):
        pair_avg = (runtimes[i] + runtimes[i + 1]) / 2.0
        if runtimes[i] > pair_avg and widths[i] > 1:
            widths[i] -= 1
            widths[i + 1] += 1
        elif runtimes[i + 1] > pair_avg and widths[i + 1] > 1:
            widths[i + 1] -= 1
            widths[i] += 1
    return widths


def widths_to_ownership(widths: np.ndarray) -> np.ndarray:
    """(D,) column widths -> (BX,) column -> device map."""
    out = np.zeros((int(np.sum(widths)),), dtype=np.int32)
    x = 0
    for dev, w in enumerate(widths):
        out[x:x + int(w)] = dev
        x += int(w)
    return out


def equal_split_loads(weights: np.ndarray,
                      mesh_shape: Tuple[int, ...]) -> np.ndarray:
    """Per-device loads of the engine's equal-split partition: the device at
    mesh coordinate ``c`` owns the equal block of boxes at block-index
    ``c`` along every axis."""
    mesh = tuple(mesh_shape)
    if weights.ndim != len(mesh):
        raise ValueError(
            f"mesh {mesh} has {len(mesh)} axes for a {weights.ndim}-D "
            "box grid")
    if any(b % m for b, m in zip(weights.shape, mesh)):
        raise ValueError(
            f"mesh {mesh} does not divide the box grid {weights.shape}")
    shape: Tuple[int, ...] = ()
    for b, m in zip(weights.shape, mesh):
        shape += (m, b // m)
    return weights.reshape(shape).sum(
        axis=tuple(range(1, 2 * len(mesh), 2))).ravel()


def _factorizations(n: int, ndim: int):
    """All ordered ``ndim``-tuples of positive ints with product ``n``,
    lexicographically ascending."""
    if ndim == 1:
        yield (n,)
        return
    for m in range(1, n + 1):
        if n % m == 0:
            for rest in _factorizations(n // m, ndim - 1):
                yield (m,) + rest


def choose_mesh_shape(weights: np.ndarray,
                      n_devices: int) -> Tuple[int, ...]:
    """Pick the mesh factorization of ``n_devices`` (one factor per box-grid
    axis) minimizing the equal-split imbalance over the density histogram —
    the realizable half of a re-shard plan (core.reshard) and the elastic
    path's mesh picker when the device count changes.  All divisor
    factorizations are scanned (not just powers of two) so degraded counts
    like 3 or 6 factorize too; ties break toward smaller earlier axes."""
    best = None
    for mesh in _factorizations(n_devices, weights.ndim):
        if all(b % m == 0 for b, m in zip(weights.shape, mesh)):
            score = imbalance(equal_split_loads(weights, mesh))
            if best is None or score < best[0]:
                best = (score, mesh)
    if best is None:
        raise ValueError("no valid mesh factorization divides the histogram")
    return best[1]
