"""Load balancing (paper §2.4.5): global RCB and diffusive planners.

TPU adaptation (DESIGN.md §2): XLA's static shapes make per-iteration dynamic
ownership an anti-pattern, so load balancing is applied at *re-shard
boundaries*: the planners run on the host over the (tiny) per-box occupancy
histogram, emit a new ownership/mesh plan, and the engine re-initializes from
the flattened agent state (the checkpoint path doubles as the mass-migration
path — the paper notes global RCB "might lead to a new partitioning that
differs substantially ... causing mass migrations" (§2.4.5); here that cost
is paid exactly once per re-shard and is also what makes the engine
**elastic**: the same path restores a checkpoint onto a different device
count after a node failure).

Two planners, matching the paper:

* ``plan_rcb``     — recursive coordinate bisection over the weighted
                     occupancy histogram (Zoltan2-RCB analogue).
* ``plan_diffusive`` — neighboring partitions exchange boundary box columns;
                     partitions slower than the local average cede boxes to
                     faster neighbors.

Both return ownership maps (box -> device) plus an imbalance metric; tests
assert the imbalance strictly improves on skewed densities.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


def imbalance(loads: np.ndarray) -> float:
    """max/mean - 1; 0 is perfect balance."""
    m = float(np.mean(loads))
    if m <= 0:
        return 0.0
    return float(np.max(loads)) / m - 1.0


def device_loads(ownership: np.ndarray, weights: np.ndarray,
                 n_devices: int) -> np.ndarray:
    loads = np.zeros((n_devices,), dtype=np.float64)
    np.add.at(loads, ownership.ravel(), weights.ravel())
    return loads


# ---------------------------------------------------------------------------
# Global: recursive coordinate bisection (RCB)
# ---------------------------------------------------------------------------

def plan_rcb(weights: np.ndarray, n_devices: int) -> np.ndarray:
    """Partition a 2D weight histogram into ``n_devices`` contiguous
    rectangles by recursive coordinate bisection.

    Args:
      weights: (BX, BY) per-partitioning-box weight (agent count, optionally
        scaled by last-iteration runtime, as in the paper).
      n_devices: number of devices; must be a power of two.

    Returns:
      ownership: (BX, BY) int32 box -> device map.
    """
    if n_devices & (n_devices - 1):
        raise ValueError("RCB requires a power-of-two device count")
    bx, by = weights.shape
    ownership = np.zeros((bx, by), dtype=np.int32)

    def split(x0, x1, y0, y1, dev0, ndev):
        if ndev == 1:
            ownership[x0:x1, y0:y1] = dev0
            return
        w = weights[x0:x1, y0:y1]
        # Bisect the longer axis at the weighted median.
        if (x1 - x0) >= (y1 - y0):
            prof = w.sum(axis=1)
            axis_len = x1 - x0
        else:
            prof = w.sum(axis=0)
            axis_len = y1 - y0
        half = prof.sum() / 2.0
        cut = int(np.searchsorted(np.cumsum(prof), half)) + 1
        cut = max(1, min(axis_len - 1, cut))
        if (x1 - x0) >= (y1 - y0):
            split(x0, x0 + cut, y0, y1, dev0, ndev // 2)
            split(x0 + cut, x1, y0, y1, dev0 + ndev // 2, ndev // 2)
        else:
            split(x0, x1, y0, y0 + cut, dev0, ndev // 2)
            split(x0, x1, y0 + cut, y1, dev0 + ndev // 2, ndev // 2)

    split(0, bx, 0, by, 0, n_devices)
    return ownership


# ---------------------------------------------------------------------------
# Diffusive: neighbor column exchange
# ---------------------------------------------------------------------------

def plan_diffusive(
    widths: np.ndarray, col_weights: np.ndarray, runtimes: np.ndarray
) -> np.ndarray:
    """One diffusive step over a 1D chain of partitions owning contiguous
    box-column ranges (paper: "ranks whose runtime exceeds the local average
    send boxes to neighbors that were faster").

    Args:
      widths: (D,) number of box columns owned by each device (sum = BX).
      col_weights: (BX,) weight per box column.
      runtimes: (D,) last-iteration runtime per device.

    Returns:
      new widths (D,), each >= 1, sum preserved.
    """
    d = len(widths)
    widths = widths.astype(np.int64).copy()
    for i in range(d - 1):
        pair_avg = (runtimes[i] + runtimes[i + 1]) / 2.0
        if runtimes[i] > pair_avg and widths[i] > 1:
            widths[i] -= 1
            widths[i + 1] += 1
        elif runtimes[i + 1] > pair_avg and widths[i + 1] > 1:
            widths[i + 1] -= 1
            widths[i] += 1
    return widths


def widths_to_ownership(widths: np.ndarray) -> np.ndarray:
    """(D,) column widths -> (BX,) column -> device map."""
    out = np.zeros((int(np.sum(widths)),), dtype=np.int32)
    x = 0
    for dev, w in enumerate(widths):
        out[x:x + int(w)] = dev
        x += int(w)
    return out


def equal_split_loads(weights: np.ndarray,
                      mesh_shape: Tuple[int, int]) -> np.ndarray:
    """Per-device loads of the engine's equal-split partition: device (i, j)
    owns the (BX/mx, BY/my) block of boxes at block-index (i, j)."""
    bx, by = weights.shape
    mx, my = mesh_shape
    if bx % mx or by % my:
        raise ValueError(
            f"mesh {mesh_shape} does not divide the box grid {(bx, by)}")
    return weights.reshape(mx, bx // mx, my, by // my).sum(axis=(1, 3)).ravel()


def choose_mesh_shape(weights: np.ndarray, n_devices: int) -> Tuple[int, int]:
    """Pick the (mx, my) factorization of ``n_devices`` minimizing the
    equal-split imbalance over the density histogram — the realizable half of
    a re-shard plan (core.reshard) and the elastic path's mesh picker when
    the device count changes.  All divisor factorizations are scanned (not
    just powers of two) so degraded counts like 3 or 6 factorize too; ties
    break toward the smaller mx."""
    best = None
    for m in range(1, n_devices + 1):
        if n_devices % m == 0:
            mx, my = m, n_devices // m
            bx, by = weights.shape
            if bx % mx == 0 and by % my == 0:
                score = imbalance(equal_split_loads(weights, (mx, my)))
                if best is None or score < best[0]:
                    best = (score, (mx, my))
    if best is None:
        raise ValueError("no valid mesh factorization divides the histogram")
    return best[1]
