"""Load balancing (paper §2.4.5): global RCB and diffusive planners.

TPU adaptation (DESIGN.md §2): XLA's static shapes make per-iteration dynamic
ownership an anti-pattern, so load balancing is applied at *re-shard
boundaries*: the planners run on the host over the (tiny) per-box occupancy
histogram, emit a new ownership/mesh plan, and the engine re-initializes from
the flattened agent state (the checkpoint path doubles as the mass-migration
path — the paper notes global RCB "might lead to a new partitioning that
differs substantially ... causing mass migrations" (§2.4.5); here that cost
is paid exactly once per re-shard and is also what makes the engine
**elastic**: the same path restores a checkpoint onto a different device
count after a node failure).

Three planners:

* ``plan_rcb``     — recursive coordinate bisection over the weighted
                     occupancy histogram (Zoltan2-RCB analogue); its
                     hierarchical cuts are a report-only bound (no aligned
                     ``ppermute`` realization).
* ``plan_rectilinear`` — the *realizable* uneven planner: per-axis cut
                     positions shared across the mesh (marginal-quantile
                     init + exact per-axis DP refinement), the structure a
                     masked-halo engine can own directly
                     (``core.domain.Partition``).
* ``plan_diffusive`` — neighboring partitions exchange boundary box columns;
                     partitions slower than the local average cede boxes to
                     faster neighbors.

``choose_partition(weights, n, ownership="equal"|"rcb")`` scans every mesh
factorization of the device count with the matching planner and returns
the best realizable plan; the legacy equal-split-only
``choose_mesh_shape`` survives as a DeprecationWarning shim over it.
Tests assert the planned imbalance strictly improves on skewed densities.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import numpy as np

from repro.core.domain import Partition


def imbalance(loads: np.ndarray) -> float:
    """max/mean - 1; 0 is perfect balance."""
    m = float(np.mean(loads))
    if m <= 0:
        return 0.0
    return float(np.max(loads)) / m - 1.0


def device_loads(ownership: np.ndarray, weights: np.ndarray,
                 n_devices: int) -> np.ndarray:
    loads = np.zeros((n_devices,), dtype=np.float64)
    np.add.at(loads, ownership.ravel(), weights.ravel())
    return loads


# ---------------------------------------------------------------------------
# Global: recursive coordinate bisection (RCB)
# ---------------------------------------------------------------------------

def plan_rcb(weights: np.ndarray, n_devices: int) -> np.ndarray:
    """Partition an N-D weight histogram into ``n_devices`` contiguous
    hyper-rectangles by recursive coordinate bisection.

    Args:
      weights: per-partitioning-box weight over the Domain's box grid
        (agent count, optionally scaled by last-iteration runtime, as in
        the paper) — 2-D or 3-D.
      n_devices: number of devices; must be a power of two.

    Returns:
      ownership: int32 box -> device map, same shape as ``weights``.
    """
    if n_devices & (n_devices - 1):
        raise ValueError("RCB requires a power-of-two device count")
    nd = weights.ndim
    ownership = np.zeros(weights.shape, dtype=np.int32)

    def split(bounds, dev0, ndev):
        region = tuple(slice(lo, hi) for lo, hi in bounds)
        if ndev == 1:
            ownership[region] = dev0
            return
        lens = [hi - lo for lo, hi in bounds]
        # Bisect the longest axis (ties -> lowest axis) at the weighted
        # median.
        ax = int(np.argmax(lens))
        w = weights[region]
        prof = w.sum(axis=tuple(a for a in range(nd) if a != ax))
        half = prof.sum() / 2.0
        cut = int(np.searchsorted(np.cumsum(prof), half)) + 1
        cut = max(1, min(lens[ax] - 1, cut))
        lo, hi = bounds[ax]
        b1 = list(bounds)
        b1[ax] = (lo, lo + cut)
        b2 = list(bounds)
        b2[ax] = (lo + cut, hi)
        split(tuple(b1), dev0, ndev // 2)
        split(tuple(b2), dev0 + ndev // 2, ndev // 2)

    split(tuple((0, s) for s in weights.shape), 0, n_devices)
    return ownership


# ---------------------------------------------------------------------------
# Diffusive: neighbor column exchange
# ---------------------------------------------------------------------------

def plan_diffusive(
    widths: np.ndarray, col_weights: np.ndarray, runtimes: np.ndarray
) -> np.ndarray:
    """One diffusive step over a 1D chain of partitions owning contiguous
    box-column ranges (paper: "ranks whose runtime exceeds the local average
    send boxes to neighbors that were faster").

    Args:
      widths: (D,) number of box columns owned by each device (sum = BX).
      col_weights: (BX,) weight per box column.
      runtimes: (D,) last-iteration runtime per device.

    Returns:
      new widths (D,), each >= 1, sum preserved.
    """
    d = len(widths)
    widths = widths.astype(np.int64).copy()
    for i in range(d - 1):
        pair_avg = (runtimes[i] + runtimes[i + 1]) / 2.0
        if runtimes[i] > pair_avg and widths[i] > 1:
            widths[i] -= 1
            widths[i + 1] += 1
        elif runtimes[i + 1] > pair_avg and widths[i + 1] > 1:
            widths[i + 1] -= 1
            widths[i] += 1
    return widths


def widths_to_ownership(widths: np.ndarray) -> np.ndarray:
    """(D,) column widths -> (BX,) column -> device map."""
    out = np.zeros((int(np.sum(widths)),), dtype=np.int32)
    x = 0
    for dev, w in enumerate(widths):
        out[x:x + int(w)] = dev
        x += int(w)
    return out


def equal_split_loads(weights: np.ndarray,
                      mesh_shape: Tuple[int, ...]) -> np.ndarray:
    """Per-device loads of the engine's equal-split partition: the device at
    mesh coordinate ``c`` owns the equal block of boxes at block-index
    ``c`` along every axis."""
    mesh = tuple(mesh_shape)
    if weights.ndim != len(mesh):
        raise ValueError(
            f"mesh {mesh} has {len(mesh)} axes for a {weights.ndim}-D "
            "box grid")
    if any(b % m for b, m in zip(weights.shape, mesh)):
        raise ValueError(
            f"mesh {mesh} does not divide the box grid {weights.shape}")
    shape: Tuple[int, ...] = ()
    for b, m in zip(weights.shape, mesh):
        shape += (m, b // m)
    return weights.reshape(shape).sum(
        axis=tuple(range(1, 2 * len(mesh), 2))).ravel()


# ---------------------------------------------------------------------------
# Rectilinear (box-granular uneven) partitions — the realizable RCB analogue
# ---------------------------------------------------------------------------

def partition_loads(weights: np.ndarray, partition: Partition) -> np.ndarray:
    """Per-device loads of a rectilinear :class:`Partition` whose cuts are
    expressed in the units of ``weights``' grid (boxes); device order is
    row-major over the partition's mesh."""
    w = np.asarray(weights, np.float64)
    if partition.global_cells != w.shape:
        raise ValueError(
            f"partition covers {partition.global_cells} boxes; the "
            f"histogram has {w.shape}")
    for a in range(w.ndim):
        w = np.add.reduceat(w, partition.cuts[a][:-1], axis=a)
    return w.ravel()


def _axis_profiles(weights: np.ndarray, cuts, axis: int) -> np.ndarray:
    """Collapse every axis except ``axis`` onto its current cut blocks:
    returns (X, J) where X is the axis length and J the flattened
    other-axis block index."""
    w = np.asarray(weights, np.float64)
    for b in range(w.ndim):
        if b != axis:
            w = np.add.reduceat(w, cuts[b][:-1], axis=b)
    w = np.moveaxis(w, axis, 0)
    return w.reshape(w.shape[0], -1)


def _best_axis_cuts(col: np.ndarray, m: int) -> Tuple[Tuple[int, ...], float]:
    """Optimal contiguous partition of the rows of ``col`` (X, J) into
    ``m`` non-empty parts minimizing the max over (part, j) of the part's
    column sum — the exact 1-D subproblem of rectilinear partitioning
    (each j is one fixed other-axis block; a part's worst column is the
    load of its worst device in that axis row)."""
    x = col.shape[0]
    if m > x:
        raise ValueError(f"{m} parts over {x} boxes")
    pref = np.concatenate(
        [np.zeros((1, col.shape[1])), np.cumsum(col, axis=0)])
    # L[lo, hi] = max_j sum of rows [lo, hi)
    L = np.max(pref[None, :, :] - pref[:, None, :], axis=2)
    inf = float("inf")
    dp = np.full((m + 1, x + 1), inf)
    arg = np.zeros((m + 1, x + 1), np.int64)
    dp[0, 0] = 0.0
    for k in range(1, m + 1):
        for i in range(k, x - (m - k) + 1):
            lo = k - 1
            cand = np.maximum(dp[k - 1, lo:i], L[lo:i, i])
            j = int(np.argmin(cand))
            dp[k, i] = cand[j]
            arg[k, i] = lo + j
    cuts = [x]
    i = x
    for k in range(m, 0, -1):
        i = int(arg[k, i])
        cuts.append(i)
    return tuple(reversed(cuts)), float(dp[m, x])


def _quantile_cuts(marginal: np.ndarray, m: int) -> Tuple[int, ...]:
    """Initial per-axis cuts at the weighted quantiles of a marginal, with
    every slab at least one box wide."""
    x = len(marginal)
    cs = np.cumsum(np.asarray(marginal, np.float64))
    total = cs[-1]
    cuts = [0]
    for k in range(1, m):
        c = int(np.searchsorted(cs, total * k / m, side="left")) + 1
        c = max(cuts[-1] + 1, min(c, x - (m - k)))
        cuts.append(c)
    cuts.append(x)
    return tuple(cuts)


def plan_rectilinear(weights: np.ndarray, mesh_shape: Tuple[int, ...],
                     sweeps: int = 4) -> Partition:
    """Rectilinear uneven partition over a weight histogram: per-axis cut
    positions shared across the whole mesh (the structure a masked
    ``ppermute`` halo exchange can realize; Nicol-style alternating
    refinement).

    Cuts start at the per-axis weighted marginal quantiles, then each axis
    is re-cut *optimally* (exact DP over contiguous box ranges) holding the
    other axes fixed, cycling until a sweep stops improving.  This is the
    realizable counterpart of :func:`plan_rcb`'s hierarchical bisection —
    for clustered densities whose mass separates along one axis, or
    symmetric blobs, the refined cuts reach the RCB bound; a strictly
    non-rectilinear RCB optimum cannot be realized on a tensor mesh.
    """
    w = np.asarray(weights, np.float64)
    mesh = tuple(int(m) for m in mesh_shape)
    if len(mesh) != w.ndim:
        raise ValueError(f"mesh {mesh} has {len(mesh)} axes for a "
                         f"{w.ndim}-D histogram")
    if any(m > s for m, s in zip(mesh, w.shape)):
        raise ValueError(f"mesh {mesh} exceeds the box grid {w.shape}")
    cuts = [
        _quantile_cuts(
            w.sum(axis=tuple(b for b in range(w.ndim) if b != a)), mesh[a])
        for a in range(w.ndim)
    ]

    def score(cs):
        return imbalance(partition_loads(w, Partition(cuts=tuple(cs))))

    best = score(cuts)
    for _ in range(max(int(sweeps), 1)):
        improved = False
        for a in range(w.ndim):
            new_a, _ = _best_axis_cuts(_axis_profiles(w, cuts, a), mesh[a])
            if new_a != cuts[a]:
                trial = list(cuts)
                trial[a] = new_a
                s = score(trial)
                if s < best - 1e-12:
                    cuts, best, improved = trial, s, True
        if not improved:
            break
    return Partition(cuts=tuple(cuts))


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """One realizable ownership plan over a box histogram."""

    mesh_shape: Tuple[int, ...]
    partition: Partition         # cuts in box units (the histogram's grid)
    imbalance: float


def choose_partition(weights: np.ndarray, n_devices: int,
                     ownership: str = "rcb") -> PartitionPlan:
    """Pick the best realizable ownership plan for ``n_devices`` over a
    weight histogram — the partition-aware successor of the deprecated
    :func:`choose_mesh_shape`.

    ``ownership="equal"`` reproduces the historical equal-split scan
    exactly (same factorization order, same score, same tie-break);
    ``ownership="rcb"`` additionally cuts every factorization with
    :func:`plan_rectilinear`, realizing box-granular uneven ownership —
    the live analogue of the ``plan_rcb`` bound.  Returns the plan with
    cuts in **box units** (scale by ``Domain.box_factor`` for cell cuts).
    """
    if ownership not in ("equal", "rcb"):
        raise ValueError(
            f"unknown ownership {ownership!r}; expected 'equal' or 'rcb'")
    best: Optional[PartitionPlan] = None
    for mesh in _factorizations(n_devices, weights.ndim):
        if ownership == "equal":
            if not all(b % m == 0 for b, m in zip(weights.shape, mesh)):
                continue
            part = Partition.equal(weights.shape, mesh)
            score = imbalance(equal_split_loads(weights, mesh))
        else:
            if any(m > b for m, b in zip(mesh, weights.shape)):
                continue
            part = plan_rectilinear(weights, mesh)
            score = imbalance(partition_loads(weights, part))
        if best is None or score < best.imbalance:
            best = PartitionPlan(mesh_shape=mesh, partition=part,
                                 imbalance=score)
    if best is None:
        raise ValueError("no valid mesh factorization divides the histogram")
    return best


def _factorizations(n: int, ndim: int):
    """All ordered ``ndim``-tuples of positive ints with product ``n``,
    lexicographically ascending."""
    if ndim == 1:
        yield (n,)
        return
    for m in range(1, n + 1):
        if n % m == 0:
            for rest in _factorizations(n // m, ndim - 1):
                yield (m,) + rest


def choose_mesh_shape(weights: np.ndarray,
                      n_devices: int) -> Tuple[int, ...]:
    """DEPRECATED equal-split-only mesh picker: scan the divisor
    factorizations of ``n_devices`` (not just powers of two, so degraded
    counts like 3 or 6 factorize too) for the least equal-split imbalance;
    ties break toward smaller earlier axes.

    Use :func:`choose_partition` — it runs the identical scan for
    ``ownership="equal"`` (shim-parity is pinned by tests) and also cuts
    box-granular uneven partitions for ``ownership="rcb"``."""
    warnings.warn(
        "choose_mesh_shape is deprecated — use choose_partition(weights, "
        "n_devices, ownership='equal').mesh_shape, which also plans "
        "box-granular uneven ownership with ownership='rcb'",
        DeprecationWarning, stacklevel=2)
    return choose_partition(weights, n_devices,
                            ownership="equal").mesh_shape
