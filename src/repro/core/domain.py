"""The N-dimensional spatial ``Domain`` — one abstraction from 2-D sheets
to 3-D tissues.

Mirrors the paper's dimension-agnostic partitioning-grid formulation
(§2.1, §2.4.1) and BioDynaMo's ``Space``/``Environment`` decoupling: the
whole spatial stack (binning, aura exchange, neighbor sweep, migration,
load balancing) reasons over *axes*, never over named x/y coordinates, so
moving a model from a 2-D sheet to a 3-D tissue is a one-argument change —
the same seamlessness the paper claims for laptop-to-supercomputer (§3.4).

A :class:`Domain` is the single source of spatial truth threaded through
``Simulation``/``Engine``/``make_sim``:

* ``ndim`` (2 or 3) — derived from ``interior``.
* per-axis interior cell counts and per-axis device-mesh shape.
* per-axis boundary conditions (``"closed"`` | ``"toroidal"``), replacing
  the historical single global ``boundary`` string (a plain string is
  broadcast to every axis, so existing call sites read unchanged).
* the NSG cell size, per-cell slot capacity, and the partitioning-box
  factor (paper §2.4.1 granularity knob).

``Domain`` is frozen and hashable: it keys the engine's compiled step /
segment caches and ``grid.bin_agents_jit`` exactly as ``GridGeom`` did.
The historical 2-D :func:`repro.core.grid.GridGeom` survives as a thin
deprecated constructor shim returning a ``Domain``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Axis naming shared by the halo edge keys ("xm"/"xp"/.../"zp") and the
# spatial mesh axis names ("sx", "sy", "sz").
AXIS_CHARS = "xyz"

BOUNDARIES = ("closed", "toroidal")


def spatial_axis_names(ndim: int) -> Tuple[str, ...]:
    """Device-mesh axis names for an ``ndim``-dimensional spatial mesh."""
    return tuple("s" + AXIS_CHARS[a] for a in range(ndim))


def _as_int_tuple(x) -> Tuple[int, ...]:
    if isinstance(x, int):
        return (x,)
    return tuple(int(v) for v in x)


def normalize_boundary(boundary: Union[str, Sequence[str]],
                       ndim: int) -> Tuple[str, ...]:
    """Broadcast/validate a boundary spec to a per-axis tuple.

    Raises ``ValueError`` on unknown boundary values (historically any
    string was silently treated as ``"closed"`` everywhere except the
    comm permutation — now rejected at construction time).
    """
    if isinstance(boundary, str):
        boundary = (boundary,) * ndim
    b = tuple(str(v) for v in boundary)
    if len(b) != ndim:
        raise ValueError(
            f"boundary {b} has {len(b)} entries for a {ndim}-D domain")
    for v in b:
        if v not in BOUNDARIES:
            raise ValueError(
                f"unknown boundary {v!r}; expected one of {BOUNDARIES} "
                "(per axis, or one string broadcast to all axes)")
    return b


@dataclasses.dataclass(frozen=True)
class Partition:
    """Per-axis box-granular cut positions — uneven ownership over a
    rectilinear device mesh (paper §2.4.5; BioDynaMo's space partitioning).

    ``cuts[a]`` is a strictly increasing tuple of ``mesh_shape[a] + 1``
    cell coordinates from 0 to the global cell count along axis ``a``: the
    device at mesh coordinate ``c`` owns the global cell slab
    ``[cuts[a][c], cuts[a][c+1])`` along every axis.  Rectilinear cuts (one
    shared cut set per axis, not per-row) are what a ``ppermute``-based
    neighbor exchange can realize: neighbors along an axis then always
    share their cross-axis cut positions, so halo slabs stay aligned.

    The engine realizes a Partition with *padded* per-device grids: every
    device allocates the per-axis **maximum** slab width and masks binning,
    sweeping, and halo indices to its own owned widths — the memory cost is
    ``prod(max_w) / mean(prod(w))`` relative to perfectly-sized blocks
    (docs/load_balancing.md).  ``Partition.equal`` is the historical
    equal-split special case and normalizes away (``Domain`` drops it), so
    equal-split runs stay bit-exact on the legacy static-index paths.
    """

    cuts: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        cuts = tuple(tuple(int(v) for v in c) for c in self.cuts)
        if len(cuts) not in (2, 3):
            raise ValueError(
                f"Partition supports 2-D and 3-D spaces; got {len(cuts)} "
                "cut axes")
        for a, c in enumerate(cuts):
            if len(c) < 2 or c[0] != 0:
                raise ValueError(
                    f"axis {a} cuts {c} must start at 0 and contain at "
                    "least one slab")
            if any(hi <= lo for lo, hi in zip(c, c[1:])):
                raise ValueError(
                    f"axis {a} cuts {c} must be strictly increasing "
                    "(every device owns at least one cell per axis)")
        object.__setattr__(self, "cuts", cuts)

    @staticmethod
    def equal(global_cells: Sequence[int],
              mesh_shape: Sequence[int]) -> "Partition":
        """The historical equal-split partition (the bit-exact baseline)."""
        g = _as_int_tuple(global_cells)
        m = _as_int_tuple(mesh_shape)
        if len(g) != len(m) or any(gc % mm for gc, mm in zip(g, m)):
            raise ValueError(
                f"mesh {m} does not divide the global cell grid {g}")
        return Partition(cuts=tuple(
            tuple(i * (gc // mm) for i in range(mm + 1))
            for gc, mm in zip(g, m)))

    @staticmethod
    def from_widths(widths: Sequence[Sequence[int]]) -> "Partition":
        """Build from per-axis slab widths (cells)."""
        cuts = []
        for w in widths:
            c, acc = [0], 0
            for v in w:
                acc += int(v)
                c.append(acc)
            cuts.append(tuple(c))
        return Partition(cuts=tuple(cuts))

    @property
    def ndim(self) -> int:
        return len(self.cuts)

    @property
    def mesh_shape(self) -> Tuple[int, ...]:
        return tuple(len(c) - 1 for c in self.cuts)

    @property
    def global_cells(self) -> Tuple[int, ...]:
        return tuple(c[-1] for c in self.cuts)

    @property
    def widths(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-axis slab widths in cells."""
        return tuple(tuple(hi - lo for lo, hi in zip(c, c[1:]))
                     for c in self.cuts)

    @property
    def max_widths(self) -> Tuple[int, ...]:
        """Per-axis padded slab width (the per-device grid allocation)."""
        return tuple(max(w) for w in self.widths)

    @property
    def is_equal(self) -> bool:
        return all(len(set(w)) == 1 for w in self.widths)

    def scale(self, factor: int) -> "Partition":
        """Cuts in a coarser unit (boxes) -> cuts in cells."""
        return Partition(cuts=tuple(
            tuple(v * int(factor) for v in c) for c in self.cuts))

    def pad_fraction(self) -> float:
        """Padding memory overhead: allocated padded cells / owned cells."""
        alloc = math.prod(self.max_widths) * math.prod(self.mesh_shape)
        owned = math.prod(self.global_cells)
        return alloc / owned - 1.0


@dataclasses.dataclass(frozen=True)
class Domain:
    """Static N-D spatial specification of one run's partitioning + NSG.

    Attributes:
      cell_size: NSG cell edge length (>= max interaction radius).
      interior: per-axis interior cell counts per device, length ``ndim``.
      mesh_shape: per-axis spatial device mesh, length ``ndim`` (``None``
        or an all-ones tuple of any length defaults to single device).
      cap: per-cell slot capacity K.
      boundary: per-axis ``"closed"`` | ``"toroidal"`` tuple; a plain
        string is broadcast to every axis.
      box_factor: partitioning-box length as a multiple of the NSG cell
        (paper §2.4.1); load-balancing granularity only.
      partition: optional :class:`Partition` realizing *uneven* box-granular
        ownership (cut positions in cells).  When set, ``interior`` is the
        per-axis **padded** slab width (the per-axis maximum over devices)
        and every device masks its grid down to its own owned widths; an
        equal partition normalizes to ``None`` so equal-split runs stay on
        the legacy bit-exact static-index paths.
    """

    cell_size: float
    interior: Tuple[int, ...]
    mesh_shape: Tuple[int, ...] = None
    cap: int = 24
    boundary: Union[str, Tuple[str, ...]] = "closed"
    box_factor: int = 1
    partition: "Partition" = None

    def __post_init__(self):
        interior = _as_int_tuple(self.interior)
        nd = len(interior)
        if nd not in (2, 3):
            raise ValueError(
                f"Domain supports 2-D and 3-D spaces; got interior "
                f"{interior} ({nd}-D)")
        mesh = self.mesh_shape
        if mesh is None:
            mesh = (1,) * nd
        mesh = _as_int_tuple(mesh)
        if len(mesh) != nd and all(m == 1 for m in mesh):
            # the historical (1, 1) single-device default broadcasts to
            # any dimensionality
            mesh = (1,) * nd
        if len(mesh) != nd:
            raise ValueError(
                f"mesh_shape {mesh} has {len(mesh)} axes for a {nd}-D "
                f"domain (interior {interior})")
        if any(i < 1 for i in interior) or any(m < 1 for m in mesh):
            raise ValueError(
                f"interior {interior} and mesh_shape {mesh} must be >= 1 "
                "per axis")
        part = self.partition
        if part is not None:
            if not isinstance(part, Partition):
                part = Partition(cuts=tuple(part))
            if part.mesh_shape != mesh:
                raise ValueError(
                    f"partition mesh {part.mesh_shape} does not match "
                    f"mesh_shape {mesh}")
            if part.max_widths != interior:
                raise ValueError(
                    f"interior {interior} must equal the partition's "
                    f"per-axis max slab widths {part.max_widths} (the "
                    "padded per-device grid); build via Domain.repartition")
            if self.box_factor > 1 and any(
                    v % self.box_factor for c in part.cuts for v in c):
                # fail where the partition is supplied, not mid-run in the
                # first rebalance check's box-histogram reduction
                raise ValueError(
                    f"partition cuts {part.cuts} are not aligned to "
                    f"box_factor {self.box_factor} — cut positions must "
                    "lie on partitioning-box boundaries")
            if part.is_equal:
                # equal-split cuts ARE the legacy geometry: normalize away
                # so hashing/compiled-cache keys and the static-index code
                # paths are shared bit-exactly with pre-Partition Domains
                part = None
        object.__setattr__(self, "interior", interior)
        object.__setattr__(self, "mesh_shape", mesh)
        object.__setattr__(self, "partition", part)
        object.__setattr__(self, "boundary",
                           normalize_boundary(self.boundary, nd))

    # ------------------------------------------------------------------
    # Derived geometry
    # ------------------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.interior)

    @property
    def local_shape(self) -> Tuple[int, ...]:
        """Per-device cell grid including the one-cell halo ring."""
        return tuple(i + 2 for i in self.interior)

    @property
    def uneven(self) -> bool:
        """True when this Domain carries a genuinely uneven Partition (the
        masked-index code paths; equal partitions normalize to None)."""
        return self.partition is not None

    @property
    def global_cells(self) -> Tuple[int, ...]:
        if self.partition is not None:
            return self.partition.global_cells
        return tuple(i * m for i, m in zip(self.interior, self.mesh_shape))

    @property
    def domain_size(self) -> Tuple[float, ...]:
        return tuple(g * self.cell_size for g in self.global_cells)

    @property
    def n_devices(self) -> int:
        return math.prod(self.mesh_shape)

    @property
    def toroidal(self) -> Tuple[bool, ...]:
        """Per-axis toroidal flags."""
        return tuple(b == "toroidal" for b in self.boundary)

    @property
    def box_grid(self) -> Tuple[int, ...]:
        """Global partitioning-box grid (paper §2.4.1): the granularity at
        which the load-balance planners reason, ``box_factor`` NSG cells
        per box edge."""
        g = self.global_cells
        if any(gc % self.box_factor for gc in g):
            raise ValueError(
                f"box_factor {self.box_factor} must divide the global cell "
                f"grid {g}")
        return tuple(gc // self.box_factor for gc in g)

    # ------------------------------------------------------------------
    # Transformations
    # ------------------------------------------------------------------
    def with_mesh_shape(self, mesh_shape: Sequence[int]) -> "Domain":
        """Same global domain re-partitioned equally over a different device
        mesh — the geometry half of an equal-split re-shard (core.reshard).
        The global cell grid is invariant; only the per-device interior
        block changes (any uneven partition is dropped)."""
        g = self.global_cells
        mesh = _as_int_tuple(mesh_shape)
        if len(mesh) != self.ndim:
            raise ValueError(
                f"mesh {mesh} has {len(mesh)} axes for a {self.ndim}-D "
                "domain")
        if any(gc % m for gc, m in zip(g, mesh)):
            raise ValueError(
                f"mesh {mesh} does not divide the global cell grid {g}")
        return dataclasses.replace(
            self, mesh_shape=mesh, partition=None,
            interior=tuple(gc // m for gc, m in zip(g, mesh)))

    def repartition(self, partition: "Partition") -> "Domain":
        """Same global domain re-cut along a :class:`Partition` — the
        geometry half of an uneven re-shard.  The per-device grid pads to
        the partition's per-axis max slab width."""
        if partition.global_cells != self.global_cells:
            raise ValueError(
                f"partition covers {partition.global_cells} cells; this "
                f"domain has {self.global_cells}")
        return dataclasses.replace(
            self, mesh_shape=partition.mesh_shape,
            interior=partition.max_widths,
            partition=partition)

    def device_origin(self, coords: Tuple[Array, ...]) -> Array:
        """World-space origin of the device's owned region, from the
        per-axis device-mesh coordinates."""
        if self.partition is not None:
            starts = [
                jnp.asarray(np.asarray(c[:-1], np.float64) * self.cell_size,
                            jnp.float32)
                for c in self.partition.cuts
            ]
            return jnp.stack([s[c] for s, c in zip(starts, coords)]
                             ).astype(jnp.float32)
        return jnp.stack([
            c * (i * self.cell_size)
            for c, i in zip(coords, self.interior)
        ]).astype(jnp.float32)

    def owned_widths(self, coords: Tuple[Array, ...]
                     ) -> Optional[Tuple[Array, ...]]:
        """Per-axis owned slab widths (cells) of the device at ``coords``
        — traced-friendly scalars for the masked grid/halo/migration
        indices.  ``None`` on an equal split (legacy static indices)."""
        if self.partition is None:
            return None
        return tuple(
            jnp.asarray(w, jnp.int32)[c]
            for w, c in zip(self.partition.widths, coords))
