"""The N-dimensional spatial ``Domain`` — one abstraction from 2-D sheets
to 3-D tissues.

Mirrors the paper's dimension-agnostic partitioning-grid formulation
(§2.1, §2.4.1) and BioDynaMo's ``Space``/``Environment`` decoupling: the
whole spatial stack (binning, aura exchange, neighbor sweep, migration,
load balancing) reasons over *axes*, never over named x/y coordinates, so
moving a model from a 2-D sheet to a 3-D tissue is a one-argument change —
the same seamlessness the paper claims for laptop-to-supercomputer (§3.4).

A :class:`Domain` is the single source of spatial truth threaded through
``Simulation``/``Engine``/``make_sim``:

* ``ndim`` (2 or 3) — derived from ``interior``.
* per-axis interior cell counts and per-axis device-mesh shape.
* per-axis boundary conditions (``"closed"`` | ``"toroidal"``), replacing
  the historical single global ``boundary`` string (a plain string is
  broadcast to every axis, so existing call sites read unchanged).
* the NSG cell size, per-cell slot capacity, and the partitioning-box
  factor (paper §2.4.1 granularity knob).

``Domain`` is frozen and hashable: it keys the engine's compiled step /
segment caches and ``grid.bin_agents_jit`` exactly as ``GridGeom`` did.
The historical 2-D :func:`repro.core.grid.GridGeom` survives as a thin
deprecated constructor shim returning a ``Domain``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array

# Axis naming shared by the halo edge keys ("xm"/"xp"/.../"zp") and the
# spatial mesh axis names ("sx", "sy", "sz").
AXIS_CHARS = "xyz"

BOUNDARIES = ("closed", "toroidal")


def spatial_axis_names(ndim: int) -> Tuple[str, ...]:
    """Device-mesh axis names for an ``ndim``-dimensional spatial mesh."""
    return tuple("s" + AXIS_CHARS[a] for a in range(ndim))


def _as_int_tuple(x) -> Tuple[int, ...]:
    if isinstance(x, int):
        return (x,)
    return tuple(int(v) for v in x)


def normalize_boundary(boundary: Union[str, Sequence[str]],
                       ndim: int) -> Tuple[str, ...]:
    """Broadcast/validate a boundary spec to a per-axis tuple.

    Raises ``ValueError`` on unknown boundary values (historically any
    string was silently treated as ``"closed"`` everywhere except the
    comm permutation — now rejected at construction time).
    """
    if isinstance(boundary, str):
        boundary = (boundary,) * ndim
    b = tuple(str(v) for v in boundary)
    if len(b) != ndim:
        raise ValueError(
            f"boundary {b} has {len(b)} entries for a {ndim}-D domain")
    for v in b:
        if v not in BOUNDARIES:
            raise ValueError(
                f"unknown boundary {v!r}; expected one of {BOUNDARIES} "
                "(per axis, or one string broadcast to all axes)")
    return b


@dataclasses.dataclass(frozen=True)
class Domain:
    """Static N-D spatial specification of one run's partitioning + NSG.

    Attributes:
      cell_size: NSG cell edge length (>= max interaction radius).
      interior: per-axis interior cell counts per device, length ``ndim``.
      mesh_shape: per-axis spatial device mesh, length ``ndim`` (``None``
        or an all-ones tuple of any length defaults to single device).
      cap: per-cell slot capacity K.
      boundary: per-axis ``"closed"`` | ``"toroidal"`` tuple; a plain
        string is broadcast to every axis.
      box_factor: partitioning-box length as a multiple of the NSG cell
        (paper §2.4.1); load-balancing granularity only.
    """

    cell_size: float
    interior: Tuple[int, ...]
    mesh_shape: Tuple[int, ...] = None
    cap: int = 24
    boundary: Union[str, Tuple[str, ...]] = "closed"
    box_factor: int = 1

    def __post_init__(self):
        interior = _as_int_tuple(self.interior)
        nd = len(interior)
        if nd not in (2, 3):
            raise ValueError(
                f"Domain supports 2-D and 3-D spaces; got interior "
                f"{interior} ({nd}-D)")
        mesh = self.mesh_shape
        if mesh is None:
            mesh = (1,) * nd
        mesh = _as_int_tuple(mesh)
        if len(mesh) != nd and all(m == 1 for m in mesh):
            # the historical (1, 1) single-device default broadcasts to
            # any dimensionality
            mesh = (1,) * nd
        if len(mesh) != nd:
            raise ValueError(
                f"mesh_shape {mesh} has {len(mesh)} axes for a {nd}-D "
                f"domain (interior {interior})")
        if any(i < 1 for i in interior) or any(m < 1 for m in mesh):
            raise ValueError(
                f"interior {interior} and mesh_shape {mesh} must be >= 1 "
                "per axis")
        object.__setattr__(self, "interior", interior)
        object.__setattr__(self, "mesh_shape", mesh)
        object.__setattr__(self, "boundary",
                           normalize_boundary(self.boundary, nd))

    # ------------------------------------------------------------------
    # Derived geometry
    # ------------------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.interior)

    @property
    def local_shape(self) -> Tuple[int, ...]:
        """Per-device cell grid including the one-cell halo ring."""
        return tuple(i + 2 for i in self.interior)

    @property
    def global_cells(self) -> Tuple[int, ...]:
        return tuple(i * m for i, m in zip(self.interior, self.mesh_shape))

    @property
    def domain_size(self) -> Tuple[float, ...]:
        return tuple(g * self.cell_size for g in self.global_cells)

    @property
    def n_devices(self) -> int:
        return math.prod(self.mesh_shape)

    @property
    def toroidal(self) -> Tuple[bool, ...]:
        """Per-axis toroidal flags."""
        return tuple(b == "toroidal" for b in self.boundary)

    @property
    def box_grid(self) -> Tuple[int, ...]:
        """Global partitioning-box grid (paper §2.4.1): the granularity at
        which the load-balance planners reason, ``box_factor`` NSG cells
        per box edge."""
        g = self.global_cells
        if any(gc % self.box_factor for gc in g):
            raise ValueError(
                f"box_factor {self.box_factor} must divide the global cell "
                f"grid {g}")
        return tuple(gc // self.box_factor for gc in g)

    # ------------------------------------------------------------------
    # Transformations
    # ------------------------------------------------------------------
    def with_mesh_shape(self, mesh_shape: Sequence[int]) -> "Domain":
        """Same global domain re-partitioned over a different device mesh —
        the geometry half of a re-shard (core.reshard).  The global cell
        grid is invariant; only the per-device interior block changes."""
        g = self.global_cells
        mesh = _as_int_tuple(mesh_shape)
        if len(mesh) != self.ndim:
            raise ValueError(
                f"mesh {mesh} has {len(mesh)} axes for a {self.ndim}-D "
                "domain")
        if any(gc % m for gc, m in zip(g, mesh)):
            raise ValueError(
                f"mesh {mesh} does not divide the global cell grid {g}")
        return dataclasses.replace(
            self, mesh_shape=mesh,
            interior=tuple(gc // m for gc, m in zip(g, mesh)))

    def device_origin(self, coords: Tuple[Array, ...]) -> Array:
        """World-space origin of the device's interior region, from the
        per-axis device-mesh coordinates."""
        return jnp.stack([
            c * (i * self.cell_size)
            for c, i in zip(coords, self.interior)
        ]).astype(jnp.float32)
