"""Structure-of-Arrays agent container — the TPU-native analogue of TeraAgent IO.

TeraAgent's serialization insight (paper §2.2): make the wire format identical to
the in-memory format so that (de)serialization degenerates to a memcpy plus pointer
fix-up.  On TPU the idiomatic equivalent is stronger: agents live in dense, fixed-
schema structure-of-arrays slabs, so any halo/migration transfer is a plain array
collective — the receive buffer *is* the live data structure and there is zero
pack/unpack work by construction.  Pointer fields (the paper's ``AgentPointer``)
become integer global-identifier columns; behaviour dispatch (the paper's vtable
fix-up) becomes data-driven mask columns.

Layout: every attribute is an array of shape ``(hx, hy, K, *attr_shape)`` where
``(hx, hy)`` is the local neighbor-search-grid (NSG) cell grid *including a one-
cell halo ring* and ``K`` is the per-cell slot capacity.  A boolean ``valid`` mask
marks occupied slots.  Global agent identifiers follow the paper's
``<rank, counter>`` scheme as two int32 columns.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Reserved attribute names every AgentSoA carries.
POS = "pos"          # (..., 2) float32 absolute position
GID_RANK = "gid_rank"    # int32 — rank that created the agent
GID_COUNT = "gid_count"  # int32 — strictly increasing per-rank counter

RESERVED = (POS, GID_RANK, GID_COUNT)


@dataclasses.dataclass(frozen=True)
class AgentSchema:
    """Static schema: user attribute name -> (trailing shape, dtype).

    The schema is the TPU analogue of the paper's "no schema evolution" design
    point: it is fixed at trace time, so transfers carry no runtime type tags.
    """

    fields: Tuple[Tuple[str, Tuple[int, ...], Any], ...]

    @staticmethod
    def create(spec: Mapping[str, Tuple[Tuple[int, ...], Any]]) -> "AgentSchema":
        items = []
        for name, (shape, dtype) in sorted(spec.items()):
            if name in RESERVED or name == "valid":
                raise ValueError(f"attribute name {name!r} is reserved")
            items.append((name, tuple(shape), jnp.dtype(dtype)))
        return AgentSchema(fields=tuple(items))

    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _, _ in self.fields)

    def all_specs(self) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
        """Schema including the reserved columns."""
        out: Dict[str, Tuple[Tuple[int, ...], Any]] = {
            POS: ((2,), jnp.float32),
            GID_RANK: ((), jnp.int32),
            GID_COUNT: ((), jnp.int32),
        }
        for name, shape, dtype in self.fields:
            out[name] = (shape, dtype)
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AgentSoA:
    """Agents stored in NSG cell-slot layout: arrays of shape (hx, hy, K, ...)."""

    attrs: Dict[str, Array]   # each (hx, hy, K, *trailing)
    valid: Array              # (hx, hy, K) bool

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        keys = tuple(sorted(self.attrs))
        children = tuple(self.attrs[k] for k in keys) + (self.valid,)
        return children, keys

    @classmethod
    def tree_unflatten(cls, keys, children):
        attrs = dict(zip(keys, children[:-1]))
        return cls(attrs=attrs, valid=children[-1])

    # -- convenience -----------------------------------------------------
    @property
    def grid_shape(self) -> Tuple[int, int]:
        return self.valid.shape[0], self.valid.shape[1]

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[2])

    @property
    def pos(self) -> Array:
        return self.attrs[POS]

    def count(self) -> Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    def replace(self, **kw) -> "AgentSoA":
        return dataclasses.replace(self, **kw)

    def map_attrs(self, fn: Callable[[str, Array], Array]) -> "AgentSoA":
        return self.replace(attrs={k: fn(k, v) for k, v in self.attrs.items()})

    @staticmethod
    def empty(schema: AgentSchema, hx: int, hy: int, cap: int) -> "AgentSoA":
        attrs = {}
        for name, (shape, dtype) in schema.all_specs().items():
            attrs[name] = jnp.zeros((hx, hy, cap) + shape, dtype=dtype)
        valid = jnp.zeros((hx, hy, cap), dtype=jnp.bool_)
        return AgentSoA(attrs=attrs, valid=valid)


def flat_view(soa: AgentSoA) -> Tuple[Dict[str, Array], Array]:
    """Flatten (hx, hy, K, ...) -> (N, ...) for sorting/packing passes."""
    hx, hy = soa.grid_shape
    k = soa.capacity
    n = hx * hy * k
    attrs = {name: a.reshape((n,) + a.shape[3:]) for name, a in soa.attrs.items()}
    return attrs, soa.valid.reshape((n,))


def from_flat(
    attrs: Dict[str, Array], valid: Array, hx: int, hy: int, cap: int
) -> AgentSoA:
    out = {name: a.reshape((hx, hy, cap) + a.shape[1:]) for name, a in attrs.items()}
    return AgentSoA(attrs=out, valid=valid.reshape((hx, hy, cap)))


def concat_flat(
    a: Tuple[Dict[str, Array], Array], b: Tuple[Dict[str, Array], Array]
) -> Tuple[Dict[str, Array], Array]:
    """Concatenate two flat agent sets (used for spawn + received migrants)."""
    attrs = {k: jnp.concatenate([a[0][k], b[0][k]], axis=0) for k in a[0]}
    return attrs, jnp.concatenate([a[1], b[1]], axis=0)
