"""Structure-of-Arrays agent container — the TPU-native analogue of TeraAgent IO.

TeraAgent's serialization insight (paper §2.2): make the wire format identical to
the in-memory format so that (de)serialization degenerates to a memcpy plus pointer
fix-up.  On TPU the idiomatic equivalent is stronger: agents live in dense, fixed-
schema structure-of-arrays slabs, so any halo/migration transfer is a plain array
collective — the receive buffer *is* the live data structure and there is zero
pack/unpack work by construction.  Pointer fields (the paper's ``AgentPointer``)
become integer global-identifier columns; behaviour dispatch (the paper's vtable
fix-up) becomes data-driven mask columns.

Layout: every attribute is an array of shape ``(*grid, K, *attr_shape)`` where
``grid`` is the local neighbor-search-grid (NSG) cell grid — 2-D ``(hx, hy)``
or 3-D ``(hx, hy, hz)`` per the :class:`repro.core.domain.Domain` — *including
a one-cell halo ring* and ``K`` is the per-cell slot capacity.  A boolean
``valid`` mask marks occupied slots.  Global agent identifiers follow the
paper's ``<rank, counter>`` scheme as two int32 columns.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Reserved attribute names every AgentSoA carries.
POS = "pos"          # (..., ndim) float32 absolute position
GID_RANK = "gid_rank"    # int32 — rank that created the agent
GID_COUNT = "gid_count"  # int32 — strictly increasing per-rank counter

RESERVED = (POS, GID_RANK, GID_COUNT)


@dataclasses.dataclass(frozen=True)
class AgentSchema:
    """Static schema: user attribute name -> (trailing shape, dtype).

    The schema is the TPU analogue of the paper's "no schema evolution" design
    point: it is fixed at trace time, so transfers carry no runtime type tags.
    """

    fields: Tuple[Tuple[str, Tuple[int, ...], Any], ...]

    @staticmethod
    def create(spec: Mapping[str, Tuple[Tuple[int, ...], Any]]) -> "AgentSchema":
        items = []
        for name, (shape, dtype) in sorted(spec.items()):
            if name in RESERVED or name == "valid":
                raise ValueError(f"attribute name {name!r} is reserved")
            items.append((name, tuple(shape), jnp.dtype(dtype)))
        return AgentSchema(fields=tuple(items))

    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _, _ in self.fields)

    def all_specs(self, ndim: int = 2) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
        """Schema including the reserved columns; ``ndim`` sets the spatial
        dimensionality of the ``pos`` column (the Domain's ``ndim``)."""
        out: Dict[str, Tuple[Tuple[int, ...], Any]] = {
            POS: ((ndim,), jnp.float32),
            GID_RANK: ((), jnp.int32),
            GID_COUNT: ((), jnp.int32),
        }
        for name, shape, dtype in self.fields:
            out[name] = (shape, dtype)
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AgentSoA:
    """Agents stored in NSG cell-slot layout: arrays of shape (*grid, K, ...)."""

    attrs: Dict[str, Array]   # each (*grid, K, *trailing)
    valid: Array              # (*grid, K) bool

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        keys = tuple(sorted(self.attrs))
        children = tuple(self.attrs[k] for k in keys) + (self.valid,)
        return children, keys

    @classmethod
    def tree_unflatten(cls, keys, children):
        attrs = dict(zip(keys, children[:-1]))
        return cls(attrs=attrs, valid=children[-1])

    # -- convenience -----------------------------------------------------
    @property
    def grid_shape(self) -> Tuple[int, ...]:
        return tuple(self.valid.shape[:-1])

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[-1])

    @property
    def pos(self) -> Array:
        return self.attrs[POS]

    def count(self) -> Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    def replace(self, **kw) -> "AgentSoA":
        return dataclasses.replace(self, **kw)

    def map_attrs(self, fn: Callable[[str, Array], Array]) -> "AgentSoA":
        return self.replace(attrs={k: fn(k, v) for k, v in self.attrs.items()})

    @staticmethod
    def empty(schema: AgentSchema, grid_shape: Tuple[int, ...], cap: int
              ) -> "AgentSoA":
        grid_shape = tuple(grid_shape)
        attrs = {}
        for name, (shape, dtype) in schema.all_specs(len(grid_shape)).items():
            attrs[name] = jnp.zeros(grid_shape + (cap,) + shape, dtype=dtype)
        valid = jnp.zeros(grid_shape + (cap,), dtype=jnp.bool_)
        return AgentSoA(attrs=attrs, valid=valid)


def flat_view(soa: AgentSoA) -> Tuple[Dict[str, Array], Array]:
    """Flatten (*grid, K, ...) -> (N, ...) for sorting/packing passes."""
    nd = soa.valid.ndim          # grid axes + the slot axis
    n = int(np.prod(soa.valid.shape))
    attrs = {name: a.reshape((n,) + a.shape[nd:])
             for name, a in soa.attrs.items()}
    return attrs, soa.valid.reshape((n,))


def concat_flat(
    a: Tuple[Dict[str, Array], Array], b: Tuple[Dict[str, Array], Array]
) -> Tuple[Dict[str, Array], Array]:
    """Concatenate two flat agent sets (used for spawn + received migrants)."""
    attrs = {k: jnp.concatenate([a[0][k], b[0][k]], axis=0) for k in a[0]}
    return attrs, jnp.concatenate([a[1], b[1]], axis=0)
