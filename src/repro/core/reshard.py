"""Re-shard runtime: dynamic load balancing wired into the live engine.

The paper (§2.4.5) re-partitions at runtime with global RCB or diffusive
planners and notes that a new global partitioning "differs substantially"
from the old one, "causing mass migrations".  On TPU, XLA's static shapes
make per-iteration ownership changes an anti-pattern, so this module applies
load balancing at *re-shard boundaries* (DESIGN note in core.load_balance):

1. ``occupancy_histogram`` reduces the sharded :class:`SimState` to the tiny
   host-side per-box weight map the planners consume — agent counts per
   partitioning box, optionally scaled by measured per-device runtimes (the
   paper weights boxes by the owning rank's last-iteration runtime).
2. :class:`Rebalancer` checks ``imbalance()`` at a configurable cadence
   inside ``Engine.run``/``Engine.drive``; past a threshold it consults the
   planners (``choose_partition`` for the realizable plan — equal-split or
   box-granular uneven per its ``ownership`` knob; ``plan_rcb`` /
   ``plan_diffusive`` as reported bounds) and triggers a re-shard.
3. The mass migration is paid exactly once per re-shard.  On an unchanged
   device count ``reshard_state`` takes the *device-to-device* fast path
   (:func:`reshard_state_device`): one compiled global re-bin whose outputs
   are pinned to the new mesh, so XLA lowers the layout change to
   collective permutes and no agent bytes ever cross the host boundary.
   Otherwise (elastic restores, single-device geometries) the legacy host
   path runs: ``flatten_state`` gathers every live agent to host and
   ``reshard_state`` re-initializes through ``Engine.init_state``.  Both
   preserve global agent identifiers, the RNG lineage, the iteration
   counter, and the cumulative drop diagnostics — bit-exactly the same
   result either way.  Delta-encoding references are reset, so the first
   aura exchange after a re-shard must be a full refresh (the drivers force
   ``full_halo=True`` on the next step).  ``Rebalancer(defer=True)``
   additionally overlaps the *planning* input with compute: the validity
   snapshot is copied device-to-host asynchronously while the old mesh
   keeps stepping, and the plan+apply lands one step later.

Realizability note: the engine shards one uniform SoA over an N-D spatial
device mesh.  Realizable plans are the equal-split factorizations AND —
since the uneven-ownership refactor — box-granular rectilinear partitions
(``Rebalancer(ownership="rcb")``): per-axis cut positions realized with
padded per-device grids and masked halo exchange (``Partition`` on
``Domain``, docs/load_balancing.md).  ``plan_rcb``'s *hierarchical*
ownership maps remain report-only bounds (their per-half independent cuts
have no aligned ``ppermute`` realization); the ``rebalance_uneven_*``
bench rows show the realized rectilinear cuts matching or beating them on
the clustered workloads.  The same flatten→plan→re-init path makes the
engine *elastic*: restoring a checkpoint onto a different device count is
a re-shard whose histogram comes from the checkpoint
(distributed.elastic.elastic_restore_abm) — and it re-cuts uneven when
the checkpointed run was uneven.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent_soa import POS, AgentSoA
from repro.core.compile_cache import memoize
from repro.core.domain import Domain, Partition
from repro.core.engine import Engine, SimState
from repro.core.load_balance import (
    choose_partition,
    device_loads,
    equal_split_loads,
    imbalance,
    partition_loads,
    plan_diffusive,
    plan_rcb,
    widths_to_ownership,
)


# ---------------------------------------------------------------------------
# 1. Occupancy histogram extraction
# ---------------------------------------------------------------------------

def _interleaved_shape(geom: Domain) -> Tuple[int, ...]:
    """(m0, i0, m1, i1, ...) device-block/interior interleave."""
    out: Tuple[int, ...] = ()
    for m, i in zip(geom.mesh_shape, geom.interior):
        out += (m, i)
    return out


def _interior_axes(geom: Domain) -> Tuple[int, ...]:
    """Axes of the interleaved layout holding per-device interior cells."""
    return tuple(range(1, 2 * geom.ndim, 2))


def _interior_blocks(geom: Domain, arr: np.ndarray) -> np.ndarray:
    """Global ``(m0*h0, m1*h1, ..., ...)`` array -> interleaved
    ``(m0, i0, m1, i1, ..., ...)`` interior (ring cells hold aura copies of
    neighbor agents and must be excluded from any global reduction)."""
    nd = geom.ndim
    a = np.asarray(arr)
    shape: Tuple[int, ...] = ()
    for m, h in zip(geom.mesh_shape, geom.local_shape):
        shape += (m, h)
    a = a.reshape(shape + a.shape[nd:])
    sl: Tuple = ()
    for _ in range(nd):
        sl += (slice(None), slice(1, -1))
    return a[sl]


def _owned_valid_blocks(geom: Domain, valid) -> np.ndarray:
    """Interleaved interior validity with, under uneven ownership, every
    slot outside a device's owned widths zeroed: the padded interior still
    contains the aura ring (at interior index ``owned[a]``) and padding
    cells, which hold neighbor copies / nothing and must be excluded from
    any global reduction exactly like the equal split's ring cells."""
    blocks = np.array(_interior_blocks(geom, valid))
    if geom.uneven:
        widths = geom.partition.widths
        for a in range(geom.ndim):
            for ci, w in enumerate(widths[a]):
                sl = [slice(None)] * blocks.ndim
                sl[2 * a] = ci
                sl[2 * a + 1] = slice(w, None)
                blocks[tuple(sl)] = False
    return blocks


def _assemble_global(geom: Domain, interleaved: np.ndarray) -> np.ndarray:
    """Interleaved per-device owned data -> the true global cell grid.  On
    the equal split this is the legacy contiguous reshape; under uneven
    ownership each device's owned slab lands at its cut positions (padding
    is dropped), so downstream box reductions respect the cuts."""
    nd = geom.ndim
    trailing = interleaved.shape[2 * nd:]
    if not geom.uneven:
        return interleaved.reshape(geom.global_cells + trailing)
    part = geom.partition
    out = np.zeros(geom.global_cells + trailing, dtype=interleaved.dtype)
    for coords in np.ndindex(*geom.mesh_shape):
        src: Tuple = ()
        dst: Tuple = ()
        for a in range(nd):
            lo, hi = part.cuts[a][coords[a]], part.cuts[a][coords[a] + 1]
            src += (coords[a], slice(0, hi - lo))
            dst += (slice(lo, hi),)
        out[dst] = interleaved[src]
    return out


def _per_device_sums(geom: Domain, arr: np.ndarray) -> np.ndarray:
    """Global cell grid -> per-device sums (``mesh_shape``), respecting
    cut positions under uneven ownership."""
    if not geom.uneven:
        return np.asarray(arr).reshape(_interleaved_shape(geom)).sum(
            axis=_interior_axes(geom))
    part = geom.partition
    out = np.zeros(geom.mesh_shape, dtype=np.float64)
    for coords in np.ndindex(*geom.mesh_shape):
        sl = tuple(
            slice(part.cuts[a][coords[a]], part.cuts[a][coords[a] + 1])
            for a in range(geom.ndim))
        out[coords] = np.asarray(arr)[sl].sum()
    return out


def realized_loads(geom: Domain, hist: np.ndarray) -> np.ndarray:
    """Per-device loads of the *live* ownership over a box histogram —
    equal-split blocks, or the Domain's Partition cuts when uneven."""
    if geom.uneven:
        bf = geom.box_factor
        cuts = geom.partition.cuts
        if any(v % bf for c in cuts for v in c):
            raise ValueError(
                f"partition cuts {cuts} are not aligned to box_factor {bf}")
        return partition_loads(
            hist, Partition(cuts=tuple(tuple(v // bf for v in c)
                                       for c in cuts)))
    return equal_split_loads(hist, geom.mesh_shape)


def occupancy_histogram(
    geom: Domain,
    state: SimState,
    runtimes: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Per-partitioning-box weight map (the Domain's ``box_grid`` shape)
    for the planners.

    The base weight is the live-agent count per box.  With ``runtimes``
    (a ``mesh_shape`` array of last-iteration wall-clock per device) each
    device's boxes are scaled by its measured time per agent, matching the
    paper's runtime-weighted box loads — a box full of expensive agents
    then weighs more than one full of cheap agents.
    """
    return _histogram_from_valid(geom, state.soa.valid, runtimes)


def _histogram_from_valid(
    geom: Domain,
    valid,
    runtimes: Optional[np.ndarray] = None,
) -> np.ndarray:
    """:func:`occupancy_histogram` body over a bare validity array — the
    deferred-plan path feeds it an async host snapshot taken one step
    earlier, so the old mesh keeps stepping while the copy lands."""
    nd = geom.ndim
    counts = _owned_valid_blocks(geom, valid).sum(axis=-1)
    if runtimes is not None:
        rt = np.asarray(runtimes, np.float64).reshape(geom.mesh_shape)
        dev_counts = counts.sum(axis=_interior_axes(geom))
        total = float(counts.sum())
        per_agent = rt / np.maximum(dev_counts, 1.0)
        expand: Tuple[int, ...] = ()
        for m in geom.mesh_shape:
            expand += (m, 1)
        counts = counts * per_agent.reshape(expand)
        # renormalize so the histogram total still reads as an agent count
        # (empty devices contribute nothing, so they cannot skew the scale)
        if counts.sum() > 0:
            counts = counts * (total / counts.sum())
    cells = _assemble_global(geom, counts)
    bf = geom.box_factor
    boxed: Tuple[int, ...] = ()
    for b in geom.box_grid:
        boxed += (b, bf)
    return cells.reshape(boxed).sum(
        axis=tuple(range(1, 2 * nd, 2))).astype(np.float64)


def current_imbalance(geom: Domain, state: SimState,
                      runtimes: Optional[np.ndarray] = None) -> float:
    """``imbalance()`` of the live ownership (equal split or the Domain's
    uneven Partition)."""
    hist = occupancy_histogram(geom, state, runtimes)
    return imbalance(realized_loads(geom, hist))


def estimate_device_runtimes(geom: Domain, state: SimState,
                             wall_s: float) -> np.ndarray:
    """Split one measured host-side step wall time into per-device runtimes.

    In a single-controller SPMD step every device finishes inside one XLA
    executable, so the host can only measure the *total* step time; the
    paper's per-rank iteration timers have no direct analogue.  What the
    host can attribute is each device's share of the pair-interaction work —
    the dominant cost — measured from the live state: per NSG cell,
    ``occupancy * (3^D neighborhood occupancy)`` counts the pair evaluations
    the interaction sweep actually performs (a quadratic-in-density signal,
    unlike the linear agent count the unweighted histogram uses).  The
    measured wall clock calibrates the absolute scale; the work shares
    distribute it.  The 3^D sum uses closed (zero-padded) edges — for
    toroidal domains this slightly underweights seam cells, which is noise
    at re-shard granularity.

    Returns a ``mesh_shape`` float array suitable for
    ``Rebalancer.runtimes`` / ``occupancy_histogram(..., runtimes=...)``.
    """
    nd = geom.ndim
    occ = _owned_valid_blocks(geom, state.soa.valid).sum(axis=-1)
    cells = _assemble_global(geom, occ).astype(np.float64)
    padded = np.pad(cells, 1)
    nbhd = sum(
        padded[tuple(slice(1 + o, 1 + o + s)
                     for o, s in zip(off, cells.shape))]
        for off in itertools.product((-1, 0, 1), repeat=nd))
    work = _per_device_sums(geom, cells * nbhd)
    total = work.sum()
    if total <= 0:
        return np.full(geom.mesh_shape,
                       float(wall_s) / geom.n_devices)
    return float(wall_s) * work / total


# ---------------------------------------------------------------------------
# 2. Planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    """Outcome of one planning pass over the occupancy histogram."""

    mesh_shape: Tuple[int, ...]        # realizable equal-split target
    imbalance: float                   # planned imbalance of mesh_shape
    current: float                     # imbalance of the live partition
    rcb_bound: Optional[float]         # box-granular RCB imbalance (lower bound)
    diffusive_bound: Optional[float]   # 1-D diffusive-step imbalance, if 1-D
    partition: Optional[Partition] = None   # uneven plan, cuts in CELLS
    partition_imbalance: Optional[float] = None


def plan_reshard(
    hist: np.ndarray,
    geom: Domain,
    n_devices: Optional[int] = None,
    runtimes: Optional[np.ndarray] = None,
) -> ReshardPlan:
    """Run all applicable planners over a box histogram.

    ``choose_partition(..., "equal")`` gives the realizable equal-split
    plan; ``choose_partition(..., "rcb")`` cuts a box-granular rectilinear
    partition (the uneven-ownership plan the engine can now realize with
    padded grids + masked halo); ``plan_rcb`` (power-of-two counts) gives
    the hierarchical-bisection bound both are measured against; for chain
    meshes (all but one axis of size 1) one ``plan_diffusive`` step over
    the chain-axis marginal is evaluated too (using measured runtimes when
    given, else the column loads as the runtime proxy).
    """
    mesh = geom.mesh_shape
    n = n_devices if n_devices is not None else geom.n_devices
    if geom.uneven:
        cur = imbalance(realized_loads(geom, hist))
    else:
        divisible = all(b % m == 0 for b, m in zip(hist.shape, mesh))
        cur = imbalance(equal_split_loads(hist, mesh)) if divisible \
            else float("inf")

    # Either planner alone may have no valid plan (no factorization
    # divides the box grid for "equal"; more devices than boxes on every
    # factorization for "rcb") — each failure is recorded as inf, and only
    # when BOTH fail is there nothing realizable to report.
    eq_err = None
    target = None
    planned = float("inf")
    try:
        eq_plan = choose_partition(hist, n, ownership="equal")
        target = eq_plan.mesh_shape
        planned = eq_plan.imbalance
    except ValueError as e:
        eq_err = e

    part_cells = None
    part_imb = None
    try:
        uneven_plan = choose_partition(hist, n, ownership="rcb")
        part_cells = uneven_plan.partition.scale(geom.box_factor)
        part_imb = uneven_plan.imbalance
    except ValueError:
        pass
    if eq_err is not None:
        if part_cells is None:
            raise eq_err
        if target is None:
            target = part_cells.mesh_shape   # best realizable mesh overall

    rcb_bound = None
    if n & (n - 1) == 0:
        own = plan_rcb(hist, n)
        rcb_bound = imbalance(device_loads(own, hist, n))

    diff_bound = None
    is_chain = n > 1 and sum(m > 1 for m in mesh) == 1
    if (is_chain and n == geom.n_devices and not geom.uneven
            and cur != float("inf")):
        chain = int(np.argmax(mesh))
        d = mesh[chain]
        col_w = hist.sum(axis=tuple(a for a in range(hist.ndim)
                                    if a != chain))
        if col_w.size % d == 0:
            widths = np.full((d,), col_w.size // d, np.int64)
            loads0 = equal_split_loads(hist, mesh)
            rt = (np.asarray(runtimes, np.float64).ravel()
                  if runtimes is not None else loads0)
            new_w = plan_diffusive(widths, col_w, rt)
            own_1d = widths_to_ownership(new_w)
            loads = device_loads(own_1d[:, None], col_w[:, None], d)
            diff_bound = imbalance(loads)

    return ReshardPlan(mesh_shape=target, imbalance=planned, current=cur,
                       rcb_bound=rcb_bound, diffusive_bound=diff_bound,
                       partition=part_cells, partition_imbalance=part_imb)


# ---------------------------------------------------------------------------
# 3. Mass migration: flatten -> re-derive geometry -> re-init
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FlatAgents:
    """Host-side flattened simulation state — the unit of mass migration
    (and of the logical ABM checkpoint, distributed.checkpoint.save_abm)."""

    positions: np.ndarray              # (N, ndim) float32
    attrs: Dict[str, np.ndarray]       # (N, ...) incl. gid_rank/gid_count
    it: int                            # iteration counter
    gid_counters: np.ndarray           # (old_n_ranks,) next spawn counter
    base_key: np.ndarray               # (2,) uint32 RNG lineage root
    dropped_total: int                 # cumulative overflow drops


def flatten_state(geom: Domain, state: SimState) -> FlatAgents:
    """Gather every live agent (owned interior cells only — the aura ring
    and, under uneven ownership, the padding cells hold copies/nothing)
    plus the engine carry needed to re-initialize elsewhere."""
    nd = geom.ndim
    valid = _owned_valid_blocks(geom, state.soa.valid).ravel()
    attrs = {}
    for name, a in state.soa.attrs.items():
        blocks = _interior_blocks(geom, a)
        trailing = blocks.shape[2 * nd + 1:]
        attrs[name] = blocks.reshape((valid.size,) + trailing)[valid]
    positions = attrs.pop(POS)
    return FlatAgents(
        positions=positions,
        attrs=attrs,
        it=int(np.max(np.asarray(state.it))),
        gid_counters=np.asarray(state.gid_counter, np.int64).ravel(),
        base_key=np.asarray(state.key)[(0,) * nd].astype(np.uint32),
        dropped_total=int(np.sum(np.asarray(state.dropped))),
    )


def reshard_state(
    engine: Engine, state: SimState,
    mesh_shape: Optional[Tuple[int, ...]] = None,
    partition: Optional[Partition] = None,
    transport: str = "auto",
) -> Tuple[Engine, SimState]:
    """Mass-migrate ``state`` onto a new device mesh — an equal split over
    ``mesh_shape``, or the uneven box-granular ``partition`` (cuts in
    cells; the per-device grids pad to the partition's max slab widths).

    Preserved across the re-shard: global agent ids, per-rank spawn-counter
    floors (so future spawns never collide with any id ever issued), the
    iteration counter, the RNG lineage (new per-device keys are split from
    the old root key folded with the iteration), and the cumulative drop
    count.  Delta references are re-zeroed — callers must run the next step
    with ``full_halo=True``.

    ``transport`` picks the migration path: ``"host"`` is the legacy
    flatten-to-host round trip; ``"device"`` is the collective
    device-to-device re-bin (:func:`reshard_state_device` — zero agent
    bytes through host, requires an unchanged device count); ``"auto"``
    (default) takes the device path whenever it is realizable and falls
    back to host otherwise (elastic restores onto a different device
    count, single-device geometries).
    """
    if (mesh_shape is None) == (partition is None):
        raise ValueError(
            "reshard_state takes exactly one of mesh_shape (equal split) "
            "or partition (uneven ownership)")
    if transport not in ("auto", "host", "device"):
        raise ValueError(
            f"unknown transport {transport!r}; expected 'auto', 'host', "
            "or 'device'")
    n_new = math.prod(mesh_shape if mesh_shape is not None
                      else partition.mesh_shape)
    if transport == "device" or (
            transport == "auto" and n_new == engine.geom.n_devices
            and n_new > 1 and jax.device_count() >= n_new):
        # realizability is decided here, not by catching the device path's
        # errors: a genuine failure there (cell-capacity overflow) must
        # propagate, not silently retry through the host round trip
        return reshard_state_device(
            engine, state, mesh_shape=mesh_shape, partition=partition)
    flat = flatten_state(engine.geom, state)
    if partition is not None:
        new_geom = engine.geom.repartition(partition)
    else:
        new_geom = engine.geom.with_mesh_shape(mesh_shape)
    new_engine = dataclasses.replace(engine, geom=new_geom)
    new_state = new_engine.init_state(
        flat.positions,
        flat.attrs,
        gid_counters=flat.gid_counters,
        it0=flat.it,
        base_key=flat.base_key,
    )
    if flat.dropped_total:
        new_state.dropped = new_state.dropped.at[
            (0,) * new_geom.ndim].add(jnp.int32(flat.dropped_total))
    return new_engine, new_state


# ---------------------------------------------------------------------------
# 3b. Device-to-device mass migration (no host round trip)
# ---------------------------------------------------------------------------

def _interleave_flat(geom: Domain, a):
    """Global sharded array -> flat per-slot view in the canonical
    interleaved order (c0, i0, c1, i1, ..., slot) — the traced twin of
    :func:`_interior_blocks` + ravel, so the device path enumerates agents
    in exactly the order the host path does (slot assignment downstream is
    order-dependent through the stable sort)."""
    nd = geom.ndim
    shape: Tuple[int, ...] = ()
    for m, h in zip(geom.mesh_shape, geom.local_shape):
        shape += (m, h)
    a = a.reshape(shape + a.shape[nd:])
    sl: Tuple = ()
    for _ in range(nd):
        sl += (slice(None), slice(1, -1))
    a = a[sl]
    return a.reshape((-1,) + a.shape[2 * nd + 1:])


def _owned_flat_mask(geom: Domain) -> Optional[np.ndarray]:
    """Static per-slot validity mask over the interleaved flat order for
    uneven old geometries (padding + per-device aura ring excluded), or
    None on the equal split (the interior slice already excludes the
    ring)."""
    if not geom.uneven:
        return None
    shape: Tuple[int, ...] = ()
    for m, i in zip(geom.mesh_shape, geom.interior):
        shape += (m, i)
    mask = np.ones(shape, bool)
    widths = geom.partition.widths
    for a in range(geom.ndim):
        for ci, w in enumerate(widths[a]):
            sl = [slice(None)] * mask.ndim
            sl[2 * a] = ci
            sl[2 * a + 1] = slice(w, None)
            mask[tuple(sl)] = False
    return np.repeat(mask.ravel(), geom.cap)


@memoize("reshard.device_migration", maxsize=32)
def _cached_device_migration(engine: Engine, new_geom: Domain):
    """Compiled device-to-device migration: old-mesh sharded state in,
    new-mesh sharded state out, agents never touching host.

    The body is the global generalization of ``grid.bin_agents``: flatten
    every owned slot in the canonical interleaved order, route each agent
    to its new device (equal-split floor-divide or searchsorted partition
    cuts — the same arithmetic ``Engine.init_state`` runs on host), then
    one stable argsort over the combined (device, local cell) key assigns
    slots *identically* to the host path's per-device binning (the stable
    global sort preserves original order within every (device, cell) run,
    exactly like host-side selection followed by a per-device stable
    sort).  ``out_shardings`` pins every output to the new mesh, so XLA
    lowers the layout change to collective permutes of the per-device
    shards.
    """
    from repro.launch.mesh import make_abm_mesh  # deferred: device state
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.domain import spatial_axis_names

    old = engine.geom
    nd = old.ndim
    cap = old.cap
    cs = float(new_geom.cell_size)
    mesh_to = new_geom.mesh_shape
    lshape = new_geom.local_shape
    n_ranks = new_geom.n_devices
    part = new_geom.partition
    # Static routing tables, computed with the same float64->float32
    # rounding the host path uses.
    if part is None:
        lens = [i * cs for i in new_geom.interior]
        origins = [
            (np.arange(m, dtype=np.float64) * lens[a]).astype(np.float32)
            for a, m in enumerate(mesh_to)]
        cuts = owned_w = None
    else:
        cuts = [np.asarray(part.cuts[a]) for a in range(nd)]
        origins = [
            (np.asarray(part.cuts[a][:-1], np.float64) * cs
             ).astype(np.float32) for a in range(nd)]
        owned_w = [np.asarray(part.widths[a], np.int32) for a in range(nd)]
    old_mask = _owned_flat_mask(old)
    n_local = math.prod(lshape)
    total = n_local * n_ranks * cap
    # Pin every output to the new mesh: the jit boundary then owes XLA a
    # layout change from old-mesh to new-mesh shards, which GSPMD lowers
    # to collective permutes — the "mass migration" without a host hop.
    dev_mesh = make_abm_mesh(mesh_to)
    out_sh = NamedSharding(dev_mesh, P(*spatial_axis_names(nd)))
    rep_sh = NamedSharding(dev_mesh, P())

    def migrate(state: SimState):
        fvalid = _interleave_flat(old, state.soa.valid)
        if old_mask is not None:
            fvalid = fvalid & jnp.asarray(old_mask)
        flats = {n: _interleave_flat(old, a)
                 for n, a in state.soa.attrs.items()}
        pos = flats[POS]
        n = fvalid.shape[0]

        # 1. Route to the owning device of the new partition.
        dev = []
        for a in range(nd):
            if cuts is None:
                d = jnp.floor_divide(
                    pos[:, a], jnp.float32(lens[a])).astype(jnp.int32)
            else:
                cell = jnp.clip(
                    jnp.floor_divide(
                        pos[:, a], jnp.float32(cs)).astype(jnp.int32),
                    0, new_geom.global_cells[a] - 1)
                d = (jnp.searchsorted(
                    jnp.asarray(cuts[a]), cell, side="right") - 1
                ).astype(jnp.int32)
            dev.append(jnp.clip(d, 0, mesh_to[a] - 1))

        # 2. Local cell on that device (cell_of semantics incl. the halo
        # offset and the uneven-ownership clamp).
        origin = jnp.stack(
            [jnp.asarray(origins[a])[dev[a]] for a in range(nd)], axis=1)
        rel = (pos - origin) / jnp.float32(cs)
        c = jnp.floor(rel).astype(jnp.int32) + 1
        cell = []
        for a in range(nd):
            if owned_w is None:
                cell.append(jnp.clip(c[:, a], 0, lshape[a] - 1))
            else:
                cell.append(jnp.clip(
                    c[:, a], 0, jnp.asarray(owned_w[a])[dev[a]] + 1))

        # 3. One global stable sort over (device, local cell).
        devlin = dev[0]
        for a in range(1, nd):
            devlin = devlin * mesh_to[a] + dev[a]
        clocal = cell[0]
        for a in range(1, nd):
            clocal = clocal * lshape[a] + cell[a]
        sentinel = n_ranks * n_local
        skey = jnp.where(fvalid, devlin * n_local + clocal, sentinel)
        order = jnp.argsort(skey, stable=True)
        sorted_key = skey[order]
        idx = jnp.arange(n, dtype=jnp.int32)
        is_start = jnp.concatenate(
            [jnp.ones((1,), jnp.bool_),
             sorted_key[1:] != sorted_key[:-1]])
        # lax.cummax, NOT lax.associative_scan(jnp.maximum, ...): the
        # generic scan's slice/concat decomposition miscompiles under
        # GSPMD auto-partitioning (this function runs partitioned over the
        # old mesh — unlike grid.bin_agents, whose identical idiom sits
        # inside shard_map and never meets the partitioner).  cummax
        # lowers to a dedicated op the partitioner handles correctly.
        start_idx = jax.lax.cummax(
            jnp.where(is_start, idx, jnp.int32(-1)))
        rank = idx - start_idx
        ok = (sorted_key < sentinel) & (rank < cap)
        n_dropped = jnp.sum((sorted_key < sentinel) & (rank >= cap))

        # 4. Scatter into the new global cell-slot grid.  Flat target
        # index folds (dev, cell) straight into the global axes
        # (global index along axis a = dev_a * h'_a + cell_a).
        gidx = dev[0] * lshape[0] + cell[0]
        for a in range(1, nd):
            gidx = (gidx * (mesh_to[a] * lshape[a])
                    + dev[a] * lshape[a] + cell[a])
        slot = jnp.where(ok, gidx[order] * cap + rank, total)
        gshape = tuple(m * h for m, h in zip(mesh_to, lshape))
        new_attrs = {}
        for name, a in flats.items():
            src = a[order]
            tgt = jnp.zeros((total + 1,) + a.shape[1:], a.dtype)
            new_attrs[name] = tgt.at[slot].set(src)[:total].reshape(
                gshape + (cap,) + a.shape[1:])
        v = jnp.zeros((total + 1,), jnp.bool_).at[slot].set(ok)
        new_soa = AgentSoA(attrs=new_attrs,
                           valid=v[:total].reshape(gshape + (cap,)))

        # 5. Engine carry: spawn-counter floors (per-rank max carried id +
        # the global floor max), iteration counter, RNG lineage, drops.
        from repro.core.agent_soa import GID_COUNT, GID_RANK
        g_rank = flats[GID_RANK]
        g_count = flats[GID_COUNT]
        in_range = fvalid & (g_rank >= 0) & (g_rank < n_ranks)
        counters = jnp.zeros((n_ranks,), jnp.int32).at[
            jnp.where(in_range, g_rank, 0)
        ].max(jnp.where(in_range, g_count + 1, 0))
        floor = jnp.max(state.gid_counter).astype(jnp.int32)
        counters = jnp.maximum(counters, floor).reshape(mesh_to)

        it0 = jnp.max(state.it)
        base_key = state.key[(0,) * nd].astype(jnp.uint32)
        root = jax.random.fold_in(base_key, it0)
        keys = jax.random.split(root, n_ranks).reshape(mesh_to + (-1,))
        dropped = jnp.zeros(mesh_to, jnp.int32).at[(0,) * nd].add(
            jnp.sum(state.dropped).astype(jnp.int32))
        nguards = state.health.shape[-1]
        out = (new_soa, counters, jnp.full(mesh_to, it0, jnp.int32),
               keys, dropped,
               jnp.zeros(mesh_to + (nguards,), jnp.int32))
        out = jax.tree_util.tree_map(
            lambda a: jax.lax.with_sharding_constraint(a, out_sh), out)
        return out + (jax.lax.with_sharding_constraint(
            n_dropped, rep_sh),)

    return jax.jit(migrate)


def reshard_state_device(
    engine: Engine, state: SimState,
    mesh_shape: Optional[Tuple[int, ...]] = None,
    partition: Optional[Partition] = None,
) -> Tuple[Engine, SimState]:
    """Device-to-device mass migration: the collective-permute fast path
    of :func:`reshard_state`.

    Agents move directly between device shards inside one compiled
    dispatch — ``flatten_state`` is never called and no agent bytes cross
    the host boundary (the only host-visible scalar is the overflow-drop
    diagnostic, which mirrors ``init_state``'s capacity check).  Requires
    the device count to stay unchanged (elastic restores go through the
    host path) and a multi-device geometry.  Bit-exact with the host
    path: same routing arithmetic, same stable-sort slot assignment, same
    carry (spawn floors, iteration, RNG lineage, cumulative drops).
    """
    if (mesh_shape is None) == (partition is None):
        raise ValueError(
            "reshard_state_device takes exactly one of mesh_shape or "
            "partition")
    if partition is not None:
        new_geom = engine.geom.repartition(partition)
    else:
        new_geom = engine.geom.with_mesh_shape(mesh_shape)
    if new_geom.n_devices != engine.geom.n_devices:
        raise ValueError(
            f"device path needs an unchanged device count "
            f"({engine.geom.n_devices} -> {new_geom.n_devices}); use the "
            "host path")
    if new_geom.n_devices == 1:
        raise ValueError("single-device re-shard has no wire to avoid; "
                         "use the host path")
    if jax.device_count() < new_geom.n_devices:
        raise ValueError(
            f"device path needs {new_geom.n_devices} devices, have "
            f"{jax.device_count()}; use the host path")
    migrate = _cached_device_migration(engine, new_geom)
    (new_soa, counters, it, keys, dropped, health,
     n_dropped) = migrate(state)
    if int(n_dropped) != 0:
        raise ValueError(
            f"cell capacity overflow during device re-shard: "
            f"{int(n_dropped)} agents dropped; raise geom.cap")
    new_engine = dataclasses.replace(engine, geom=new_geom)
    mesh_to = new_geom.mesh_shape
    # Fresh zero aura references on the new geometry (the next step must
    # run with full_halo=True, exactly like the host path).
    from repro.core.engine import _bcast
    from repro.core.halo import init_refs
    nd = new_geom.ndim
    sample = AgentSoA(
        attrs={n: jnp.zeros(new_geom.local_shape + (new_geom.cap,)
                            + a.shape[nd + 1:], a.dtype)
               for n, a in new_soa.attrs.items()},
        valid=jnp.zeros(new_geom.local_shape + (new_geom.cap,), jnp.bool_))
    refs0 = init_refs(new_geom, sample)
    refs = {d: {f: _bcast(v, mesh_to) for f, v in slab.items()}
            for d, slab in refs0.items()}
    new_state = SimState(
        soa=new_soa, refs=refs, it=it, key=keys,
        gid_counter=counters, dropped=dropped,
        halo_bytes=jnp.zeros(mesh_to, jnp.int32),
        codec_overflow=jnp.zeros(mesh_to, jnp.int32),
        health=health)
    return new_engine, new_state


# ---------------------------------------------------------------------------
# 4. The runtime: cadence + threshold + trigger
# ---------------------------------------------------------------------------

def default_make_step(engine: Engine):
    """Step factory used after a re-shard: local step on a single-device
    mesh, else a sharded step over a fresh version-compat spatial mesh."""
    if engine.geom.n_devices == 1:
        return engine.make_local_step()
    from repro.launch.mesh import make_abm_mesh  # deferred: device state
    return engine.make_sharded_step(make_abm_mesh(engine.geom.mesh_shape))


@dataclasses.dataclass
class Rebalancer:
    """Dynamic load balancing policy, evaluated inside the run loop.

    Every ``every`` iterations the occupancy histogram is extracted; when
    the live partition's ``imbalance()`` exceeds ``threshold`` and the best
    realizable plan improves it by at least ``min_gain``x, the state is
    re-sharded in place.  ``ownership`` selects what the planner may
    realize: ``"equal"`` (historical equal-split meshes only) or ``"rcb"``
    (box-granular rectilinear partitions on padded per-device grids with
    masked halo exchange — the live analogue of the RCB bound).
    ``transport`` picks the migration path for applied re-shards
    (``reshard_state``'s knob: ``"auto"`` takes the device-to-device
    collective whenever realizable).  ``defer=True`` splits each check in
    two: at the due tick the validity snapshot starts an *async*
    device-to-host copy and the call returns immediately, so the old mesh
    keeps stepping while the copy lands and the plan builds; the
    histogram/threshold/plan/apply work runs on the next step against that
    one-step-stale snapshot (plan quality is unaffected — agents move at
    most one cell per step — and the migration itself always uses the
    live state).
    ``history`` records every decision (both applied and declined) with
    the planner diagnostics; ``engine`` always points at the engine
    matching the latest state.
    """

    every: int = 10
    threshold: float = 0.5
    min_gain: float = 1.5
    ownership: str = "equal"
    transport: str = "auto"
    defer: bool = False
    make_step: Callable[[Engine], Callable] = default_make_step
    runtimes: Optional[np.ndarray] = None   # optional measured per-device times
    engine: Optional[Engine] = None
    history: List[dict] = dataclasses.field(default_factory=list)
    _pending: Optional[dict] = dataclasses.field(
        default=None, init=False, repr=False)

    def __post_init__(self):
        if self.ownership not in ("equal", "rcb"):
            raise ValueError(
                f"unknown ownership {self.ownership!r}; expected 'equal' "
                "or 'rcb'")
        if self.transport not in ("auto", "host", "device"):
            raise ValueError(
                f"unknown transport {self.transport!r}; expected 'auto', "
                "'host', or 'device'")

    def due(self, i: int) -> bool:
        if self._pending is not None:
            return True   # deferred plan lands on the very next check
        return self.every > 0 and i % self.every == 0

    def maybe_reshard(
        self, engine: Engine, state: SimState
    ) -> Tuple[Engine, SimState, bool]:
        self.engine = engine
        if (self.runtimes is not None
                and np.asarray(self.runtimes).shape != engine.geom.mesh_shape):
            self.runtimes = None  # measured on a different mesh: stale
        snapshot = None
        if self.defer:
            if self._pending is None:
                # Phase 1: kick off the device-to-host copy and return
                # without blocking on any device value.  The drive loop
                # dispatches the next step on the old mesh immediately;
                # the copy overlaps it.
                valid = state.soa.valid
                if hasattr(valid, "copy_to_host_async"):
                    valid.copy_to_host_async()
                self._pending = {"valid": valid, "geom": engine.geom,
                                 "runtimes": self.runtimes}
                return engine, state, False
            pend, self._pending = self._pending, None
            if pend["geom"] == engine.geom:
                snapshot = pend   # else geometry changed underneath: replan
        if snapshot is not None:
            hist = _histogram_from_valid(
                engine.geom, np.asarray(snapshot["valid"]),
                snapshot["runtimes"])
        else:
            hist = occupancy_histogram(engine.geom, state, self.runtimes)
        mesh = engine.geom.mesh_shape
        # a box grid coarser than the mesh (large box_factor) has no
        # per-device load reading: treat as maximally imbalanced and let the
        # planner look for a factorization the box grid does support
        if engine.geom.uneven:
            cur = imbalance(realized_loads(engine.geom, hist))
        else:
            cur = (imbalance(equal_split_loads(hist, mesh))
                   if all(b % m == 0 for b, m in zip(hist.shape, mesh))
                   else float("inf"))
        record = {
            "it": int(np.max(np.asarray(state.it))),
            "mesh_from": engine.geom.mesh_shape,
            "ownership": self.ownership,
            "imbalance_before": cur,
            "applied": False,
        }
        if snapshot is not None:
            record["deferred"] = True
        if cur <= self.threshold:
            self.history.append(record)
            return engine, state, False

        try:
            plan = plan_reshard(hist, engine.geom, runtimes=self.runtimes)
        except ValueError as e:
            # e.g. no factorization of the device count divides the box grid
            record["declined"] = str(e)
            self.history.append(record)
            return engine, state, False
        record.update(
            mesh_to=plan.mesh_shape,
            imbalance_planned=plan.imbalance,
            rcb_bound=plan.rcb_bound,
            diffusive_bound=plan.diffusive_bound,
            partition_imbalance=plan.partition_imbalance,
        )
        uneven = (self.ownership == "rcb" and plan.partition is not None)
        if uneven:
            # realize the box-granular cut plan on padded grids
            target_imb = plan.partition_imbalance
            new_geom = engine.geom.repartition(plan.partition)
            record.update(
                mesh_to=plan.partition.mesh_shape,
                partition_widths=plan.partition.widths,
                pad_fraction=plan.partition.pad_fraction(),
            )
            no_improvement = (new_geom == engine.geom
                              or cur < target_imb * self.min_gain)
        else:
            no_improvement = (
                plan.mesh_shape == engine.geom.mesh_shape
                and not engine.geom.uneven
            ) or cur < plan.imbalance * self.min_gain
        if no_improvement:
            self.history.append(record)
            return engine, state, False

        t0 = time.perf_counter()
        if uneven:
            new_engine, new_state = reshard_state(
                engine, state, partition=plan.partition,
                transport=self.transport)
        else:
            new_engine, new_state = reshard_state(
                engine, state, plan.mesh_shape, transport=self.transport)
        # rebalance plans never change the device count, so auto resolves
        # to the device-to-device collective on any multi-device mesh
        used = ("host" if self.transport == "host"
                or engine.geom.n_devices == 1 else "device")
        record.update(
            applied=True,
            transport=used,
            migration_s=time.perf_counter() - t0,
            imbalance_after=current_imbalance(new_engine.geom, new_state),
        )
        self.history.append(record)
        self.engine = new_engine
        # per-device times were measured on the old mesh; devices now own
        # different regions, so the next check starts from pure counts
        self.runtimes = None
        return new_engine, new_state, True
