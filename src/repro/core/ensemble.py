"""Vmapped ensemble runner: R simulation configs in ONE dispatch.

TeraAgent's pitch is time-to-result (paper §1, §4) — and for parameter
sweeps, calibration, and multi-tenant serving, time-to-result is dominated
not by one simulation's step rate but by how many *configurations* finish
per second.  Running R configs as R sequential processes pays the full
compile + per-step dispatch floor R times over, and leaves the device idle
whenever one small run cannot fill it.

This module batches instead: an :class:`Ensemble` vmaps the engine's
scan-fused segment body (:meth:`Engine._segment_body`) over a leading
*replica* axis, so R replicas of :class:`SimState` — stacked leaf-wise
into one pytree — advance together in a single compiled dispatch.
Per-replica *parameters* (interaction strengths, infection rates, radius
gates, …) ride along as traced ``(R,)`` arrays threaded through a
``behavior_fn(params) -> Behavior`` factory, so one executable covers
every parameter point of a *family*:

    family = (Domain, behavior_fn, param_names, dt, delta codec,
              sweep backend, guard config)

Everything *structural* must be shared across the family (shapes, mesh,
static radii, guard policy — these bake into the trace); everything
*numeric* can vary per replica.  Replicas never interact: vmap lanes are
independent by construction, so per-replica guard words
(:func:`ensemble_health_counts`) and per-replica scheduled-op reductions
(``operations.batch_*``) read each lane untouched by its neighbors, and a
padding lane (``active=False``) cannot perturb real ones — the property
the bit-exactness tests pin.

Sharded meshes compose the other way around: the vmap sits *inside*
``shard_map``, so each device steps its spatial block of all R replicas
and the halo ``ppermute``s batch over the replica axis.  One device mesh,
R simulations.

Compiled runners are cached in a bounded, instrumented
:class:`~repro.core.compile_cache.CompiledCache` keyed by the family
fingerprint — the scenario server (``launch/serve.py``) reuses a family's
executable across requests and reports the hit rate.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile_cache import CompiledCache
from repro.core.delta import DeltaConfig
from repro.core.domain import Domain, spatial_axis_names
from repro.core.engine import Engine, SimState, _mesh_for
from repro.core.guards import GUARD_CONSERVATION, GuardConfig, NUM_GUARDS
from repro.core.halo import LocalComm, ShardComm, shard_map_compat

Array = Any

# One process-wide cache of compiled ensemble runners, keyed by family
# fingerprint (+ mesh).  Small maxsize: each entry may hold several
# jit-compiled executables, and a server hosts few families at once.
_RUNNER_CACHE = CompiledCache("ensemble.runner", maxsize=16)


# ---------------------------------------------------------------------------
# Ensemble state: R stacked replicas + per-replica params + active mask
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EnsembleState:
    """R replicas of one simulation family, stacked for one dispatch.

    ``state`` is a :class:`SimState` whose every leaf carries a leading
    ``(R, ...)`` replica axis; ``params`` maps each family parameter name
    to an ``(R,)`` array (replica r's scalar at index r); ``active`` is a
    host-side ``(R,)`` bool mask — padding lanes (``False``) are stepped
    like any other (vmap has no ragged lanes) but their outputs are
    ignored by every reader.  The mask is deliberately *not* traced:
    masking inside the kernel would retrace per occupancy pattern and buy
    nothing, since inactive lanes cost the same either way.
    """

    state: SimState
    params: Dict[str, Array]
    active: np.ndarray

    @property
    def replicas(self) -> int:
        return int(self.active.shape[0])

    @property
    def n_active(self) -> int:
        return int(self.active.sum())


def stack_states(states: Sequence[SimState]) -> SimState:
    """Stack R solo states leaf-wise into one (R, ...)-leading pytree."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def replica_state(state: SimState, r: int) -> SimState:
    """Slice replica ``r`` back out of a stacked state (solo layout)."""
    return jax.tree_util.tree_map(lambda x: x[r], state)


def ensemble_health_counts(estate: EnsembleState) -> np.ndarray:
    """Per-replica guard words: (R, NUM_GUARDS), each lane reduced over
    the device mesh exactly like the solo :func:`~repro.core.guards.
    health_counts` (sum per device; conservation is a replicated global,
    so max).  Lanes stay independent — one replica's NaN burst must not
    poison its batch neighbors' health reading."""
    h = np.asarray(estate.state.health)
    rr = h.shape[0]
    h = h.reshape(rr, -1, NUM_GUARDS)
    out = h.sum(axis=1, dtype=np.int64)
    out[:, GUARD_CONSERVATION] = h[:, :, GUARD_CONSERVATION].max(
        axis=1, initial=0)
    return out


# ---------------------------------------------------------------------------
# The ensemble runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ensemble:
    """Batched runner for one compatibility family of simulations.

    ``behavior_fn(params)`` builds the family's :class:`Behavior` from a
    dict of scalars — called once per trace with *traced* ``(R,)->()``
    values, so the behavior's pair/update kernels see parameters as
    abstract tracers (anything structural — static radii for
    ``compose()``'s gating, accumulator specs, schemas — must not depend
    on them).  Two ensembles are the same family iff their fingerprints
    match: same Domain, same ``behavior_fn`` *object*, same parameter
    names, codec, sweep backend, and guards.
    """

    geom: Domain
    behavior_fn: Callable[[Dict[str, Array]], Any]
    param_names: Tuple[str, ...]
    dt: float = 1.0
    delta_cfg: DeltaConfig = DeltaConfig(enabled=False)
    sweep_backend: str = "auto"
    guards: GuardConfig = GuardConfig()
    family: str = ""              # display label (serve telemetry)

    def __post_init__(self):
        object.__setattr__(self, "param_names",
                           tuple(sorted(self.param_names)))

    # -- identity ------------------------------------------------------

    @property
    def fingerprint(self) -> Tuple:
        """Hashable family identity — the compiled-runner cache key and
        the batching key of the scenario server."""
        return (self.geom, self.behavior_fn, self.param_names, self.dt,
                self.delta_cfg, self.sweep_backend, self.guards)

    # -- construction helpers -----------------------------------------

    def proto_engine(self) -> Engine:
        """Concrete solo :class:`Engine` of this family (parameters at
        0.0) — for ``init_state``, contract checks, and as the structural
        base the traced behavior is swapped into."""
        zeros = {n: jnp.float32(0.0) for n in self.param_names}
        return Engine(geom=self.geom, behavior=self.behavior_fn(zeros),
                      delta_cfg=self.delta_cfg, dt=self.dt,
                      sweep_backend=self.sweep_backend, guards=self.guards)

    def solo_engine(self, params: Dict[str, float]) -> Engine:
        """Solo engine at one concrete parameter point.  Parameters are
        cast to f32 scalars exactly as the batched trace sees them, so a
        solo run is *bit-exact* against the corresponding ensemble lane
        (the property the tier-1 ensemble tests pin)."""
        conc = {n: jnp.float32(params[n]) for n in self.param_names}
        return Engine(geom=self.geom, behavior=self.behavior_fn(conc),
                      delta_cfg=self.delta_cfg, dt=self.dt,
                      sweep_backend=self.sweep_backend, guards=self.guards)

    def pack_params(self, points: Sequence[Dict[str, float]]
                    ) -> Dict[str, Array]:
        """(R,) parameter arrays from R parameter dicts (f32; missing
        names raise — a family's replicas all sweep the same knobs)."""
        for p in points:
            missing = set(self.param_names) - set(p)
            if missing:
                raise ValueError(
                    f"replica missing family params {sorted(missing)}")
        return {n: jnp.asarray([float(p[n]) for p in points],
                               dtype=jnp.float32)
                for n in self.param_names}

    def init(self, states: Sequence[SimState],
             points: Sequence[Dict[str, float]]) -> EnsembleState:
        """Stack R solo states (from ``proto_engine().init_state`` — the
        behavior only shapes the schema, not the initial state) with
        their R parameter points into one :class:`EnsembleState`."""
        if len(states) != len(points):
            raise ValueError(f"{len(states)} states vs {len(points)} "
                             "parameter points")
        if not states:
            raise ValueError("ensemble needs at least one replica")
        return EnsembleState(state=stack_states(states),
                             params=self.pack_params(points),
                             active=np.ones(len(states), dtype=bool))

    def pad_to(self, estate: EnsembleState, slots: int) -> EnsembleState:
        """Pad a partial batch to ``slots`` lanes by tiling replica 0
        with ``active=False`` — inert no-op lanes that keep the compiled
        runner's shape fixed across batch occupancies (one executable per
        family, not one per fill level)."""
        r = estate.replicas
        if slots < r:
            raise ValueError(f"cannot pad {r} replicas down to {slots}")
        if slots == r:
            return estate
        idx = jnp.asarray(np.r_[np.arange(r), np.zeros(slots - r, int)])
        take = lambda x: jnp.take(x, idx, axis=0)
        return EnsembleState(
            state=jax.tree_util.tree_map(take, estate.state),
            params={k: take(v) for k, v in estate.params.items()},
            active=np.r_[estate.active, np.zeros(slots - r, dtype=bool)])

    # -- the one-dispatch runner --------------------------------------

    def _replica_seg(self, comm, full_first: bool):
        """Single-lane segment body with *traced* params: rebuild the
        behavior from this lane's parameter scalars, graft it onto the
        structural base engine, and run its scan-fused segment.  vmap of
        this over lanes is the whole ensemble trick."""
        base = self.proto_engine()

        def seg(state: SimState, params: Dict[str, Array],
                n_steps: Array) -> SimState:
            eng = dataclasses.replace(base,
                                      behavior=self.behavior_fn(params))
            return eng._segment_body(comm, full_first)(state, n_steps)

        return seg

    def _build_runner(self, mesh):
        geom = self.geom
        if mesh is None:
            comm = LocalComm(toroidal=geom.toroidal)

            def wrap(full_first):
                seg = self._replica_seg(comm, full_first)
                return jax.jit(jax.vmap(seg, in_axes=(0, 0, None)))
        else:
            from jax.sharding import PartitionSpec as P

            axis_names = spatial_axis_names(geom.ndim)
            comm = ShardComm(axis_names=axis_names,
                             mesh_shape=geom.mesh_shape,
                             toroidal=geom.toroidal)
            # vmap INSIDE shard_map: each device holds its spatial block
            # of every replica (replica axis unsharded, spec prefix
            # ``P(None, sx, sy, ...)``), halo ppermutes batch over lanes.
            state_spec = P(None, *axis_names)
            param_spec = P(None)

            def wrap(full_first):
                seg = self._replica_seg(comm, full_first)

                def body(states, params, n):
                    return jax.vmap(
                        lambda s, p: seg(s, p, n), in_axes=(0, 0)
                    )(states, params)

                return jax.jit(shard_map_compat(
                    body, mesh=mesh,
                    in_specs=(state_spec, param_spec, P()),
                    out_specs=state_spec))

        seg_t = wrap(True)
        seg_f = wrap(False)

        def run(state, params, n_steps, full_first=True):
            n = jnp.int32(n_steps)
            return seg_t(state, params, n) if full_first \
                else seg_f(state, params, n)

        return run

    def make_runner(self, mesh=None):
        """Cached compiled ensemble runner
        ``run(stacked_state, params, n_steps, full_first) -> stacked_state``
        — one dispatch for all R lanes.  Cache key is the family
        fingerprint (+ mesh), so every request of a family after the
        first is a cache hit (``compile_cache.cache_stats('ensemble')``)."""
        key = (self.fingerprint, mesh)
        return _RUNNER_CACHE.get_or_build(
            key, lambda: self._build_runner(mesh))

    def run(self, estate: EnsembleState, n_steps: int, *,
            mesh: Optional[Any] = None, full_first: bool = True,
            collect: Optional[Callable[[EnsembleState], Any]] = None,
            ) -> Tuple[EnsembleState, list]:
        """Advance every lane ``n_steps`` iterations.

        Without delta encoding this is literally ONE compiled dispatch.
        With delta encoding the host loops over refresh boundaries —
        segments of ``refresh_interval`` steps, each opening with a full
        aura refresh — mirroring ``Engine.drive``'s scan-fused schedule.
        ``collect(estate)`` (if given) runs at every segment boundary and
        its non-None results are returned as the frame list — the hook
        the scenario server streams metric frames from.
        """
        if mesh is None and self.geom.n_devices > 1:
            mesh = _mesh_for(self.proto_engine())
        runner = self.make_runner(mesh)
        frames: list = []

        def step_chunk(st, n, ff):
            return runner(st, estate.params, n, ff)

        state = estate.state
        if not self.delta_cfg.enabled:
            state = step_chunk(state, n_steps, full_first)
            estate = dataclasses.replace(estate, state=state)
            if collect is not None:
                out = collect(estate)
                if out is not None:
                    frames.append(out)
            return estate, frames

        r = max(int(self.delta_cfg.refresh_interval), 1)
        done = 0
        ff = full_first
        while done < n_steps:
            n = min(r, n_steps - done)
            state = step_chunk(state, n, ff)
            done += n
            ff = True          # every later segment opens with a refresh
            if collect is not None:
                cur = dataclasses.replace(estate, state=state)
                out = collect(cur)
                if out is not None:
                    frames.append(out)
        return dataclasses.replace(estate, state=state), frames


def runner_cache_stats() -> Dict[str, Any]:
    """Hit/miss/evict snapshot of the ensemble runner cache."""
    return _RUNNER_CACHE.stats().as_dict()
