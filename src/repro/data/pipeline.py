"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step): any host can materialize any
shard at any time, which is the backbone of the fault-tolerance story —
a restarted or replacement worker regenerates exactly the batches it needs
(no data-loader state to checkpoint beyond the step counter), and a
straggler's shard can be recomputed by any peer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_for_step(self, step: int) -> Dict[str, Array]:
        """Materialize the full global batch for one step (host-side)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        b, s = self.global_batch, self.seq_len
        out: Dict[str, Array] = {}
        if cfg.family == "audio":
            k1, k2 = jax.random.split(key)
            out["frames"] = jax.random.normal(
                k1, (b, s, cfg.frontend_dim), jnp.bfloat16)
            out["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab)
        elif cfg.family == "vlm":
            k1, k2 = jax.random.split(key)
            s_text = s - cfg.n_patches
            toks = jax.random.randint(k1, (b, s_text), 0, cfg.vocab)
            out["tokens"] = toks
            out["patches"] = jax.random.normal(
                k2, (b, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)
            out["labels"] = jnp.roll(toks, -1, axis=1)
        else:
            toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
            out["tokens"] = toks
            out["labels"] = jnp.roll(toks, -1, axis=1)
        return out

    def abstract_batch(self) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        cfg = self.cfg
        b, s = self.global_batch, self.seq_len
        i32 = jnp.int32
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.family == "vlm":
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - cfg.n_patches), i32),
                "patches": jax.ShapeDtypeStruct(
                    (b, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s - cfg.n_patches), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
