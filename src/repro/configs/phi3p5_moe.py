"""phi3.5-moe-42b-a6.6b — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig, register

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=0,
    vocab=32064, attention="gqa", norm="layernorm", pos="rope",
    moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=6400),
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=256,
    moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=96),
)

register(FULL, SMOKE)
