"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
import dataclasses

from repro.configs.base import ArchConfig, XLSTMConfig, register

FULL = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, attention="none", norm="layernorm", pos="none",
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, conv_kernel=4),
    sub_quadratic=True,
    notes="48 blocks, 7:1 mLSTM:sLSTM mixing; linear-time state.",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, vocab=256,
    xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, conv_kernel=4),
)

register(FULL, SMOKE)
