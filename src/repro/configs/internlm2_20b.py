"""internlm2-20b — dense GQA kv=8 [arXiv:2403.17297]."""
import dataclasses

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92544, attention="gqa", norm="rmsnorm", pos="rope",
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
    vocab=256,
)

register(FULL, SMOKE)
