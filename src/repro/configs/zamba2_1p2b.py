"""zamba2-1.2b — Mamba2 blocks + shared attention [arXiv:2411.15242]."""
import dataclasses

from repro.configs.base import ArchConfig, SSMConfig, register

FULL = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, attention="gqa", norm="rmsnorm", pos="rope",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, n_heads=32, chunk=256),
    shared_attn_every=6, sub_quadratic=True,
    notes="38 Mamba2 blocks; ONE shared attention+MLP block (weight reuse) "
          "applied every 6 blocks (6 groups + 2-layer tail).",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, n_heads=4, chunk=32),
    shared_attn_every=2,
)

register(FULL, SMOKE)
