"""hubert-xlarge — encoder-only audio backbone [arXiv:2106.07447].

The conv feature extractor is a STUB per the assignment: input_specs provide
precomputed 512-d frame embeddings; the backbone (48L transformer encoder)
is fully implemented.  Encoder-only => no decode shapes.
"""
import dataclasses

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab=504, attention="gqa", causal=False, norm="layernorm", pos="rope",
    frontend_dim=512,
    notes="Bidirectional encoder; masked-unit prediction head (504 units). "
          "Conv frontend stubbed with precomputed frame embeddings.",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=64, frontend_dim=16,
)

register(FULL, SMOKE)
