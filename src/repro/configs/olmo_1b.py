"""olmo-1b — dense, non-parametric LayerNorm [arXiv:2402.00838]."""
import dataclasses

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, attention="gqa", norm="nonparametric_ln", pos="rope",
    tie_embeddings=True,
    notes="Non-parametric LN (no scale/bias), tied embeddings.",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab=256,
)

register(FULL, SMOKE)
