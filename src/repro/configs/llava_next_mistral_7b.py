"""llava-next-mistral-7b — VLM, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Vision tower is a STUB per the assignment: input_specs provide precomputed
1024-d CLIP patch embeddings for the anyres tiles (n_patches prefix); the
Mistral-7B decoder backbone is fully implemented.
"""
import dataclasses

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, attention="gqa", norm="rmsnorm", pos="rope",
    rope_theta=1e6, frontend_dim=1024, n_patches=1152,
    notes="anyres tiling -> 1152-patch prefix (base 576 + tile pool), "
          "projected and prepended to the token sequence.",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, frontend_dim=24, n_patches=8,
)

register(FULL, SMOKE)
