"""Architecture registry: importing this package registers all 10 assigned
architectures (exact published configs) plus their smoke reductions."""

from repro.configs import (  # noqa: F401
    hubert_xlarge,
    internlm2_20b,
    llava_next_mistral_7b,
    minicpm3_4b,
    minicpm_2b,
    olmo_1b,
    phi3p5_moe,
    qwen3_moe,
    xlstm_1p3b,
    zamba2_1p2b,
)
from repro.configs.base import ArchConfig, ArchSpec, get, names  # noqa: F401
