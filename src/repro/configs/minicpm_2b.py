"""minicpm-2b — llama-like dense arch trained with WSD [arXiv:2404.06395]."""
import dataclasses

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, attention="gqa", norm="rmsnorm", pos="rope",
    tie_embeddings=True,
    notes="WSD (warmup-stable-decay) schedule is the training-side feature; "
          "see repro.training.optimizer.WSDSchedule.",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab=255,
)

register(FULL, SMOKE)
