"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (exact published numbers) plus a
``smoke()`` reduction of the same family for CPU tests.  Block composition is
expressed as a pattern over block kinds so dense, MoE, SSM, hybrid and
encoder-only families all lower through the same assembly code.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    n_heads: int = 32          # SSD heads
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8        # every k-th block is sLSTM, rest mLSTM
    proj_factor: float = 2.0    # mLSTM up-projection
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    attention: str = "gqa"      # gqa | mla | none
    causal: bool = True
    norm: str = "rmsnorm"       # rmsnorm | layernorm | nonparametric_ln
    pos: str = "rope"           # rope | learned | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k SSM blocks
    shared_attn_every: int = 0
    # modality frontend stubs (audio/vlm): precomputed embedding dim
    frontend_dim: int = 0
    n_patches: int = 0          # vlm: image-patch prefix length
    sub_quadratic: bool = False # may run long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/head shard
        cleanly over any mesh axis (standard production padding; the extra
        logit columns are masked to -inf in the loss)."""
        return ((self.vocab + 255) // 256) * 256

    # -- parameter count (for MODEL_FLOPS = 6*N*D) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        n = 0
        # embeddings (+ untied head)
        n += v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention == "gqa":
            per_layer += d * self.n_heads * hd          # q
            per_layer += 2 * d * self.n_kv_heads * hd   # k, v
            per_layer += self.n_heads * hd * d          # o
        elif self.attention == "mla":
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_hd
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        if self.moe is not None:
            e = self.moe.n_experts if not active_only else self.moe.top_k
            per_layer += d * self.moe.n_experts          # router
            per_layer += e * 3 * d * self.moe.expert_d_ff
        elif self.family in ("ssm",) and self.xlstm is not None:
            di = int(self.d_model * self.xlstm.proj_factor)
            per_layer += 2 * d * di + di * d + 3 * di * (di // 64)  # coarse
        elif self.family in ("ssm", "hybrid") and self.ssm is not None:
            di = self.ssm.expand * d
            per_layer += d * 2 * di + di * d + di * self.ssm.d_conv
            per_layer += di * 2 * self.ssm.d_state
        if f:
            per_layer += 3 * d * f                       # swiglu (or 2*d*f gelu)
        n += self.n_layers * per_layer
        return n

    def model_flops_per_token(self) -> float:
        """6*N (dense) or 6*N_active (MoE) — multiplied by tokens D later."""
        return 6.0 * self.param_count(active_only=self.moe is not None)


# Registry ------------------------------------------------------------------

_REGISTRY: Dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    full: ArchConfig
    smoke: ArchConfig


def register(full: ArchConfig, smoke: ArchConfig) -> ArchSpec:
    spec = ArchSpec(full=full, smoke=smoke)
    _REGISTRY[full.name] = spec
    return spec


def get(name: str) -> ArchSpec:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401 — triggers per-arch module imports
    return _REGISTRY[name]


def names() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))
