"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-235B-A22B family]."""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig, register

FULL = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=0,
    head_dim=128, vocab=151936, attention="gqa", norm="rmsnorm", pos="rope",
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=1536),
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    vocab=256, moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=64),
)

register(FULL, SMOKE)
