"""minicpm3-4b — MLA attention [hf:openbmb/MiniCPM3-4B]."""
import dataclasses

from repro.configs.base import ArchConfig, MLAConfig, register

FULL = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, attention="mla", norm="rmsnorm", pos="rope",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    notes="Multi-head Latent Attention: KV cache stores only the 288-d "
          "compressed latent per position — the paper-analogue "
          "small-slowly-varying exchange state.",
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=8),
)

register(FULL, SMOKE)
