"""Roofline analysis from compiled HLO (deliverable g).

``cost_analysis()`` on XLA:CPU counts while-loop bodies ONCE (verified
empirically), which under-counts scanned-layer models by ~n_layers.  This
module therefore implements a loop-aware mini cost model over the optimized
(post-SPMD-partitioning, i.e. per-device) HLO text:

  * FLOPs           — from ``dot`` ops: 2 x prod(output shape) x contracted
                      size (matmul-dominated models; elementwise FLOPs are
                      negligible against MXU work and noted as such).
  * HBM bytes       — sum of operand + output bytes of materializing ops
                      (fusions, dots, copies, slices, gathers, collectives):
                      the standard roofline HBM-traffic model.
  * Collective bytes — per-op wire bytes with ring-algorithm factors:
                      all-gather ~ M_out, reduce-scatter ~ M_in,
                      all-reduce ~ 2M, all-to-all ~ M, collective-permute = M.
  * While loops     — trip counts parsed from the loop condition's constant;
                      body costs are multiplied through (nested loops
                      compose multiplicatively).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (single-axis worst case; multi-axis overlap is an
optimization recorded separately when exploited).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

ITEMSIZE = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def _split_type_op(rest: str):
    """Split '<type> <opcode>(<args...>' handling tuple types that contain
    parens and /*index=N*/ comments."""
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:]
        return rest, ""
    m = re.match(r"(\S+)\s+(.*)$", rest)
    if not m:
        return rest, ""
    return m.group(1), m.group(2)

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
BYTES_OPS = COLLECTIVES + (
    "fusion", "dot", "copy", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "gather", "scatter", "reduce",
    "transpose", "convert", "sort", "broadcast", "iota", "pad", "reverse",
    "reduce-window", "select-and-scatter", "rng", "cholesky",
    "triangular-solve", "convolution",
)
SKIP_OPS = ("parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "while", "conditional", "call", "after-all", "add-dependency",
            "custom-call", "partition-id", "replica-id", "reshape")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in ITEMSIZE:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * ITEMSIZE[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dtype, dims = m.group(1), m.group(2)
    dl = [int(d) for d in dims.split(",") if d] if dims else []
    return dtype, dl


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_bytes: int
    type_str: str
    args: str
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    depth = 0
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and depth == 0:
            cur = m.group(1)
            comps[cur] = []
            depth = 1
            continue
        if cur is not None:
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _parse_ops(lines: List[str]) -> List[OpInfo]:
    ops = []
    for line in lines:
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        type_str, after = _split_type_op(rest)
        om = _OPCODE_RE.match(after)
        if not om:
            continue
        opcode, args = om.groups()
        ops.append(OpInfo(
            name=name, opcode=opcode, out_bytes=_shape_bytes(type_str),
            type_str=type_str, args=args, line=line,
        ))
    return ops


def _dot_flops(op: OpInfo, symtab: Dict[str, OpInfo]) -> float:
    out = _shape_dims(op.type_str)
    if out is None:
        return 0.0
    _, out_dims = out
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    lhs_name = None
    argm = re.match(r"\s*%?([\w\.\-]+)", op.args)
    if argm:
        lhs_name = argm.group(1)
    csize = 1
    if lhs_name and lhs_name in symtab and cdims:
        lhs = _shape_dims(symtab[lhs_name].type_str)
        if lhs:
            _, ldims = lhs
            for c in cdims:
                if c < len(ldims):
                    csize *= ldims[c]
    return 2.0 * math.prod(out_dims or [1]) * csize


def _operand_bytes(op: OpInfo, symtab: Dict[str, OpInfo],
                   cap: Optional[int] = None,
                   consumed: Optional[set] = None) -> int:
    """Sum operand bytes.  ``cap`` bounds each operand's contribution at the
    op's output size — the right HBM model for kLoop fusions and slicing ops
    that read only what they produce (otherwise a dynamic-slice of a stacked
    per-layer parameter inside a scan counts the whole stack every trip).
    ``consumed`` dedups reads: a buffer read by several consumers within one
    computation is charged once (it stays resident / is re-fused), which
    keeps the HBM-traffic model from scaling with HLO fan-out."""
    total = 0
    for ref in re.findall(r"%([\w\.\-]+)", op.args.split(")", 1)[0]):
        if ref in symtab:
            if consumed is not None:
                if ref in consumed:
                    continue
                consumed.add(ref)
            b = symtab[ref].out_bytes
            if cap is not None:
                b = min(b, cap)
            total += b
    return total


def _collective_wire_bytes(op: OpInfo, symtab: Dict[str, OpInfo]) -> float:
    out_b = op.out_bytes
    in_b = _operand_bytes(op, symtab)
    kind = op.opcode
    if kind.startswith("all-reduce"):
        return 2.0 * out_b
    if kind.startswith("all-gather"):
        return float(out_b)
    if kind.startswith("reduce-scatter"):
        return float(in_b)
    if kind.startswith("all-to-all"):
        return float(out_b)
    if kind.startswith("collective-permute"):
        return float(out_b)
    return 0.0


def _trip_count(cond_lines: List[str]) -> int:
    """Best-effort trip count: the comparison constant in the while cond."""
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def analyze_hlo(text: str) -> CompCost:
    comps = _split_computations(text)
    parsed = {name: _parse_ops(lines) for name, lines in comps.items()}
    symtabs = {name: {o.name: o for o in ops} for name, ops in parsed.items()}

    # map computation -> cost (memoized, loop-scaled)
    memo: Dict[str, CompCost] = {}

    def cost_of(comp: str) -> CompCost:
        if comp in memo:
            return memo[comp]
        total = CompCost()
        memo[comp] = total  # guard cycles
        ops = parsed.get(comp, [])
        st = symtabs.get(comp, {})
        consumed: set = set()
        for op in ops:
            oc = op.opcode
            if oc == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.line)
                if mb:
                    sub = cost_of(mb.group(1))
                    mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                                   op.line)
                    if mt:
                        trips = int(mt.group(1))
                    else:
                        trips = (_trip_count(comps.get(mc.group(1), []))
                                 if mc else 1)
                    total.flops += sub.flops * trips
                    total.hbm_bytes += sub.hbm_bytes * trips
                    total.coll_bytes += sub.coll_bytes * trips
                    for k, v in sub.coll_by_kind.items():
                        total.coll_by_kind[k] = (
                            total.coll_by_kind.get(k, 0.0) + v * trips)
                continue
            if oc in ("call", "conditional"):
                for sub_name in re.findall(
                        r"(?:to_apply|branch_computations=\{|true_computation"
                        r"|false_computation)=?\{?%?([\w\.\-]+)", op.line):
                    if sub_name in parsed:
                        sub = cost_of(sub_name)
                        total.flops += sub.flops
                        total.hbm_bytes += sub.hbm_bytes
                        total.coll_bytes += sub.coll_bytes
                        for k, v in sub.coll_by_kind.items():
                            total.coll_by_kind[k] = (
                                total.coll_by_kind.get(k, 0.0) + v)
                continue
            if oc == "fusion":
                # fused subcomputation: count its dot flops (calls=%comp)
                mfc = re.search(r"calls=%?([\w\.\-]+)", op.line)
                if mfc and mfc.group(1) in parsed:
                    fops = parsed[mfc.group(1)]
                    fst = symtabs[mfc.group(1)]
                    for fo in fops:
                        if fo.opcode == "dot":
                            total.flops += _dot_flops(fo, fst)
                # kLoop fusions read O(output) per operand; kInput
                # (reduction) fusions read operands fully.
                cap = op.out_bytes if "kind=kLoop" in op.line else None
                total.hbm_bytes += op.out_bytes + _operand_bytes(
                    op, st, cap, consumed)
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, st)
                total.hbm_bytes += op.out_bytes + _operand_bytes(
                    op, st, None, consumed)
                continue
            if oc.startswith(COLLECTIVES):
                w = _collective_wire_bytes(op, st)
                total.coll_bytes += w
                base = next(c for c in COLLECTIVES if oc.startswith(c))
                total.coll_by_kind[base] = (
                    total.coll_by_kind.get(base, 0.0) + w)
                total.hbm_bytes += op.out_bytes + _operand_bytes(
                    op, st, None, consumed)
                continue
            if oc.startswith(BYTES_OPS) and not oc.startswith(SKIP_OPS):
                cap = (op.out_bytes
                       if oc.startswith(("slice", "dynamic-slice", "gather",
                                         "dynamic-update-slice", "copy",
                                         "transpose", "convert", "broadcast",
                                         "concatenate", "pad", "reverse"))
                       else None)
                total.hbm_bytes += op.out_bytes + _operand_bytes(
                    op, st, cap, consumed)
        return total

    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    entry = m.group(1) if m and m.group(1) in parsed else None
    if entry is None:
        # fall back: computation with the most ops
        entry = max(parsed, key=lambda n: len(parsed[n]))
    return cost_of(entry)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

HW = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_kind: Dict[str, float]
    model_flops_global: float
    per_device_memory_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / HW["peak_flops_bf16"]

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / HW["ici_bw"]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time / bound time — the score we hillclimb."""
        t_useful = (self.model_flops_global / self.chips
                    / HW["peak_flops_bf16"])
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / bound if bound else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_dev": self.flops_per_device,
            "hbm_bytes_per_dev": self.hbm_bytes_per_device,
            "coll_bytes_per_dev": self.coll_bytes_per_device,
            "coll_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_memory_bytes": self.per_device_memory_bytes,
        }


def model_flops_for_cell(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed.
    Train counts fwd+bwd (3x forward); prefill/decode count forward only
    (2*N*D)."""
    from repro.configs.base import get
    from repro.launch.specs import SHAPES
    from repro.models.model import build_model
    from repro.models.params import count_params

    cfg = get(arch).full
    model = build_model(cfg)
    n_total = count_params(model.spec)
    if cfg.moe is not None:
        m = cfg.moe
        expert_params = cfg.n_layers * m.n_experts * 3 * cfg.d_model * \
            m.expert_d_ff
        active = n_total - expert_params * (1.0 - m.top_k / m.n_experts)
    else:
        active = n_total
    s = SHAPES[shape_name]
    if s["kind"] == "train":
        tokens = s["seq"] * s["batch"]
        return 6.0 * active * tokens
    if s["kind"] == "prefill":
        tokens = s["seq"] * s["batch"]
        return 2.0 * active * tokens
    # decode: one token per sequence, but attention reads the full cache —
    # 2*N per token plus cache-read FLOPs (2 * cache_dot) folded into N term.
    tokens = s["batch"]
    return 2.0 * active * tokens


# ---------------------------------------------------------------------------
# Op-level breakdown (hillclimbing forensics)
# ---------------------------------------------------------------------------

def breakdown(text: str, top: int = 15):
    """Top contributors to HBM traffic and collective bytes, loop-scaled."""
    comps = _split_computations(text)
    parsed = {n: _parse_ops(l) for n, l in comps.items()}
    symtabs = {n: {o.name: o for o in ops} for n, ops in parsed.items()}
    trips: Dict[str, int] = {}
    for n, ops in parsed.items():
        for o in ops:
            if o.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", o.line)
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', o.line)
                if mb:
                    trips[mb.group(1)] = int(mt.group(1)) if mt else 1
    # propagate nesting (one level is enough for scan-in-scan)
    for n, ops in parsed.items():
        base = trips.get(n, 1)
        for o in ops:
            if o.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", o.line)
                if mb and mb.group(1) in trips:
                    trips[mb.group(1)] *= base

    hbm_rows, coll_rows = [], []
    for n, ops in parsed.items():
        t = trips.get(n, 1)
        st = symtabs[n]
        consumed: set = set()
        for o in ops:
            meta = re.search(r'op_name="([^"]*)"', o.line)
            label = (meta.group(1) if meta else o.name)[-90:]
            if o.opcode.startswith(COLLECTIVES):
                w = _collective_wire_bytes(o, st)
                coll_rows.append((w * t, w, t, o.opcode, label))
                hbm_rows.append((
                    (o.out_bytes + _operand_bytes(o, st)) * t,
                    o.out_bytes, t, o.opcode, label))
            elif o.opcode == "fusion" or (
                    o.opcode.startswith(BYTES_OPS)
                    and not o.opcode.startswith(SKIP_OPS)):
                cap = o.out_bytes if "kind=kLoop" in o.line else None
                b = o.out_bytes + _operand_bytes(o, st, cap, consumed)
                hbm_rows.append((b * t, b, t, o.opcode, label))
    hbm_rows.sort(reverse=True)
    coll_rows.sort(reverse=True)
    return hbm_rows[:top], coll_rows[:top]


def print_breakdown(text: str, top: int = 15):
    hbm, coll = breakdown(text, top)
    print("== top HBM traffic ==")
    for tot, b, t, op, label in hbm:
        print(f"  {tot:10.3e} ({b:9.2e} x{t:4d}) {op:22s} {label}")
    print("== top collective bytes ==")
    for tot, b, t, op, label in coll:
        print(f"  {tot:10.3e} ({b:9.2e} x{t:4d}) {op:22s} {label}")
