"""Cell proliferation (paper §3.1): cells grow and divide until space
saturates — exercises the spawn path, capacity handling and migration."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, POS, Simulation, operations
from repro.core.behaviors import soft_repulsion_adhesion
from repro.core.compile_cache import memoize
from repro.sims.common import disk_positions, init_agents, make_sim

SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


def _update(attrs, valid, acc, key, params, dt):
    f = acc["force"]
    max_step = jnp.float32(params["max_step"])
    norm = jnp.sqrt(jnp.sum(f * f, axis=-1, keepdims=True) + 1e-12)
    step = f * jnp.minimum(max_step / norm, dt)
    new = dict(attrs)
    new[POS] = attrs[POS] + jnp.where(valid[..., None], step, 0.0)
    # growth
    d = attrs["diameter"] + jnp.where(valid, params["growth"] * dt, 0.0)
    divide_ready = d >= params["div_diameter"]
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, valid.shape)
    spawn = valid & divide_ready & (u < params["div_prob"])
    d = jnp.where(spawn, d * 0.5, d)
    new["diameter"] = d
    # child: half diameter, offset position
    off = 0.25 * jax.random.normal(k2, new[POS].shape)
    child = dict(new)
    child[POS] = new[POS] + off
    child["diameter"] = jnp.where(spawn, d, 0.5)
    return new, valid, spawn, child


@memoize("sims.cell_proliferation.behavior", maxsize=8)
def behavior(radius=2.0) -> Behavior:
    return Behavior(
        schema=SCHEMA,
        pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"),
        update_fn=_update,
        radius=radius,
        params={"repulsion": 2.0, "adhesion": 0.0, "same_type_only": 0.0,
                "max_step": 0.4, "growth": 0.4, "div_diameter": 1.0,
                "div_prob": 0.3},
        can_spawn=True,
    )


def init(sim, n_agents: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lx, ly = sim.geom.domain_size
    pos = disk_positions(rng, n_agents, (lx / 2, ly / 2), min(lx, ly) / 8)
    attrs = {
        "diameter": np.full((n_agents,), 0.6, np.float32),
        "ctype": np.zeros((n_agents,), np.int32),
    }
    return init_agents(sim, pos, attrs, seed=seed)


def simulation(n_agents=50, seed=0, mesh=None, mesh_shape=(1, 1),
               interior=(8, 8), delta=None, rebalance=None,
               sweep_backend="auto") -> Simulation:
    sim = make_sim(behavior(), interior=interior, mesh_shape=mesh_shape,
                   cap=32, delta=delta, mesh=mesh, rebalance=rebalance,
                   sweep_backend=sweep_backend)
    return init(sim, n_agents, seed)


def run(n_agents=50, steps=20, seed=0, mesh=None, mesh_shape=(1, 1),
        interior=(8, 8), delta=None, rebalance=None, sweep_backend="auto"):
    sim = simulation(n_agents=n_agents, seed=seed, mesh=mesh,
                     mesh_shape=mesh_shape, interior=interior, delta=delta,
                     rebalance=rebalance, sweep_backend=sweep_backend)
    n0 = sim.n_agents()
    sim.every(1, operations.agent_count, name="counts")
    sim.run(steps)
    counts = sim.series["counts"]
    return sim.state, {"n_initial": n0, "n_final": counts[-1],
                       "counts": counts}
