"""SIR-with-mechanics: a composed-behavior sim (facade behavior stacks).

The epidemic behavior from :mod:`repro.sims.epidemiology` is stacked on top
of the soft-sphere mechanics behavior from :mod:`repro.sims.cell_clustering`
with :func:`repro.core.compose` — no hand-fused kernel.  Mechanically
adhering cells form clusters, and the infection now spreads along that
emergent contact structure: the two pair kernels run over one neighborhood
gather (the infection kernel gated to its own smaller radius), and the two
updates chain (displacement first, then random walk + compartment
transitions).

This is the scenario the paper's composability story is about: existing
library behaviors combined into a new model with one line.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import Domain, Simulation, compose, operations
from repro.core.compile_cache import memoize
from repro.core.ensemble import Ensemble
from repro.sims import cell_clustering, epidemiology
from repro.sims.common import init_agents, make_sim, uniform_positions

S, I, R = epidemiology.S, epidemiology.I, epidemiology.R


@memoize("sims.sir_mechanics.behavior", maxsize=32)
def behavior(repulsion=2.0, adhesion=0.5, mech_radius=2.0, max_step=0.3,
             beta=0.05, gamma=0.1, sigma=0.3, sir_radius=1.5):
    """``compose(mechanics, sir)`` — union schema {diameter, ctype, state},
    max radius from mechanics, infection gated to its smaller radius."""
    mech = cell_clustering.behavior(
        repulsion=repulsion, adhesion=adhesion, radius=mech_radius,
        max_step=max_step)
    sir = epidemiology.behavior(
        beta=beta, gamma=gamma, sigma=sigma, radius=sir_radius)
    return compose(mech, sir)


def init(sim, n_agents: int, initial_infected: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pos = uniform_positions(rng, n_agents, sim.geom)
    st = np.zeros((n_agents,), np.int32)
    st[rng.choice(n_agents, initial_infected, replace=False)] = I
    attrs = {
        "diameter": np.full((n_agents,), 1.0, np.float32),
        "ctype": rng.integers(0, 2, n_agents).astype(np.int32),
        "state": st,
    }
    return init_agents(sim, pos, attrs, seed=seed)


def simulation(n_agents=400, initial_infected=20, seed=0, mesh=None,
               mesh_shape=(1, 1), interior=(8, 8), delta=None,
               rebalance=None, sweep_backend="auto", **bparams
               ) -> Simulation:
    sim = make_sim(behavior(**bparams), interior=interior,
                   mesh_shape=mesh_shape, cap=32, boundary="toroidal",
                   dt=1.0, delta=delta, mesh=mesh, rebalance=rebalance,
                   sweep_backend=sweep_backend)
    init(sim, n_agents, initial_infected, seed)
    sim.every(1, operations.attr_counts("state", (S, I, R)), name="sir")
    return sim


def run(n_agents=400, steps=40, initial_infected=20, seed=0, mesh=None,
        mesh_shape=(1, 1), interior=(8, 8), delta=None, rebalance=None,
        sweep_backend="auto", **bparams):
    sim = simulation(n_agents=n_agents, initial_infected=initial_infected,
                     seed=seed, mesh=mesh, mesh_shape=mesh_shape,
                     interior=interior, delta=delta, rebalance=rebalance,
                     sweep_backend=sweep_backend, **bparams)
    f0 = cell_clustering.same_type_fraction(sim.state, sim.engine)
    sim.run(steps)
    f1 = cell_clustering.same_type_fraction(sim.state, sim.engine)
    return sim.state, {"series": np.array(sim.series["sir"]),
                       "same_frac_initial": f0, "same_frac_final": f1}


# ---------------------------------------------------------------------------
# Ensemble family (core.ensemble): the same composed model with its numeric
# knobs threaded as traced per-replica parameters, so R parameter points run
# in one vmapped dispatch (the serving layer's sir_mechanics family).
# ---------------------------------------------------------------------------

# Structural interaction radii of the family.  Radii shape the neighbor
# sweep and compose()'s static gating, so they bake into the trace and are
# shared by every replica; the *effective* infection radius still sweeps
# per replica through the traced `sir_radius` gate below (always within
# this structural bound).
MECH_RADIUS = 2.0
SIR_RADIUS_MAX = 1.5

ENSEMBLE_PARAMS = ("adhesion", "beta", "gamma", "max_step", "repulsion",
                   "sigma", "sir_radius")


def ensemble_defaults() -> dict:
    """Solo-model parameter point (matches ``behavior()``'s defaults)."""
    return {"repulsion": 2.0, "adhesion": 0.5, "max_step": 0.3,
            "beta": 0.05, "gamma": 0.1, "sigma": 0.3,
            "sir_radius": SIR_RADIUS_MAX}


def _gated_sir_pair(ai, aj, disp, dist2, params):
    """Epidemiology pair kernel with a *traced* radius gate: contributions
    beyond ``sir_radius`` vanish, so the infection radius sweeps per
    replica under the static structural radius."""
    out = epidemiology._pair(ai, aj, disp, dist2, params)
    r = jnp.float32(params["sir_radius"])
    gate = dist2 <= r * r
    return {k: jnp.where(gate, v, jnp.zeros_like(v))
            for k, v in out.items()}


def ensemble_behavior(params):
    """Family behavior factory: ``params`` values may be tracers (the
    ensemble runner calls this with per-replica ``(R,)->()`` scalars).
    Structure is fixed — schemas, radii, kernels — only numbers vary."""
    mech = dataclasses.replace(
        cell_clustering.behavior(radius=MECH_RADIUS),
        params={"repulsion": params["repulsion"],
                "adhesion": params["adhesion"],
                "same_type_only": 1.0,
                "max_step": params["max_step"]})
    sir = dataclasses.replace(
        epidemiology.behavior(radius=SIR_RADIUS_MAX),
        pair_fn=_gated_sir_pair,
        params={"beta": params["beta"], "gamma": params["gamma"],
                "sigma": params["sigma"],
                "sir_radius": params["sir_radius"]})
    return compose(mech, sir)


def ensemble_family(interior=(8, 8), mesh_shape=(1, 1), cap=32,
                    partition=None, delta=None, sweep_backend="auto",
                    guards=None) -> Ensemble:
    """The sir_mechanics compatibility family on a given geometry."""
    from repro.core import DeltaConfig, GuardConfig
    if partition is not None:
        geom = Domain(cell_size=2.0, interior=partition.max_widths,
                      mesh_shape=partition.mesh_shape, cap=cap,
                      boundary="toroidal", partition=partition)
    else:
        geom = Domain(cell_size=2.0, interior=tuple(interior),
                      mesh_shape=tuple(mesh_shape), cap=cap,
                      boundary="toroidal")
    return Ensemble(
        geom=geom, behavior_fn=ensemble_behavior,
        param_names=ENSEMBLE_PARAMS, dt=1.0,
        delta_cfg=delta if delta is not None else DeltaConfig(enabled=False),
        sweep_backend=sweep_backend,
        guards=guards if guards is not None else GuardConfig(),
        family="sir_mechanics")


def ensemble_point_state(ens: Ensemble, seed: int = 0, n_agents=400,
                         initial_infected=20):
    """Solo :class:`SimState` for one replica of the family (placement and
    RNG stream keyed by ``seed``) — the unit the scenario server stacks."""
    eng = ens.proto_engine()
    rng = np.random.default_rng(seed)
    pos = uniform_positions(rng, n_agents, ens.geom)
    st = np.zeros((n_agents,), np.int32)
    st[rng.choice(n_agents, initial_infected, replace=False)] = I
    attrs = {
        "diameter": np.full((n_agents,), 1.0, np.float32),
        "ctype": rng.integers(0, 2, n_agents).astype(np.int32),
        "state": st,
    }
    return eng.init_state(pos, attrs, seed=seed)


def ensemble_init(ens: Ensemble, points, n_agents=400,
                  initial_infected=20):
    """Stacked :class:`EnsembleState` for R parameter points.  Each point
    dict holds the family's traced knobs plus an optional host-side
    ``seed`` (default: the replica index) controlling initial placement
    and the per-replica RNG stream."""
    states, pts = [], []
    for r, p in enumerate(points):
        p = dict(p)
        seed = int(p.pop("seed", r))
        states.append(ensemble_point_state(
            ens, seed=seed, n_agents=n_agents,
            initial_infected=initial_infected))
        pts.append({**ensemble_defaults(), **p})
    return ens.init(states, pts)
