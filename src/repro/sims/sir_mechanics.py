"""SIR-with-mechanics: a composed-behavior sim (facade behavior stacks).

The epidemic behavior from :mod:`repro.sims.epidemiology` is stacked on top
of the soft-sphere mechanics behavior from :mod:`repro.sims.cell_clustering`
with :func:`repro.core.compose` — no hand-fused kernel.  Mechanically
adhering cells form clusters, and the infection now spreads along that
emergent contact structure: the two pair kernels run over one neighborhood
gather (the infection kernel gated to its own smaller radius), and the two
updates chain (displacement first, then random walk + compartment
transitions).

This is the scenario the paper's composability story is about: existing
library behaviors combined into a new model with one line.
"""

from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.core import Simulation, compose, operations
from repro.sims import cell_clustering, epidemiology
from repro.sims.common import init_agents, make_sim, uniform_positions

S, I, R = epidemiology.S, epidemiology.I, epidemiology.R


@lru_cache(maxsize=32)
def behavior(repulsion=2.0, adhesion=0.5, mech_radius=2.0, max_step=0.3,
             beta=0.05, gamma=0.1, sigma=0.3, sir_radius=1.5):
    """``compose(mechanics, sir)`` — union schema {diameter, ctype, state},
    max radius from mechanics, infection gated to its smaller radius."""
    mech = cell_clustering.behavior(
        repulsion=repulsion, adhesion=adhesion, radius=mech_radius,
        max_step=max_step)
    sir = epidemiology.behavior(
        beta=beta, gamma=gamma, sigma=sigma, radius=sir_radius)
    return compose(mech, sir)


def init(sim, n_agents: int, initial_infected: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pos = uniform_positions(rng, n_agents, sim.geom)
    st = np.zeros((n_agents,), np.int32)
    st[rng.choice(n_agents, initial_infected, replace=False)] = I
    attrs = {
        "diameter": np.full((n_agents,), 1.0, np.float32),
        "ctype": rng.integers(0, 2, n_agents).astype(np.int32),
        "state": st,
    }
    return init_agents(sim, pos, attrs, seed=seed)


def simulation(n_agents=400, initial_infected=20, seed=0, mesh=None,
               mesh_shape=(1, 1), interior=(8, 8), delta=None,
               rebalance=None, sweep_backend="auto", **bparams
               ) -> Simulation:
    sim = make_sim(behavior(**bparams), interior=interior,
                   mesh_shape=mesh_shape, cap=32, boundary="toroidal",
                   dt=1.0, delta=delta, mesh=mesh, rebalance=rebalance,
                   sweep_backend=sweep_backend)
    init(sim, n_agents, initial_infected, seed)
    sim.every(1, operations.attr_counts("state", (S, I, R)), name="sir")
    return sim


def run(n_agents=400, steps=40, initial_infected=20, seed=0, mesh=None,
        mesh_shape=(1, 1), interior=(8, 8), delta=None, rebalance=None,
        sweep_backend="auto", **bparams):
    sim = simulation(n_agents=n_agents, initial_infected=initial_infected,
                     seed=seed, mesh=mesh, mesh_shape=mesh_shape,
                     interior=interior, delta=delta, rebalance=rebalance,
                     sweep_backend=sweep_backend, **bparams)
    f0 = cell_clustering.same_type_fraction(sim.state, sim.engine)
    sim.run(steps)
    f1 = cell_clustering.same_type_fraction(sim.state, sim.engine)
    return sim.state, {"series": np.array(sim.series["sir"]),
                       "same_frac_initial": f0, "same_frac_final": f1}
