"""Epidemiology use case (paper §3.1, Figure 5): spatial SIR model.

Agents random-walk and infect susceptible neighbors within the interaction
radius; infected agents recover at rate gamma.  With high mobility the
spatial model converges to the classic Kermack–McKendrick ODE — the paper's
correctness figure compares exactly these S/I/R curves, and our test does
the same against an RK4 integration of the ODE.

Distributed evaluation uses ``Comm.sum_over_all_ranks`` — the engine-level
analogue of the paper's two-line ``SumOverAllRanks`` change (§3.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, POS, Simulation, operations
from repro.core.compile_cache import memoize
from repro.sims.common import init_agents, make_sim, uniform_positions

S, I, R = 0, 1, 2

SCHEMA = AgentSchema.create({
    "state": ((), jnp.int32),
})


def _pair(ai, aj, disp, dist2, params):
    # count infected neighbors
    return {"n_inf": (aj["state"] == I).astype(jnp.float32)}


def _update(attrs, valid, acc, key, params, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    # Brownian walk (high mobility -> well-mixed limit)
    step = params["sigma"] * jax.random.normal(k1, attrs[POS].shape)
    new = dict(attrs)
    new[POS] = attrs[POS] + jnp.where(valid[..., None], step, 0.0)
    st = attrs["state"]
    # infection: P = 1 - (1-beta)^n_infected_neighbors
    p_inf = 1.0 - jnp.power(1.0 - params["beta"], acc["n_inf"])
    u1 = jax.random.uniform(k2, st.shape)
    becomes_i = (st == S) & (u1 < p_inf)
    u2 = jax.random.uniform(k3, st.shape)
    recovers = (st == I) & (u2 < params["gamma"] * dt)
    st = jnp.where(becomes_i, I, st)
    st = jnp.where(recovers, R, st)
    new["state"] = st
    spawn = jnp.zeros_like(valid)
    return new, valid, spawn, None


@memoize("sims.epidemiology.behavior", maxsize=32)
def behavior(beta=0.03, gamma=0.25, sigma=1.2, radius=2.0) -> Behavior:
    return Behavior(
        schema=SCHEMA,
        pair_fn=_pair,
        pair_attrs=("state",),
        update_fn=_update,
        radius=radius,
        params={"beta": beta, "gamma": gamma, "sigma": sigma},
    )


def init(sim, n_agents: int, initial_infected: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pos = uniform_positions(rng, n_agents, sim.geom)
    st = np.zeros((n_agents,), np.int32)
    st[rng.choice(n_agents, initial_infected, replace=False)] = I
    return init_agents(sim, pos, {"state": st}, seed=seed)


def sir_counts(state) -> tuple:
    st = np.asarray(state.soa.attrs["state"]).ravel()
    v = np.asarray(state.soa.valid).ravel()
    st = st[v]
    return (int(np.sum(st == S)), int(np.sum(st == I)),
            int(np.sum(st == R)))


def sir_ode(n, i0, beta_eff, gamma, dt, steps):
    """RK4 Kermack–McKendrick reference."""
    s, i, r = float(n - i0), float(i0), 0.0
    out = [(s, i, r)]

    def f(y):
        s, i, r = y
        return np.array([-beta_eff * s * i / n,
                         beta_eff * s * i / n - gamma * i,
                         gamma * i])

    y = np.array([s, i, r])
    for _ in range(steps):
        k1 = f(y)
        k2 = f(y + 0.5 * dt * k1)
        k3 = f(y + 0.5 * dt * k2)
        k4 = f(y + dt * k3)
        y = y + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
        out.append(tuple(y))
    return np.array(out)


def simulation(n_agents=600, initial_infected=30, seed=0, mesh=None,
               mesh_shape=(1, 1), interior=(10, 10), delta=None,
               rebalance=None, sweep_backend="auto", **bparams
               ) -> Simulation:
    """SIR sim on the facade, with the S/I/R compartment reducer (the
    paper's §3.4 ``SumOverAllRanks`` two-liner) pre-scheduled every step."""
    sim = make_sim(behavior(**bparams), interior=interior,
                   mesh_shape=mesh_shape, boundary="toroidal", dt=1.0,
                   delta=delta, mesh=mesh, rebalance=rebalance,
                   sweep_backend=sweep_backend)
    init(sim, n_agents, initial_infected, seed)
    sim.every(1, operations.attr_counts("state", (S, I, R)), name="sir")
    return sim


def run(n_agents=600, steps=60, initial_infected=30, seed=0, mesh=None,
        mesh_shape=(1, 1), interior=(10, 10), delta=None, rebalance=None,
        sweep_backend="auto", **bparams):
    sim = simulation(n_agents=n_agents, initial_infected=initial_infected,
                     seed=seed, mesh=mesh, mesh_shape=mesh_shape,
                     interior=interior, delta=delta, rebalance=rebalance,
                     sweep_backend=sweep_backend, **bparams)
    sim.run(steps)
    return sim.state, {"series": np.array(sim.series["sir"])}
