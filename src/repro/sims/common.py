"""Shared scaffolding for the paper's benchmark simulations (§3.1).

The sims build on :class:`repro.core.Simulation` — ``make_sim`` wires the
historical geometry defaults into the facade.  The former
``make_engine``/``run_sim`` pairing survives only as deprecation shims with
the one-line facade equivalent in the warning text.
"""

from __future__ import annotations

import warnings
from typing import Callable, Optional, Tuple, Union

import numpy as np

from repro.core import (
    Behavior, DeltaConfig, Engine, GridGeom, Rebalance, Simulation,
)
from repro.core.engine import SimState, warn_if_stale_engine


def make_sim(
    behaviors,
    *,
    interior: Tuple[int, int] = (8, 8),
    mesh_shape: Tuple[int, int] = (1, 1),
    cell_size: float = 2.0,
    cap: int = 24,
    boundary: str = "closed",
    delta: Optional[DeltaConfig] = None,
    dt: float = 0.1,
    mesh=None,
    rebalance: Union[Rebalance, int, None] = None,
    checkpoint=None,
    sweep_backend: str = "auto",
) -> Simulation:
    """Facade builder with the sims' historical geometry defaults."""
    return Simulation(
        dict(cell_size=cell_size, interior=interior, mesh_shape=mesh_shape,
             cap=cap, boundary=boundary),
        behaviors, mesh=mesh, delta=delta, dt=dt,
        rebalance=rebalance, checkpoint=checkpoint,
        sweep_backend=sweep_backend)


def init_agents(sim, positions: np.ndarray, attrs, seed: int = 0):
    """Initialize a :class:`Simulation` facade — or, for legacy callers, a
    raw :class:`Engine` — with the same (positions, attrs) arguments."""
    if isinstance(sim, Simulation):
        return sim.init(positions, attrs, seed=seed)
    return sim.init_state(positions, attrs, seed=seed)


def uniform_positions(rng: np.random.Generator, n: int, geom: GridGeom,
                      margin: float = 0.5) -> np.ndarray:
    lx, ly = geom.domain_size
    return rng.uniform([margin, margin], [lx - margin, ly - margin],
                       size=(n, 2)).astype(np.float32)


def disk_positions(rng: np.random.Generator, n: int, center, radius
                   ) -> np.ndarray:
    th = rng.uniform(0, 2 * np.pi, n)
    r = radius * np.sqrt(rng.uniform(0, 1, n))
    return np.stack([center[0] + r * np.cos(th),
                     center[1] + r * np.sin(th)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Deprecation shims (the only callers of warn_if_stale_engine)
# ---------------------------------------------------------------------------

def make_engine(
    behavior: Behavior,
    *,
    interior: Tuple[int, int] = (8, 8),
    mesh_shape: Tuple[int, int] = (1, 1),
    cell_size: float = 2.0,
    cap: int = 24,
    boundary: str = "closed",
    delta: Optional[DeltaConfig] = None,
    dt: float = 0.1,
    mesh=None,
    rebalance_every: int = 0,
    imbalance_threshold: float = 0.5,
) -> Engine:
    """DEPRECATED: build a raw Engine.  Use the facade instead:
    ``Simulation(dict(interior=..., mesh_shape=..., ...), behavior,
    delta=..., dt=..., rebalance=Rebalance(every=n, threshold=t))``."""
    warnings.warn(
        "make_engine is deprecated — use repro.core.Simulation("
        "dict(interior=..., mesh_shape=..., cap=...), behavior, delta=..., "
        "dt=..., rebalance=Rebalance(every=n, threshold=t)) instead",
        DeprecationWarning, stacklevel=2)
    geom = GridGeom(cell_size=cell_size, interior=interior,
                    mesh_shape=mesh_shape, cap=cap, boundary=boundary)
    return Engine(geom=geom, behavior=behavior,
                  delta_cfg=delta or DeltaConfig(enabled=False), dt=dt,
                  rebalance_every=rebalance_every,
                  imbalance_threshold=imbalance_threshold)


def run_sim(engine: Engine, state: SimState, steps: int, mesh=None,
            collect: Optional[Callable] = None, rebalancer=None):
    """DEPRECATED: drive a raw (engine, state) pair.  Use the facade instead:
    ``sim.run(steps, collect=...)`` — ``sim.engine``/``sim.state`` stay
    consistent across re-shards with no stale-handle contract to honor."""
    warnings.warn(
        "run_sim is deprecated — use repro.core.Simulation: "
        "sim.run(steps, collect=...); read sim.state / sim.series",
        DeprecationWarning, stacklevel=2)
    if mesh is not None:
        step = engine.make_sharded_step(mesh)
    else:
        step = engine.make_local_step()
    had_handle = rebalancer is not None
    eng, state, series = engine.drive(state, steps, step_fn=step,
                                      rebalancer=rebalancer, collect=collect)
    warn_if_stale_engine(engine, eng, had_handle)
    return state, series
