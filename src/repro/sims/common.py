"""Shared scaffolding for the paper's benchmark simulations (§3.1)."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, DeltaConfig, Engine, GridGeom
from repro.core.engine import SimState, total_agents, warn_if_stale_engine


@dataclasses.dataclass
class SimSetup:
    engine: Engine
    state: SimState
    step: Callable


def make_engine(
    behavior: Behavior,
    *,
    interior: Tuple[int, int] = (8, 8),
    mesh_shape: Tuple[int, int] = (1, 1),
    cell_size: float = 2.0,
    cap: int = 24,
    boundary: str = "closed",
    delta: Optional[DeltaConfig] = None,
    dt: float = 0.1,
    mesh=None,
    rebalance_every: int = 0,
    imbalance_threshold: float = 0.5,
) -> Engine:
    """``rebalance_every`` > 0 arms the dynamic load balancer (paper §2.4.5,
    core.reshard): every that many iterations the run loop checks the
    occupancy imbalance and re-shards past ``imbalance_threshold``."""
    geom = GridGeom(cell_size=cell_size, interior=interior,
                    mesh_shape=mesh_shape, cap=cap, boundary=boundary)
    return Engine(geom=geom, behavior=behavior,
                  delta_cfg=delta or DeltaConfig(enabled=False), dt=dt,
                  rebalance_every=rebalance_every,
                  imbalance_threshold=imbalance_threshold)


def uniform_positions(rng: np.random.Generator, n: int, geom: GridGeom,
                      margin: float = 0.5) -> np.ndarray:
    lx, ly = geom.domain_size
    return rng.uniform([margin, margin], [lx - margin, ly - margin],
                       size=(n, 2)).astype(np.float32)


def disk_positions(rng: np.random.Generator, n: int, center, radius
                   ) -> np.ndarray:
    th = rng.uniform(0, 2 * np.pi, n)
    r = radius * np.sqrt(rng.uniform(0, 1, n))
    return np.stack([center[0] + r * np.cos(th),
                     center[1] + r * np.sin(th)], axis=1).astype(np.float32)


def run_sim(engine: Engine, state: SimState, steps: int, mesh=None,
            collect: Optional[Callable] = None, rebalancer=None):
    """Drive a simulation; optionally collect per-step metrics.

    Dynamic load balancing engages when the engine's ``rebalance_every``
    knob is set or a ``core.reshard.Rebalancer`` is passed explicitly; after
    a re-shard the state lives on a different mesh, so pass an explicit
    rebalancer and read ``rebalancer.engine`` when you need the matching
    engine afterwards (or call ``engine.drive`` directly)."""
    if mesh is not None:
        step = engine.make_sharded_step(mesh)
    else:
        step = engine.make_local_step()
    had_handle = rebalancer is not None
    eng, state, series = engine.drive(state, steps, step_fn=step,
                                      rebalancer=rebalancer, collect=collect)
    warn_if_stale_engine(engine, eng, had_handle)
    return state, series
