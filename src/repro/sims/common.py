"""Shared scaffolding for the paper's benchmark simulations (§3.1)."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, DeltaConfig, Engine, GridGeom
from repro.core.engine import SimState, total_agents


@dataclasses.dataclass
class SimSetup:
    engine: Engine
    state: SimState
    step: Callable


def make_engine(
    behavior: Behavior,
    *,
    interior: Tuple[int, int] = (8, 8),
    mesh_shape: Tuple[int, int] = (1, 1),
    cell_size: float = 2.0,
    cap: int = 24,
    boundary: str = "closed",
    delta: Optional[DeltaConfig] = None,
    dt: float = 0.1,
    mesh=None,
) -> Engine:
    geom = GridGeom(cell_size=cell_size, interior=interior,
                    mesh_shape=mesh_shape, cap=cap, boundary=boundary)
    return Engine(geom=geom, behavior=behavior,
                  delta_cfg=delta or DeltaConfig(enabled=False), dt=dt)


def uniform_positions(rng: np.random.Generator, n: int, geom: GridGeom,
                      margin: float = 0.5) -> np.ndarray:
    lx, ly = geom.domain_size
    return rng.uniform([margin, margin], [lx - margin, ly - margin],
                       size=(n, 2)).astype(np.float32)


def disk_positions(rng: np.random.Generator, n: int, center, radius
                   ) -> np.ndarray:
    th = rng.uniform(0, 2 * np.pi, n)
    r = radius * np.sqrt(rng.uniform(0, 1, n))
    return np.stack([center[0] + r * np.cos(th),
                     center[1] + r * np.sin(th)], axis=1).astype(np.float32)


def run_sim(engine: Engine, state: SimState, steps: int, mesh=None,
            collect: Optional[Callable] = None):
    """Drive a simulation; optionally collect per-step metrics."""
    if mesh is not None:
        step = engine.make_sharded_step(mesh)
    else:
        step = engine.make_local_step()
    r = max(int(engine.delta_cfg.refresh_interval), 1)
    series = []
    for i in range(steps):
        full = (not engine.delta_cfg.enabled) or (i % r == 0)
        state = step(state, full_halo=full)
        if collect is not None:
            series.append(collect(state))
    return state, series
