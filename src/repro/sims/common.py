"""Shared scaffolding for the paper's benchmark simulations (§3.1).

The sims build on :class:`repro.core.Simulation` — ``make_sim`` wires the
historical geometry defaults into the facade and is fully N-dimensional:
pass a 3-axis ``interior``/``mesh_shape`` (or a :class:`repro.core.Domain`
via ``domain=``) and the same model runs in 3-D (docs/domains.md).  The
former ``make_engine``/``run_sim`` pairing survives only as deprecation
shims with the one-line facade equivalent in the warning text.
"""

from __future__ import annotations

import warnings
from typing import Callable, Optional, Tuple, Union

import numpy as np

from repro.core import (
    Behavior, DeltaConfig, Domain, Engine, Partition, Rebalance, Simulation,
)
from repro.core.engine import SimState, warn_if_stale_engine


def resolve_delta(delta, n_devices: int) -> Optional[DeltaConfig]:
    """Per-sim codec quality knob -> the facade's ``DeltaConfig``.

    ``None`` (default) enables the int8 delta codec exactly where a wire
    exists — multi-device meshes — and keeps single-device runs on full
    refresh (the exchange there is a local copy; quantizing it would cost
    accuracy for zero wire savings).  Shorthands: ``"int8"`` / ``"int16"``
    pick the quantized payload width; a ``"+mig"`` suffix (``"int8+mig"``)
    additionally sends emigrant positions through the int16 migration
    codec; ``"full"``/``"off"`` force raw f32 slabs every step.  A
    :class:`DeltaConfig` passes through untouched.
    """
    if delta is None:
        if n_devices <= 1:
            return None
        return DeltaConfig(enabled=True)       # int8, refresh_interval=16
    if isinstance(delta, DeltaConfig):
        return delta
    if isinstance(delta, str):
        if delta in ("off", "full"):
            return DeltaConfig(enabled=False)
        base, _, mig = delta.partition("+")
        if base in ("int8", "int16") and mig in ("", "mig"):
            import jax.numpy as jnp
            return DeltaConfig(
                enabled=True,
                qdtype=jnp.int8 if base == "int8" else jnp.int16,
                migration=jnp.int16 if mig else None)
        raise ValueError(
            f"unknown delta quality {delta!r}; expected 'int8', 'int16', "
            "'int8+mig', 'int16+mig', 'full'/'off', a DeltaConfig, or "
            "None (auto)")
    raise TypeError(
        f"delta must be a DeltaConfig, a quality string, or None; "
        f"got {type(delta).__name__}")


def make_sim(
    behaviors,
    *,
    interior: Tuple[int, ...] = (8, 8),
    mesh_shape: Tuple[int, ...] = (1, 1),
    cell_size: float = 2.0,
    cap: int = 24,
    boundary: Union[str, Tuple[str, ...]] = "closed",
    domain: Optional[Domain] = None,
    partition: Optional[Partition] = None,
    delta: Union[DeltaConfig, str, None] = None,
    dt: float = 0.1,
    mesh=None,
    rebalance: Union[Rebalance, int, None] = None,
    checkpoint=None,
    sweep_backend: str = "auto",
    overlap: str = "auto",
    check: str = "error",
    guards=None,
) -> Simulation:
    """Facade builder with the sims' historical geometry defaults.

    ``domain=`` takes a ready-made :class:`Domain` and wins over the
    individual geometry kwargs; otherwise the kwargs build one (an
    all-ones ``mesh_shape`` broadcasts to ``interior``'s dimensionality).
    ``partition=`` starts the run on an uneven box-granular ownership
    (cuts in cells): it defines its own mesh shape and padded per-device
    interior, so it overrides ``interior``/``mesh_shape``.

    ``delta=`` is the per-sim codec quality knob (:func:`resolve_delta`):
    multi-device sims default to the int8 delta-encoded aura exchange
    (paper §2.3 — positions are smooth, deltas are tiny); pass ``"int16"``
    for a higher-fidelity payload, ``"off"`` for raw f32 slabs (bit-exact
    with the single-device oracle), or a full :class:`DeltaConfig`.
    """
    if partition is not None:
        if domain is not None:
            raise ValueError("pass either domain= or partition=, not both")
        geom = Domain(
            cell_size=cell_size, interior=partition.max_widths,
            mesh_shape=partition.mesh_shape, cap=cap, boundary=boundary,
            partition=partition)
        n_devices = geom.n_devices
    else:
        geom = domain if domain is not None else dict(
            cell_size=cell_size, interior=interior, mesh_shape=mesh_shape,
            cap=cap, boundary=boundary)
        n_devices = geom.n_devices if isinstance(geom, Domain) else \
            int(np.prod(geom["mesh_shape"]))
    return Simulation(
        geom, behaviors, mesh=mesh, delta=resolve_delta(delta, n_devices),
        dt=dt, rebalance=rebalance, checkpoint=checkpoint,
        sweep_backend=sweep_backend, overlap=overlap, check=check,
        guards=guards)


def init_agents(sim, positions: np.ndarray, attrs, seed: int = 0):
    """Initialize a :class:`Simulation` facade — or, for legacy callers, a
    raw :class:`Engine` — with the same (positions, attrs) arguments."""
    if isinstance(sim, Simulation):
        return sim.init(positions, attrs, seed=seed)
    return sim.init_state(positions, attrs, seed=seed)


def uniform_positions(rng: np.random.Generator, n: int, geom: Domain,
                      margin: float = 0.5) -> np.ndarray:
    """Uniform positions over the domain interior, any dimensionality."""
    size = geom.domain_size
    lo = [margin] * geom.ndim
    hi = [s - margin for s in size]
    return rng.uniform(lo, hi, size=(n, geom.ndim)).astype(np.float32)


def disk_positions(rng: np.random.Generator, n: int, center, radius
                   ) -> np.ndarray:
    """Uniform positions inside a 2-D disk."""
    th = rng.uniform(0, 2 * np.pi, n)
    r = radius * np.sqrt(rng.uniform(0, 1, n))
    return np.stack([center[0] + r * np.cos(th),
                     center[1] + r * np.sin(th)], axis=1).astype(np.float32)


def ball_positions(rng: np.random.Generator, n: int, center, radius
                   ) -> np.ndarray:
    """Uniform positions inside a 3-D ball (the spheroid seeds)."""
    v = rng.normal(size=(n, 3))
    v /= np.maximum(np.linalg.norm(v, axis=1, keepdims=True), 1e-12)
    r = radius * np.cbrt(rng.uniform(0, 1, n))[:, None]
    return (np.asarray(center)[None, :] + v * r).astype(np.float32)


# ---------------------------------------------------------------------------
# Deprecation shims (the only callers of warn_if_stale_engine)
# ---------------------------------------------------------------------------

def make_engine(
    behavior: Behavior,
    *,
    interior: Tuple[int, ...] = (8, 8),
    mesh_shape: Tuple[int, ...] = (1, 1),
    cell_size: float = 2.0,
    cap: int = 24,
    boundary: Union[str, Tuple[str, ...]] = "closed",
    delta: Optional[DeltaConfig] = None,
    dt: float = 0.1,
    mesh=None,
    rebalance_every: int = 0,
    imbalance_threshold: float = 0.5,
) -> Engine:
    """DEPRECATED: build a raw Engine.  Use the facade instead:
    ``Simulation(dict(interior=..., mesh_shape=..., ...), behavior,
    delta=..., dt=..., rebalance=Rebalance(every=n, threshold=t))``."""
    warnings.warn(
        "make_engine is deprecated — use repro.core.Simulation("
        "dict(interior=..., mesh_shape=..., cap=...), behavior, delta=..., "
        "dt=..., rebalance=Rebalance(every=n, threshold=t)) instead",
        DeprecationWarning, stacklevel=2)
    geom = Domain(cell_size=cell_size, interior=interior,
                  mesh_shape=mesh_shape, cap=cap, boundary=boundary)
    return Engine(geom=geom, behavior=behavior,
                  delta_cfg=delta or DeltaConfig(enabled=False), dt=dt,
                  rebalance_every=rebalance_every,
                  imbalance_threshold=imbalance_threshold)


def run_sim(engine: Engine, state: SimState, steps: int, mesh=None,
            collect: Optional[Callable] = None, rebalancer=None):
    """DEPRECATED: drive a raw (engine, state) pair.  Use the facade instead:
    ``sim.run(steps, collect=...)`` — ``sim.engine``/``sim.state`` stay
    consistent across re-shards with no stale-handle contract to honor."""
    warnings.warn(
        "run_sim is deprecated — use repro.core.Simulation: "
        "sim.run(steps, collect=...); read sim.state / sim.series",
        DeprecationWarning, stacklevel=2)
    if mesh is not None:
        step = engine.make_sharded_step(mesh)
    else:
        step = engine.make_local_step()
    had_handle = rebalancer is not None
    eng, state, series = engine.drive(state, steps, step_fn=step,
                                      rebalancer=rebalancer, collect=collect)
    warn_if_stale_engine(engine, eng, had_handle)
    return state, series
