"""The paper's four benchmark simulations (§3.1) — cell clustering, cell
proliferation, epidemiology (SIR), oncology (tumor spheroid) — plus
``sir_mechanics``, a composed-behavior sim (``compose(mechanics, sir)``)
exercising the facade's behavior-stacking algebra, and ``tumor_spheroid``,
the 3-D flagship workload on the N-D Domain (proliferation + soft-sphere
mechanics + nutrient-gated growth).  Each module exposes
``simulation(...) -> repro.core.Simulation`` and a ``run(...)`` wrapper."""
