"""The paper's four benchmark simulations (§3.1): cell clustering, cell
proliferation, epidemiology (SIR), oncology (tumor spheroid)."""
