"""Tumor spheroid growth in 3-D (paper §3.1 oncology use case, now on the
N-D Domain): the flagship 3-D workload exercising the new spatial axis.

A composed behavior stack (``compose(mechanics, growth)``, docs/api.md):

* **mechanics** — soft-sphere repulsion + adhesion with overdamped
  displacement (the shared :func:`soft_repulsion_adhesion` /
  :func:`displacement_update` pair, unchanged from the 2-D sims — the pair
  math is dimension-agnostic, so the same behavior code runs in 3-D).
* **growth** — nutrient-gated proliferation: each cell carries a
  ``nutrient`` level relaxing toward the local supply, which crowding
  (the 3^3-neighborhood occupancy, an oxygen-consumption proxy) depletes.
  Cells grow only while nutrient holds above a threshold, and divide once
  past the division diameter — so the spheroid develops the classic
  rim-proliferating / core-quiescent structure without any global field.

The spheroid diameter is measured with the paper's approximate method —
the enclosing bounding box of all tumor cells (§3.4) — identical in serial
and distributed execution.  Moving this model from one device to a
``1x1x2`` (or larger) spatial mesh is a ``mesh_shape`` argument change
only: see ``examples/spheroid_3d.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, POS, Simulation, compose, total_agents
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.compile_cache import memoize
from repro.sims.common import ball_positions, init_agents, make_sim

# Spatial dimensionality of this sim's default geometry (read by
# launch.simulate to size an all-ones --mesh; 2-D sims omit it).
NDIM = 3

MECH_SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})

GROWTH_SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "nutrient": ((), jnp.float32),
})


def _crowd_pair(ai, aj, disp, dist2, params):
    """Neighbor count — the local oxygen-consumption proxy."""
    return {"crowd": jnp.ones_like(dist2)}


def _growth_update(attrs, valid, acc, key, params, dt):
    crowd = acc["crowd"]
    # nutrient relaxes toward supply and is depleted by crowding
    uptake = params["uptake"] * crowd
    nut = attrs["nutrient"] + dt * (params["supply"]
                                    * (1.0 - attrs["nutrient"]) - uptake)
    nut = jnp.clip(nut, 0.0, 1.0)
    fed = nut > params["nutrient_threshold"]
    # growth is nutrient-gated; starved cells go quiescent
    d = attrs["diameter"] + jnp.where(
        valid & fed, params["growth"] * dt, 0.0)
    divide_ready = d >= params["div_diameter"]
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, valid.shape)
    spawn = valid & fed & divide_ready & (u < params["div_prob"])
    d = jnp.where(spawn, d * 0.5, d)
    new = dict(attrs)
    new["diameter"] = d
    new["nutrient"] = nut
    # child: sibling half of the division, offset in a random 3-D direction
    off = params["div_offset"] * jax.random.normal(k2, new[POS].shape)
    child = dict(new)
    child[POS] = new[POS] + off
    child["diameter"] = jnp.where(spawn, d, jnp.float32(0.5))
    child["nutrient"] = 0.5 * nut
    return new, valid, spawn, child


@memoize("sims.tumor_spheroid.behavior", maxsize=8)
def behavior(radius=2.0, repulsion=4.0, adhesion=0.4) -> Behavior:
    """``compose(mechanics, growth)`` — union schema
    {diameter, ctype, nutrient}, both pair kernels over one 3^3 sweep."""
    mech = Behavior(
        schema=MECH_SCHEMA,
        pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"),
        update_fn=displacement_update,
        radius=radius,
        params={"repulsion": repulsion, "adhesion": adhesion,
                "same_type_only": 0.0, "max_step": 0.3},
    )
    growth = Behavior(
        schema=GROWTH_SCHEMA,
        pair_fn=_crowd_pair,
        pair_attrs=("diameter",),
        update_fn=_growth_update,
        radius=radius,
        params={"growth": 0.35, "div_diameter": 1.0, "div_prob": 0.4,
                "div_offset": 0.25, "supply": 0.6, "uptake": 0.035,
                "nutrient_threshold": 0.3},
        can_spawn=True,
    )
    return compose(mech, growth)


def init(sim, n_agents: int, seed: int = 0, center_frac=None):
    """Seed the spheroid ball.  ``center_frac`` places its center at the
    given per-axis fraction of the domain (default: the middle); an
    off-center seed is the canonical uneven-ownership demo — an equal
    split strands most devices with near-empty blocks."""
    rng = np.random.default_rng(seed)
    size = sim.geom.domain_size
    if center_frac is None:
        center_frac = (0.5,) * sim.geom.ndim
    center = tuple(s * f for s, f in zip(size, center_frac))
    pos = ball_positions(rng, n_agents, center, min(size) / 8)
    attrs = {
        "diameter": np.full((n_agents,), 0.8, np.float32),
        "ctype": np.ones((n_agents,), np.int32),
        "nutrient": np.full((n_agents,), 1.0, np.float32),
    }
    return init_agents(sim, pos, attrs, seed=seed)


def spheroid_diameter(state) -> float:
    """Paper's approximate measurement: enclosing bounding box."""
    pos = np.asarray(state.soa.attrs["pos"])
    pos = pos.reshape(-1, pos.shape[-1])
    v = np.asarray(state.soa.valid).ravel()
    pos = pos[v]
    if pos.size == 0:
        return 0.0
    ext = pos.max(axis=0) - pos.min(axis=0)
    return float(np.max(ext))


def simulation(n_agents=40, seed=0, mesh=None, mesh_shape=(1, 1, 1),
               interior=(6, 6, 6), delta=None, rebalance=None,
               sweep_backend="auto", center_frac=None,
               cap=32) -> Simulation:
    sim = make_sim(behavior(), interior=interior, mesh_shape=mesh_shape,
                   cap=cap, delta=delta, mesh=mesh, rebalance=rebalance,
                   sweep_backend=sweep_backend)
    return init(sim, n_agents, seed, center_frac=center_frac)


def run(n_agents=40, steps=15, seed=0, mesh=None, mesh_shape=(1, 1, 1),
        interior=(6, 6, 6), delta=None, rebalance=None,
        sweep_backend="auto", center_frac=None, cap=32):
    sim = simulation(n_agents=n_agents, seed=seed, mesh=mesh,
                     mesh_shape=mesh_shape, interior=interior, delta=delta,
                     rebalance=rebalance, sweep_backend=sweep_backend,
                     center_frac=center_frac, cap=cap)
    d0 = spheroid_diameter(sim.state)
    sim.run(steps, collect=lambda s: (total_agents(s), spheroid_diameter(s)))
    return sim.state, {"diam_initial": d0, "series": sim.series["collect"]}
