"""Cell clustering (paper §3.1): two cell types with same-type adhesion and
short-range repulsion self-organize into clusters — the paper's canonical
benchmark (Figure 3 shows its first three iterations)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, Simulation
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.compile_cache import memoize
from repro.sims.common import init_agents, make_sim, uniform_positions

SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


# Cached on the (hashable) parameter tuple: repeated builds return the
# *same* Behavior object, so the engine's compiled step/segment caches hit
# across Simulation instances instead of re-tracing per run.
@memoize("sims.cell_clustering.behavior", maxsize=32)
def behavior(repulsion=2.0, adhesion=0.6, radius=2.0, max_step=0.5
             ) -> Behavior:
    return Behavior(
        schema=SCHEMA,
        pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"),
        update_fn=displacement_update,
        radius=radius,
        params={"repulsion": repulsion, "adhesion": adhesion,
                "same_type_only": 1.0, "max_step": max_step},
    )


def init(sim, n_agents: int, seed: int = 0):
    """Initialize through the facade (also accepts a raw Engine)."""
    rng = np.random.default_rng(seed)
    pos = uniform_positions(rng, n_agents, sim.geom)
    attrs = {
        "diameter": np.full((n_agents,), 1.0, np.float32),
        "ctype": rng.integers(0, 2, n_agents).astype(np.int32),
    }
    return init_agents(sim, pos, attrs, seed=seed)


def _same_type_pair(ai, aj, disp, dist2, params):
    same = (ai["ctype"] == aj["ctype"]).astype(jnp.float32)
    return {"same": same, "cnt": jnp.ones_like(same)}


@partial(jax.jit, static_argnames=("geom", "radius"))
def _same_type_counts(geom, soa, radius):
    from repro.core.neighbors import sweep_accumulate

    acc = sweep_accumulate(geom, soa, _same_type_pair, ("ctype",),
                           radius, {}, backend="auto")
    return jnp.sum(acc["same"]), jnp.sum(acc["cnt"])


def same_type_fraction(state, engine) -> float:
    """Clustering metric: fraction of neighbor pairs with equal type."""
    same, cnt = _same_type_counts(engine.geom, state.soa,
                                  float(engine.behavior.radius))
    return float(same) / max(float(cnt), 1.0)


def simulation(n_agents=400, seed=0, mesh=None, mesh_shape=(1, 1),
               interior=(8, 8), delta=None, rebalance=None,
               sweep_backend="auto", **bparams) -> Simulation:
    """Build and initialize the clustering sim on the facade."""
    sim = make_sim(behavior(**bparams), interior=interior,
                   mesh_shape=mesh_shape, delta=delta, mesh=mesh,
                   rebalance=rebalance, sweep_backend=sweep_backend)
    return init(sim, n_agents, seed)


def run(n_agents=400, steps=30, seed=0, mesh=None, mesh_shape=(1, 1),
        interior=(8, 8), delta=None, rebalance=None, sweep_backend="auto"):
    sim = simulation(n_agents=n_agents, seed=seed, mesh=mesh,
                     mesh_shape=mesh_shape, interior=interior, delta=delta,
                     rebalance=rebalance, sweep_backend=sweep_backend)
    f0 = same_type_fraction(sim.state, sim.engine)
    sim.run(steps)
    f1 = same_type_fraction(sim.state, sim.engine)
    return sim.state, {"same_frac_initial": f0, "same_frac_final": f1}
