"""Oncology use case (paper §3.1, Figure 5): tumor spheroid growth.

Tumor cells proliferate under contact inhibition (division probability
decays with local crowding) and adhere, producing compact spheroid growth.
The tumor diameter is measured with the paper's approximate method — the
enclosing bounding box of all tumor cells (§3.4: "for simulations with a
larger number of agents we use ... the enclosing bounding box") — which is
identical in serial and distributed execution."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AgentSchema, Behavior, POS, Simulation, total_agents
from repro.core.behaviors import soft_repulsion_adhesion
from repro.core.compile_cache import memoize
from repro.sims.common import disk_positions, init_agents, make_sim

SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


def _update(attrs, valid, acc, key, params, dt):
    f = acc["force"]
    max_step = jnp.float32(params["max_step"])
    norm = jnp.sqrt(jnp.sum(f * f, axis=-1, keepdims=True) + 1e-12)
    step = f * jnp.minimum(max_step / norm, dt)
    new = dict(attrs)
    new[POS] = attrs[POS] + jnp.where(valid[..., None], step, 0.0)
    # contact inhibition: crowding = neighbor count
    crowd = acc["crowd"]
    p_div = params["div_prob"] * jnp.exp(-crowd / params["crowd_scale"])
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, valid.shape)
    spawn = valid & (u < p_div)
    child = dict(new)
    child[POS] = new[POS] + 0.3 * jax.random.normal(k2, new[POS].shape)
    child["diameter"] = jnp.full_like(attrs["diameter"], 0.9)
    return new, valid, spawn, child


def _pair(ai, aj, disp, dist2, params):
    out = soft_repulsion_adhesion(ai, aj, disp, dist2, params)
    out["crowd"] = jnp.ones_like(dist2)
    return out


@memoize("sims.oncology.behavior", maxsize=8)
def behavior(radius=2.0) -> Behavior:
    return Behavior(
        schema=SCHEMA,
        pair_fn=_pair,
        pair_attrs=("diameter", "ctype"),
        update_fn=_update,
        radius=radius,
        params={"repulsion": 4.0, "adhesion": 0.05, "same_type_only": 0.0,
                "max_step": 0.3, "div_prob": 0.5, "crowd_scale": 14.0},
        can_spawn=True,
    )


def init(sim, n_agents: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lx, ly = sim.geom.domain_size
    pos = disk_positions(rng, n_agents, (lx / 2, ly / 2), 1.2)
    attrs = {
        "diameter": np.full((n_agents,), 0.9, np.float32),
        "ctype": np.ones((n_agents,), np.int32),
    }
    return init_agents(sim, pos, attrs, seed=seed)


def tumor_diameter(state) -> float:
    """Paper's approximate measurement: enclosing bounding box."""
    pos = np.asarray(state.soa.attrs["pos"])
    pos = pos.reshape(-1, pos.shape[-1])
    v = np.asarray(state.soa.valid).ravel()
    pos = pos[v]
    if pos.size == 0:
        return 0.0
    ext = pos.max(axis=0) - pos.min(axis=0)
    return float(np.max(ext))


def simulation(n_agents=30, seed=0, mesh=None, mesh_shape=(1, 1),
               interior=(10, 10), delta=None, rebalance=None,
               sweep_backend="auto") -> Simulation:
    sim = make_sim(behavior(), interior=interior, mesh_shape=mesh_shape,
                   cap=32, delta=delta, mesh=mesh, rebalance=rebalance,
                   sweep_backend=sweep_backend)
    return init(sim, n_agents, seed)


def run(n_agents=30, steps=25, seed=0, mesh=None, mesh_shape=(1, 1),
        interior=(10, 10), delta=None, rebalance=None, sweep_backend="auto"):
    sim = simulation(n_agents=n_agents, seed=seed, mesh=mesh,
                     mesh_shape=mesh_shape, interior=interior, delta=delta,
                     rebalance=rebalance, sweep_backend=sweep_backend)
    d0 = tumor_diameter(sim.state)
    sim.run(steps, collect=lambda s: (total_agents(s), tumor_diameter(s)))
    return sim.state, {"diam_initial": d0, "series": sim.series["collect"]}
