"""Pallas TPU kernels for delta encoding/decoding (paper §2.3).

Encode: q = clip(round((x - ref)/scale)) -> int8 (4x wire-byte reduction for
f32 payloads); decode: x' = ref + q*scale.  The slab max-abs reduction that
produces ``scale`` is a cheap XLA reduction in the ops wrapper; the kernels
are pure elementwise VMEM tiles, blocked so encode/decode of large aura
slabs streams HBM->VMEM->HBM without intermediate f32 materialization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _encode_kernel(x_ref, ref_ref, scale_ref, q_ref, oflow_ref):
    x = x_ref[...].astype(jnp.float32)
    r = ref_ref[...].astype(jnp.float32)
    s = scale_ref[0]
    d = jnp.round((x - r) / s)
    # Count saturating elements before clipping: silent ±127 clipping is a
    # correctness hazard (the receiver reconstructs a stale value) that the
    # caller must be able to observe and react to (full-refresh fallback).
    oflow_ref[0] = jnp.sum((jnp.abs(d) > 127.0).astype(jnp.int32))
    q_ref[...] = jnp.clip(d, -127.0, 127.0).astype(jnp.int8)


def _decode_kernel(q_ref, ref_ref, scale_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    r = ref_ref[...].astype(jnp.float32)
    s = scale_ref[0]
    x_ref[...] = (r + q * s).astype(x_ref.dtype)


def _blocked(n: int, block: int) -> int:
    block = min(block, n)
    while n % block:
        block -= 1
    return block


def delta_encode_kernel(x, ref, scale, *, block: int = 1024,
                        interpret: bool = True):
    """x, ref: (N, L) f32; scale: () f32 ->
    (q (N, L) int8, overflow () int32).

    ``overflow`` counts elements whose quantized delta saturated at ±127
    (each is reconstructed with error > scale/2 on the receiver) — zero
    when the caller derives ``scale`` from max |delta|."""
    n, l = x.shape
    bn = _blocked(n, block)
    grid = n // bn
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    q, oflow = pl.pallas_call(
        _encode_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, l), jnp.int8),
            jax.ShapeDtypeStruct((grid,), jnp.int32),
        ],
        interpret=interpret,
    )(x, ref, scale_arr)
    return q, jnp.sum(oflow)


def delta_decode_kernel(q, ref, scale, *, block: int = 1024,
                        interpret: bool = True):
    """q (N, L) int8; ref (N, L) f32; scale () f32 -> x' (N, L) f32."""
    n, l = q.shape
    bn = _blocked(n, block)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    return pl.pallas_call(
        _decode_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bn, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, l), ref.dtype),
        interpret=interpret,
    )(q, ref, scale_arr)
