"""Pallas TPU kernels for delta encoding/decoding (paper §2.3).

Encode: q = clip(round((x - ref)/scale)) -> int8 (4x wire-byte reduction for
f32 payloads); decode: x' = ref + q*scale.  The slab max-abs reduction that
produces ``scale`` is a cheap XLA reduction in the ops wrapper; the kernels
are pure elementwise VMEM tiles, blocked so encode/decode of large aura
slabs streams HBM->VMEM->HBM without intermediate f32 materialization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _encode_kernel(x_ref, ref_ref, scale_ref, q_ref, oflow_ref):
    x = x_ref[...].astype(jnp.float32)
    r = ref_ref[...].astype(jnp.float32)
    s = scale_ref[0]
    d = jnp.round((x - r) / s)
    # Count saturating elements before clipping: silent ±127 clipping is a
    # correctness hazard (the receiver reconstructs a stale value) that the
    # caller must be able to observe and react to (full-refresh fallback).
    oflow_ref[0] = jnp.sum((jnp.abs(d) > 127.0).astype(jnp.int32))
    q_ref[...] = jnp.clip(d, -127.0, 127.0).astype(jnp.int8)


def _decode_kernel(q_ref, ref_ref, scale_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    r = ref_ref[...].astype(jnp.float32)
    s = scale_ref[0]
    x_ref[...] = (r + q * s).astype(x_ref.dtype)


def _blocked(n: int, block: int) -> int:
    block = min(block, n)
    while n % block:
        block -= 1
    return block


def delta_encode_kernel(x, ref, scale, *, block: int = 1024,
                        interpret: bool = True):
    """x, ref: (N, L) f32; scale: () f32 ->
    (q (N, L) int8, overflow () int32).

    ``overflow`` counts elements whose quantized delta saturated at ±127
    (each is reconstructed with error > scale/2 on the receiver) — zero
    when the caller derives ``scale`` from max |delta|."""
    n, l = x.shape
    bn = _blocked(n, block)
    grid = n // bn
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    q, oflow = pl.pallas_call(
        _encode_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, l), jnp.int8),
            jax.ShapeDtypeStruct((grid,), jnp.int32),
        ],
        interpret=interpret,
    )(x, ref, scale_arr)
    return q, jnp.sum(oflow)


def delta_decode_kernel(q, ref, scale, *, block: int = 1024,
                        interpret: bool = True):
    """q (N, L) int8; ref (N, L) f32; scale () f32 -> x' (N, L) f32."""
    n, l = q.shape
    bn = _blocked(n, block)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    return pl.pallas_call(
        _decode_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bn, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, l), ref.dtype),
        interpret=interpret,
    )(q, ref, scale_arr)


# ---------------------------------------------------------------------------
# Migration position codec (delta.encode_migration / decode_migration)
# ---------------------------------------------------------------------------
# Migration slabs have no temporal reference, so the payload is a one-shot
# fixed-point offset from the sender's box center: q = clip(round((x -
# center) / scale)) -> int16, per-axis scale.  The min-image wrap on
# toroidal axes is a cheap XLA prologue in the wrapper (same division of
# labor as the slab max-abs reduction above); the kernels stream the
# quantize/dequantize elementwise through VMEM.

_I16_MAX = 32767.0


def _mig_encode_kernel(d_ref, scale_ref, q_ref, oflow_ref):
    d = d_ref[...].astype(jnp.float32)
    q = jnp.round(d / scale_ref[...])
    # Saturation means the migrant broke the <=1 cell/step contract (the
    # range covers the padded box + slack) — count it, never hide it.
    oflow_ref[0] = jnp.sum((jnp.abs(q) > _I16_MAX).astype(jnp.int32))
    q_ref[...] = jnp.clip(q, -_I16_MAX, _I16_MAX).astype(jnp.int16)


def _mig_decode_kernel(q_ref, center_ref, scale_ref, x_ref):
    x_ref[...] = (center_ref[...] +
                  q_ref[...].astype(jnp.float32) * scale_ref[...])


def migration_pos_encode_kernel(pos, center, scale, *, valid=None,
                                lsz=None, toroidal=(),
                                block: int = 1024, interpret: bool = True):
    """pos (N, D) f32; center (D,) f32; scale (D,) f32 ->
    (q (N, D) int16, overflow () int32).

    ``valid`` (N,) bool, when given, zeroes dead rows' offsets before the
    kernel so stale coordinates neither overflow-count nor clip; toroidal
    axes are min-image wrapped with period ``lsz`` first."""
    n, d = pos.shape
    off = pos.astype(jnp.float32) - center.astype(jnp.float32)
    if any(toroidal):
        L = jnp.asarray(lsz, jnp.float32)
        off = jnp.where(jnp.asarray(toroidal),
                        off - L * jnp.round(off / L), off)
    if valid is not None:
        off = jnp.where(valid[:, None], off, 0.0)
    bn = _blocked(n, block)
    grid = n // bn
    q, oflow = pl.pallas_call(
        _mig_encode_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int16),
            jax.ShapeDtypeStruct((grid,), jnp.int32),
        ],
        interpret=interpret,
    )(off, scale.astype(jnp.float32).reshape(1, d))
    return q, jnp.sum(oflow)


def migration_pos_decode_kernel(q, center, scale, *, lsz=None, toroidal=(),
                                block: int = 1024, interpret: bool = True):
    """q (N, D) int16; center (D,) f32; scale (D,) f32 -> pos (N, D) f32,
    wrapped back into the fundamental domain on toroidal axes."""
    n, d = q.shape
    bn = _blocked(n, block)
    pos = pl.pallas_call(
        _mig_decode_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, center.astype(jnp.float32).reshape(1, d),
      scale.astype(jnp.float32).reshape(1, d))
    if any(toroidal):
        L = jnp.asarray(lsz, jnp.float32)
        pos = jnp.where(jnp.asarray(toroidal), jnp.mod(pos, L), pos)
    return pos
