"""Pallas TPU kernel for the ABM neighbor-interaction hot spot.

Computes the soft-sphere repulsion/adhesion force between each cell's K
agents and the 9K agents of its 3x3 NSG neighborhood — the compute-dominant
inner loop of all four paper benchmark simulations.

Grid: one program per block of BC cells.  Each program holds its (BC, K)
self slab and (BC, 9K) neighborhood slab in VMEM and evaluates the
(K x 9K) pair interactions with VPU-vectorized masked arithmetic.  The
neighborhood gather itself is cheap data movement and stays in XLA (the ops
wrapper builds it), keeping the kernel a pure compute tile — the same
decomposition BioDynaMo uses between its uniform grid and force calculation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params under the TPU-prefixed name.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _force_kernel(pos_i_ref, diam_i_ref, type_i_ref, valid_i_ref, gid_i_ref,
                  pos_j_ref, diam_j_ref, type_j_ref, valid_j_ref, gid_j_ref,
                  out_ref, *, radius: float, repulsion: float,
                  adhesion: float, same_type_only: bool):
    pos_i = pos_i_ref[...].astype(jnp.float32)        # (BC, K, 2)
    pos_j = pos_j_ref[...].astype(jnp.float32)        # (BC, 9K, 2)
    disp = pos_j[:, None, :, :] - pos_i[:, :, None, :]
    dist2 = jnp.sum(disp * disp, axis=-1)             # (BC, K, 9K)
    dist = jnp.sqrt(dist2 + 1e-6)
    unit = disp / dist[..., None]

    diam_i = diam_i_ref[...].astype(jnp.float32)
    diam_j = diam_j_ref[...].astype(jnp.float32)
    r_sum = 0.5 * (diam_i[:, :, None] + diam_j[:, None, :])
    overlap = r_sum - dist
    rep = jnp.where(overlap > 0, repulsion * overlap, 0.0)
    same = (type_i_ref[...][:, :, None] == type_j_ref[...][:, None, :])
    gate = same.astype(jnp.float32) if same_type_only else 1.0
    adh = jnp.where(overlap <= 0, adhesion * gate, 0.0)
    f = -(rep - adh)[..., None] * unit                # (BC, K, 9K, 2)

    mask = (valid_i_ref[...][:, :, None] & valid_j_ref[...][:, None, :]
            & (gid_i_ref[...][:, :, None] != gid_j_ref[...][:, None, :])
            & (dist2 <= radius * radius))
    out_ref[...] = jnp.sum(
        jnp.where(mask[..., None], f, 0.0), axis=2
    ).astype(out_ref.dtype)


def neighbor_force_kernel(
    pos_i, diam_i, type_i, valid_i, gid_i,     # (C, K, ...) self slabs
    pos_j, diam_j, type_j, valid_j, gid_j,     # (C, 9K, ...) neighborhood
    *, radius: float, repulsion: float, adhesion: float,
    same_type_only: bool = True, block_cells: int = 8,
    interpret: bool = True,
):
    c, k = valid_i.shape
    nk = valid_j.shape[1]
    bc = min(block_cells, c)
    assert c % bc == 0, (c, bc)
    kernel = functools.partial(
        _force_kernel, radius=radius, repulsion=repulsion,
        adhesion=adhesion, same_type_only=same_type_only)

    def spec(trailing, width):
        return pl.BlockSpec((bc, width) + trailing,
                            lambda i: (i,) + (0,) * (1 + len(trailing)))

    return pl.pallas_call(
        kernel,
        grid=(c // bc,),
        in_specs=[
            spec((2,), k), spec((), k), spec((), k), spec((), k), spec((), k),
            spec((2,), nk), spec((), nk), spec((), nk), spec((), nk),
            spec((), nk),
        ],
        out_specs=spec((2,), k),
        out_shape=jax.ShapeDtypeStruct((c, k, 2), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(pos_i, diam_i, type_i, valid_i, gid_i,
      pos_j, diam_j, type_j, valid_j, gid_j)
