"""Pallas TPU kernels for the ABM neighbor-interaction hot spot.

The compute-dominant inner loop of every paper benchmark simulation is the
pairwise sweep between each cell's K agents and the 3^D K agents of its
3^D NSG neighborhood (9K in 2-D, 27K in 3-D).  :func:`pair_sweep_kernel`
is a *kernel factory* over that decomposition: it takes an arbitrary
behavior pair kernel (the same ``pair_fn(attrs_i, attrs_j, disp, dist2,
params)`` contract the pure-jnp reference
``core.neighbors.pair_accumulate`` evaluates, including the stacks
``core.behaviors.compose`` builds) and emits one Pallas program per block
of BC cells that holds its (BC, K) self slabs and (BC, NK) neighborhood
slabs in VMEM and evaluates all pair contributions with VPU-vectorized
masked arithmetic.  The factory is dimension-agnostic: the caller flattens
its interior cell grid, so 2-D and 3-D domains differ only in the
neighborhood slab width NK and the trailing dim of ``pos`` (and of the
per-axis minimum-image ``box`` tuple).  The neighborhood gather itself is
cheap data movement and stays in XLA (the caller builds it), keeping the
kernel a pure compute tile — the same decomposition BioDynaMo uses
between its uniform grid and force calculation.

:func:`neighbor_force_kernel` — the original hardcoded soft-sphere force —
is retained as a thin wrapper over the factory for its callers and parity
tests.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params under the TPU-prefixed name.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

# Reserved column names (mirrors repro.core.agent_soa; string literals keep
# the kernels package importable without the core layer).
_POS = "pos"
_GID_RANK = "gid_rank"
_GID_COUNT = "gid_count"


def _pair_eval(attrs_i, attrs_j, valid_i, valid_j, *, pair_fn, radius,
               params, box):
    """Shared pair-block math: broadcast views, mask, masked contributions.

    attrs_i values are (..., K, t) and attrs_j values (..., NK, t); returns
    a dict of (..., K, t) accumulators summed over the NK axis.  Runs both
    inside the Pallas kernel body and under ``jax.eval_shape`` (to discover
    the accumulator specs before the ``pallas_call`` is built).
    """
    # Broadcast views: i -> (..., K, 1, t), j -> (..., 1, NK, t).  The pair
    # axes sit right after the leading block axis.
    ai = {n: jnp.expand_dims(a, 2) for n, a in attrs_i.items()}
    aj = {n: jnp.expand_dims(a, 1) for n, a in attrs_j.items()}

    disp = aj[_POS] - ai[_POS]                       # (..., K, NK, D)
    if box is not None:
        # per-component minimum image with scalar literals: a (2,) constant
        # array would be a captured constant inside the Pallas kernel body.
        # A None component marks a closed (non-wrapping) axis.
        comps = []
        for axis in range(disp.shape[-1]):
            d = disp[..., axis]
            if box[axis] is None:
                comps.append(d)
            else:
                b = jnp.float32(box[axis])
                comps.append(d - b * jnp.round(d / b))
        disp = jnp.stack(comps, axis=-1)
    dist2 = jnp.sum(disp * disp, axis=-1)            # (..., K, NK)

    same = (ai[_GID_RANK] == aj[_GID_RANK]) & (
        ai[_GID_COUNT] == aj[_GID_COUNT])
    mask = (valid_i[:, :, None] & valid_j[:, None, :] & ~same
            & (dist2 <= jnp.float32(radius * radius)))

    contribs = pair_fn(ai, aj, disp, dist2, params)
    out = {}
    for name, c in contribs.items():
        m = mask
        while m.ndim < c.ndim:
            m = m[..., None]
        out[name] = jnp.sum(jnp.where(m, c, jnp.zeros_like(c)), axis=2)
    return out


def pair_sweep_kernel(
    attrs_i: Dict[str, jax.Array],   # each (C, K, *t) — incl. pos + gid cols
    attrs_j: Dict[str, jax.Array],   # each (C, NK, *t) neighborhood slabs
    valid_i: jax.Array,              # (C, K) bool
    valid_j: jax.Array,              # (C, NK) bool
    *,
    pair_fn,
    radius: float,
    params: dict,
    box: Optional[Tuple[Optional[float], ...]] = None,  # per-axis minimum-
    # image box lengths; a None component marks a closed axis
    block_cells: int = 8,
    interpret: bool = True,
) -> Dict[str, jax.Array]:
    """Evaluate ``pair_fn`` for every (i, j) pair of each cell block and
    return the per-agent accumulator sums, as a dict of (C, K, *t) arrays.

    The accumulator names/shapes/dtypes are discovered with ``eval_shape``
    (no FLOPs) so arbitrary multi-output behaviors — including composed
    stacks with namespaced accumulators — run in one kernel launch.
    """
    c, k = valid_i.shape
    nk = valid_j.shape[1]
    names = tuple(sorted(attrs_i))
    for need in (_POS, _GID_RANK, _GID_COUNT):
        if need not in attrs_i or need not in attrs_j:
            raise ValueError(f"pair_sweep_kernel needs the {need!r} column")

    # Discover accumulator specs from the abstract pair evaluation.
    out_abs = jax.eval_shape(
        functools.partial(_pair_eval, pair_fn=pair_fn, radius=radius,
                          params=params, box=box),
        {n: jax.ShapeDtypeStruct(a.shape, a.dtype)
         for n, a in attrs_i.items()},
        {n: jax.ShapeDtypeStruct(a.shape, a.dtype)
         for n, a in attrs_j.items()},
        jax.ShapeDtypeStruct(valid_i.shape, valid_i.dtype),
        jax.ShapeDtypeStruct(valid_j.shape, valid_j.dtype),
    )
    out_names = tuple(sorted(out_abs))

    bc = min(block_cells, c)
    pad = (-c) % bc
    if pad:
        def padc(a):
            return jnp.concatenate(
                [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)
        attrs_i = {n: padc(a) for n, a in attrs_i.items()}
        attrs_j = {n: padc(a) for n, a in attrs_j.items()}
        valid_i = padc(valid_i)
        valid_j = padc(valid_j)
    cp = c + pad

    n_in = len(names)

    def kernel(*refs):
        in_refs, out_refs = refs[:2 * n_in + 2], refs[2 * n_in + 2:]
        ai = {n: in_refs[idx][...] for idx, n in enumerate(names)}
        aj = {n: in_refs[n_in + idx][...] for idx, n in enumerate(names)}
        vi = in_refs[2 * n_in][...]
        vj = in_refs[2 * n_in + 1][...]
        acc = _pair_eval(ai, aj, vi, vj, pair_fn=pair_fn, radius=radius,
                         params=params, box=box)
        for ref, name in zip(out_refs, out_names):
            ref[...] = acc[name].astype(ref.dtype)

    def spec(width, trailing):
        return pl.BlockSpec((bc, width) + trailing,
                            lambda i: (i,) + (0,) * (1 + len(trailing)))

    in_specs = (
        [spec(k, attrs_i[n].shape[2:]) for n in names]
        + [spec(nk, attrs_j[n].shape[2:]) for n in names]
        + [spec(k, ()), spec(nk, ())]
    )
    out_specs = [spec(k, out_abs[n].shape[2:]) for n in out_names]
    out_shape = [jax.ShapeDtypeStruct((cp, k) + out_abs[n].shape[2:],
                                      out_abs[n].dtype) for n in out_names]

    outs = pl.pallas_call(
        kernel,
        grid=(cp // bc,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*([attrs_i[n] for n in names] + [attrs_j[n] for n in names]
        + [valid_i, valid_j]))

    return {n: (o[:c] if pad else o) for n, o in zip(out_names, outs)}


def _soft_sphere_pair(attrs_i, attrs_j, disp, dist2, params):
    """The original hardcoded force law, expressed as a behavior pair_fn:
    soft-sphere repulsion + (optionally same-type-gated) adhesion."""
    dist = jnp.sqrt(dist2 + 1e-6)
    unit = disp / dist[..., None]
    r_sum = 0.5 * (attrs_i["diameter"] + attrs_j["diameter"])
    overlap = r_sum - dist
    rep = jnp.where(overlap > 0, params["repulsion"] * overlap, 0.0)
    same = (attrs_i["ctype"] == attrs_j["ctype"]).astype(jnp.float32)
    gate = same if params["same_type_only"] else 1.0
    adh = jnp.where(overlap <= 0, params["adhesion"] * gate, 0.0)
    return {"force": -(rep - adh)[..., None] * unit}


def neighbor_force_kernel(
    pos_i, diam_i, type_i, valid_i, gid_i,     # (C, K, ...) self slabs
    pos_j, diam_j, type_j, valid_j, gid_j,     # (C, 9K, ...) neighborhood
    *, radius: float, repulsion: float, adhesion: float,
    same_type_only: bool = True, block_cells: int = 8,
    interpret: bool = True,
):
    """Soft-sphere force sweep (legacy single-law entry point), now one
    instantiation of :func:`pair_sweep_kernel`.  The single ``gid`` column
    maps onto the generic <rank, counter> self-pair exclusion with rank 0."""
    def cols(pos, diam, ctype, gid):
        return {
            _POS: pos, "diameter": diam, "ctype": ctype,
            _GID_RANK: jnp.zeros_like(gid), _GID_COUNT: gid,
        }

    acc = pair_sweep_kernel(
        cols(pos_i, diam_i, type_i, gid_i),
        cols(pos_j, diam_j, type_j, gid_j),
        valid_i, valid_j,
        pair_fn=_soft_sphere_pair, radius=radius,
        params={"repulsion": repulsion, "adhesion": adhesion,
                "same_type_only": bool(same_type_only)},
        block_cells=block_cells, interpret=interpret)
    return acc["force"]
