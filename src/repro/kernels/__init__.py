"""Pallas TPU kernels for the perf-critical compute layers.

  flash_attention      — blocked attention (LM stack hot spot)
  neighbor_interaction — cell-list pairwise force pass (ABM hot spot)
  delta_codec          — delta encode/decode (paper §2.3)

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
interpret=True on CPU, Mosaic on real TPU (ops.INTERPRET = False).
EXAMPLE.md documents the pattern.
"""
