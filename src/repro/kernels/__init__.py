"""Pallas TPU kernels for the perf-critical compute layers.

  flash_attention      — blocked attention (LM stack hot spot)
  neighbor_interaction — cell-list pairwise force pass (ABM hot spot)
  delta_codec          — delta encode/decode (paper §2.3)

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py.
The Pallas interpreter is auto-selected off-TPU (``ops.use_interpret``);
set ``ops.INTERPRET`` to a bool to force either mode.  EXAMPLE.md documents
the pattern.
"""
