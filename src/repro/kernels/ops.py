"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (CPU container; kernels execute via the
Pallas interpreter).  On real TPU runtimes set
``repro.kernels.ops.INTERPRET = False`` (or pass interpret=False) and the
same kernels compile to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import delta_codec, flash_attention, neighbor_interaction

INTERPRET = True


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention_bhsd(q, k, v, *, causal=True, bq=128, bk=128):
    """q (B, H, Sq, hd); k/v (B, Hkv, Skv, hd).  GQA handled by repeating KV
    head groups (documented VMEM trade-off vs. grouped kernel)."""
    b, h, sq, hd = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * h, k.shape[2], hd)
    vf = v.reshape(b * h, v.shape[2], v.shape[3])
    out = flash_attention.flash_attention_kernel(
        qf, kf, vf, causal=causal, bq=bq, bk=bk, interpret=INTERPRET)
    return out.reshape(b, h, sq, v.shape[3])


@functools.partial(jax.jit, static_argnames=("radius", "repulsion",
                                             "adhesion", "same_type_only"))
def neighbor_force(pos_i, diam_i, type_i, valid_i, gid_i,
                   pos_j, diam_j, type_j, valid_j, gid_j,
                   *, radius, repulsion, adhesion, same_type_only=True):
    return neighbor_interaction.neighbor_force_kernel(
        pos_i, diam_i, type_i, valid_i, gid_i,
        pos_j, diam_j, type_j, valid_j, gid_j,
        radius=radius, repulsion=repulsion, adhesion=adhesion,
        same_type_only=same_type_only, interpret=INTERPRET)


@jax.jit
def delta_encode(x, ref):
    """(N, L) f32 slab -> (q int8, scale f32)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x - ref)), 1e-30) / 127.0
    q = delta_codec.delta_encode_kernel(x, ref, scale, interpret=INTERPRET)
    return q, scale


@jax.jit
def delta_decode(q, ref, scale):
    return delta_codec.delta_decode_kernel(q, ref, scale, interpret=INTERPRET)
