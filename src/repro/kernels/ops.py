"""jit'd public wrappers around the Pallas kernels.

Interpreter selection is automatic: kernels run through the Pallas
interpreter on non-TPU backends (the CPU container) and compile to Mosaic
on real TPU runtimes, keyed off ``jax.default_backend()``.  Both overrides
survive: set ``repro.kernels.ops.INTERPRET`` to a bool to force the choice
process-wide, or pass ``interpret=...`` to the wrappers that expose it.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import delta_codec, flash_attention, neighbor_interaction

# None = auto-detect (interpret everywhere except on TPU); True/False force.
INTERPRET: Optional[bool] = None


def use_interpret(override: Optional[bool] = None) -> bool:
    """Resolve the effective Pallas ``interpret`` flag: an explicit call-site
    override wins, then the module-level ``INTERPRET`` force, then backend
    auto-detection (compiled on TPU, interpreted elsewhere)."""
    if override is not None:
        return bool(override)
    if INTERPRET is not None:
        return bool(INTERPRET)
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention_bhsd(q, k, v, *, causal=True, bq=128, bk=128):
    """q (B, H, Sq, hd); k/v (B, Hkv, Skv, hd).  GQA handled by repeating KV
    head groups (documented VMEM trade-off vs. grouped kernel)."""
    b, h, sq, hd = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * h, k.shape[2], hd)
    vf = v.reshape(b * h, v.shape[2], v.shape[3])
    out = flash_attention.flash_attention_kernel(
        qf, kf, vf, causal=causal, bq=bq, bk=bk, interpret=use_interpret())
    return out.reshape(b, h, sq, v.shape[3])


@functools.partial(jax.jit, static_argnames=("radius", "repulsion",
                                             "adhesion", "same_type_only"))
def neighbor_force(pos_i, diam_i, type_i, valid_i, gid_i,
                   pos_j, diam_j, type_j, valid_j, gid_j,
                   *, radius, repulsion, adhesion, same_type_only=True):
    return neighbor_interaction.neighbor_force_kernel(
        pos_i, diam_i, type_i, valid_i, gid_i,
        pos_j, diam_j, type_j, valid_j, gid_j,
        radius=radius, repulsion=repulsion, adhesion=adhesion,
        same_type_only=same_type_only, interpret=use_interpret())


def neighborhood_pair_sweep(
    attrs_i: Dict[str, jax.Array],
    attrs_j: Dict[str, jax.Array],
    valid_i: jax.Array,
    valid_j: jax.Array,
    *,
    pair_fn,
    radius: float,
    params: dict,
    box: Optional[Tuple[Optional[float], ...]] = None,
    block_cells: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Dict[str, jax.Array]:
    """Generic fused neighborhood sweep (kernel factory entry point used by
    ``core.neighbors.pair_accumulate_pallas``).  Not jit-wrapped: behaviors'
    ``pair_fn``/``params`` are arbitrary Python, so callers trace this
    inside their own jit (the engine step does)."""
    c = valid_i.shape[0]
    bc = block_cells if block_cells is not None else min(8, max(c, 1))
    return neighbor_interaction.pair_sweep_kernel(
        attrs_i, attrs_j, valid_i, valid_j,
        pair_fn=pair_fn, radius=radius, params=params, box=box,
        block_cells=bc, interpret=use_interpret(interpret))


@jax.jit
def delta_encode(x, ref):
    """(N, L) f32 slab -> (q int8, scale f32).  The adaptive scale is
    derived from max |delta|, so quantization never saturates (the
    kernel's overflow count is identically zero and discarded here)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x - ref)), 1e-30) / 127.0
    q, _ = delta_codec.delta_encode_kernel(x, ref, scale,
                                           interpret=use_interpret())
    return q, scale


@jax.jit
def delta_encode_fixed(x, ref, scale):
    """(N, L) f32 slab at a caller-fixed scale -> (q int8, overflow int32).

    A fixed scale drops the per-slab f32 from the wire but can clip:
    ``overflow`` counts elements that saturated at ±127 so the caller can
    fall back to a full refresh (see docs/contracts.md, codec-headroom)."""
    q, oflow = delta_codec.delta_encode_kernel(x, ref, scale,
                                               interpret=use_interpret())
    return q, oflow


@jax.jit
def delta_decode(q, ref, scale):
    return delta_codec.delta_decode_kernel(q, ref, scale,
                                           interpret=use_interpret())
