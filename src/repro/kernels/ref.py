"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """q (BH, Sq, hd), k (BH, Skv, hd), v (BH, Skv, hdv) -> (BH, Sq, hdv)."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def neighbor_force_ref(pos_i, diam_i, type_i, valid_i,
                       pos_j, diam_j, type_j, valid_j,
                       gid_i, gid_j, *, radius, repulsion, adhesion,
                       same_type_only=True):
    """Per-cell pairwise mechanical force (the ABM hot spot).

    i: (C, K, ...) own agents; j: (C, 9K, ...) neighborhood agents.
    Returns force (C, K, 2).  Matches core.behaviors.soft_repulsion_adhesion
    + core.neighbors masking semantics.
    """
    disp = pos_j[:, None, :, :] - pos_i[:, :, None, :]       # (C,K,9K,2)
    dist2 = jnp.sum(disp * disp, axis=-1)
    eps = jnp.float32(1e-6)
    dist = jnp.sqrt(dist2 + eps)
    unit = disp / dist[..., None]
    r_sum = 0.5 * (diam_i[:, :, None] + diam_j[:, None, :])
    overlap = r_sum - dist
    rep = jnp.where(overlap > 0, repulsion * overlap, 0.0)
    same = (type_i[:, :, None] == type_j[:, None, :]).astype(jnp.float32)
    gate = same if same_type_only else jnp.ones_like(same)
    adh = jnp.where(overlap <= 0, adhesion * gate, 0.0)
    f = -(rep - adh)[..., None] * unit
    mask = (valid_i[:, :, None] & valid_j[:, None, :]
            & (gid_i[:, :, None] != gid_j[:, None, :])
            & (dist2 <= radius * radius))
    return jnp.sum(jnp.where(mask[..., None], f, 0.0), axis=2)


def delta_encode_ref(x, ref, scale):
    """int8 quantized delta: q = clip(round((x - ref)/scale))."""
    q = jnp.clip(jnp.round((x - ref) / scale), -127, 127).astype(jnp.int8)
    return q


def delta_decode_ref(q, ref, scale):
    return ref + q.astype(jnp.float32) * scale
