"""Blocked (flash) attention Pallas TPU kernel.

Grid: (batch*heads, Sq/BQ, Skv/BK) with the KV axis ``arbitrary`` (sequential)
so the online-softmax state (m, l, acc) lives in VMEM scratch across KV
steps.  Block shapes are MXU-aligned (BQ, BK multiples of 128; head_dim is
the lane dimension).  Validated against ref.py in interpret mode; on TPU the
same kernel compiles to the systolic pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params under the TPU-prefixed name.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, causal: bool, bq: int, bk: int, scale: float,
                  n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # whole block strictly above the diagonal -> skip
        run = (kj * bk) <= (qi * bq + bq - 1)

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
        v = v_ref[0].astype(jnp.float32)                  # (BK, hdv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (BQ, BK)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            k_pos = kj * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,    # (BH, Sq, hd)
    k: jax.Array,    # (BH, Skv, hd)
    v: jax.Array,    # (BH, Skv, hdv)
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, hd = q.shape
    _, skv, hdv = v.shape
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    scale = hd ** -0.5 if scale is None else scale
    n_kv = skv // bk

    grid = (bh, sq // bq, n_kv)
    kernel = functools.partial(
        _flash_kernel, causal=causal, bq=bq, bk=bk, scale=scale,
        n_kv_blocks=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hdv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hdv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hdv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hdv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
