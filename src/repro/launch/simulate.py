"""ABM simulation launcher — the TeraAgent-analogue entry point.

    PYTHONPATH=src python -m repro.launch.simulate --sim epidemiology \
        --agents 800 --steps 50 --mesh 2x2 --delta int16 --rebalance 10

Every sim runs through the :class:`repro.core.Simulation` facade: spatial
meshes map devices to the partitioning grid exactly as the paper maps MPI
ranks (Figure 1); ``--delta`` enables the §2.3 delta-encoded aura exchange;
``--rebalance`` arms the §2.4.5 dynamic load balancer (the facade keeps its
engine/state consistent across any mid-run re-shard).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import DeltaConfig, Rebalance, total_agents
from repro.launch.mesh import make_abm_mesh

SIMS = ["cell_clustering", "cell_proliferation", "epidemiology",
        "oncology", "sir_mechanics", "tumor_spheroid"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", required=True, choices=SIMS)
    ap.add_argument("--agents", type=int, default=400)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--mesh", default="1x1",
                    help="spatial device mesh, e.g. 2x2 (2-D) or 1x1x2 "
                         "(3-D); the axis count sets the Domain's ndim")
    ap.add_argument("--delta", default="off",
                    choices=["off", "int8", "int16"])
    ap.add_argument("--interior", type=int, default=16,
                    help="global NSG cells per axis")
    ap.add_argument("--rebalance", type=int, default=0, metavar="N",
                    help="check occupancy imbalance every N iterations "
                         "and re-shard past --imbalance")
    ap.add_argument("--imbalance", type=float, default=0.5,
                    help="re-shard threshold for --rebalance")
    ap.add_argument("--weighted", action="store_true",
                    help="weight the rebalance histogram by measured "
                         "per-device step times")
    ap.add_argument("--ownership", default="equal",
                    choices=["equal", "rcb"],
                    help="what a triggered re-shard may realize: equal-"
                         "split meshes, or box-granular uneven RCB "
                         "partitions on padded per-device grids "
                         "(docs/load_balancing.md)")
    ap.add_argument("--sweep-backend", default="auto",
                    choices=["auto", "reference", "tiled", "pallas"],
                    help="neighbor-interaction sweep implementation "
                         "(docs/performance.md); auto = tiled on CPU/GPU, "
                         "pallas on TPU")
    args = ap.parse_args()

    import importlib

    mod = importlib.import_module(f"repro.sims.{args.sim}")
    # a sim declares its dimensionality via a module-level NDIM (3-D sims
    # only; 2-D is the default); an all-ones --mesh broadcasts to it so
    # the single-device default works for any sim, and a real mesh must
    # match the sim's axis count
    sim_ndim = getattr(mod, "NDIM", 2)
    mesh_shape = tuple(int(v) for v in args.mesh.split("x"))
    if len(mesh_shape) != sim_ndim:
        if all(m == 1 for m in mesh_shape):
            mesh_shape = (1,) * sim_ndim
        else:
            ap.error(f"--mesh {args.mesh} has {len(mesh_shape)} axes but "
                     f"{args.sim} is {sim_ndim}-D")
    n_dev = 1
    for m in mesh_shape:
        n_dev *= m
    mesh = None
    if n_dev > 1:
        assert len(jax.devices()) >= n_dev, (
            f"need {n_dev} devices (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_dev})")
        mesh = make_abm_mesh(mesh_shape)
    delta = None
    if args.delta != "off":
        delta = DeltaConfig(enabled=True, qdtype=jnp.dtype(args.delta),
                            refresh_interval=16)
    rebalance = None
    if args.rebalance > 0:
        rebalance = Rebalance(every=args.rebalance,
                              threshold=args.imbalance,
                              weighted=args.weighted,
                              ownership=args.ownership)
    elif args.ownership != "equal":
        ap.error("--ownership rcb needs --rebalance N (the re-shard "
                 "runtime is what realizes uneven partitions)")

    interior = tuple(args.interior // m for m in mesh_shape)
    t0 = time.time()
    state, metrics = mod.run(
        n_agents=args.agents, steps=args.steps, mesh=mesh,
        mesh_shape=mesh_shape, interior=interior, delta=delta,
        rebalance=rebalance, sweep_backend=args.sweep_backend)
    dt = time.time() - t0
    n = total_agents(state)
    print(f"sim={args.sim} devices={n_dev} agents={n} steps={args.steps} "
          f"wall={dt:.2f}s ({n*args.steps/dt:.0f} agent_updates/s)")
    print(f"aura bytes/iter={int(state.halo_bytes.ravel()[0])} "
          f"dropped={int(state.dropped.sum())}")
    for k, v in metrics.items():
        if not hasattr(v, "__len__") or len(str(v)) < 120:
            print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
