"""ABM simulation launcher — the TeraAgent-analogue entry point.

    PYTHONPATH=src python -m repro.launch.simulate --sim epidemiology \
        --agents 800 --steps 50 --mesh 2x2 --delta int16

Spatial meshes map devices to the partitioning grid exactly as the paper
maps MPI ranks (Figure 1); ``--delta`` enables the §2.3 delta-encoded aura
exchange.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import DeltaConfig
from repro.core.engine import total_agents
from repro.launch.mesh import make_abm_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", required=True,
                    choices=["cell_clustering", "cell_proliferation",
                             "epidemiology", "oncology"])
    ap.add_argument("--agents", type=int, default=400)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--mesh", default="1x1", help="e.g. 2x2 (spatial)")
    ap.add_argument("--delta", default="off",
                    choices=["off", "int8", "int16"])
    ap.add_argument("--interior", type=int, default=16,
                    help="global NSG cells per axis")
    args = ap.parse_args()

    import importlib

    mod = importlib.import_module(f"repro.sims.{args.sim}")
    mx, my = (int(v) for v in args.mesh.split("x"))
    mesh = None
    if mx * my > 1:
        assert len(jax.devices()) >= mx * my, (
            f"need {mx*my} devices (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={mx*my})")
        mesh = make_abm_mesh((mx, my))
    delta = None
    if args.delta != "off":
        delta = DeltaConfig(enabled=True, qdtype=jnp.dtype(args.delta),
                            refresh_interval=16)

    interior = (args.interior // mx, args.interior // my)
    t0 = time.time()
    state, metrics = mod.run(
        n_agents=args.agents, steps=args.steps, mesh=mesh,
        mesh_shape=(mx, my), interior=interior, delta=delta)
    dt = time.time() - t0
    n = total_agents(state)
    print(f"sim={args.sim} devices={mx*my} agents={n} steps={args.steps} "
          f"wall={dt:.2f}s ({n*args.steps/dt:.0f} agent_updates/s)")
    print(f"aura bytes/iter={int(state.halo_bytes[0,0])} "
          f"dropped={int(state.dropped.sum())}")
    for k, v in metrics.items():
        if not hasattr(v, "__len__") or len(str(v)) < 120:
            print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
