"""Scenario server: simulation-as-a-service over the vmapped ensemble.

The batching loop that serves an LM (``examples/serve_lm.py``: collect
requests, batch compatible ones, run one compiled step, stream tokens
back) applies verbatim to simulations — the "token" is a per-step metric
frame and the "model" is a compiled ensemble runner.  This module is that
loop for agent-based scenarios:

* clients :meth:`~ScenarioServer.submit` scenario requests — a *family*
  name, a parameter point, a step budget, and a streaming cadence;
* the server groups queued requests of one compatibility family into an
  ensemble **slot** (up to ``slot_size`` lanes, partial slots padded with
  inert no-op replicas so one executable covers every fill level);
* each batch runs through the family's cached vmapped runner
  (:mod:`repro.core.ensemble`) in segment-sized dispatches whose
  boundaries are the union of every member's streaming points, so a
  request streams its frames while batch-mates with different budgets
  ride the same dispatches;
* per-request metric frames come from per-replica reducers
  (``operations.batch_*``) — lane ``r``'s frame is untouched by its
  batch neighbors;
* incompatible requests — unknown family, unknown parameter, or a family
  whose :func:`repro.analysis.check_ensemble` contract fails — are
  **rejected at submit time with the diagnostics**, never with a trace
  error mid-batch;
* :meth:`~ScenarioServer.stats` reports queue depth, batch occupancy,
  and the hit/miss counters of every compile cache
  (:mod:`repro.core.compile_cache`).

The server is deliberately in-process and synchronous — ``pump()`` runs
one batch, ``drain()`` runs until the queue is empty — so it embeds in a
CI smoke, a notebook, or a thread behind any transport.  ``--smoke``
exercises the whole loop: three compatible requests batched into one
padded slot plus one incompatible request rejected with its diagnostic.

    PYTHONPATH=src python -m repro.launch.serve --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.analysis import Diagnostic, check_ensemble
from repro.core import operations
from repro.core.compile_cache import cache_stats
from repro.core.ensemble import Ensemble


# ---------------------------------------------------------------------------
# Families, requests, results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioFamily:
    """One servable compatibility family.

    ``init_point(ensemble, seed)`` builds the solo :class:`SimState` of a
    single request (structure — agent count, schema, geometry — is fixed
    per family; only the parameter point and seed vary).  ``metric``
    reduces a *stacked* state to per-replica frames, ``(R, ...)``: lane
    ``r``'s row is request ``r``'s frame.
    """

    name: str
    ensemble: Ensemble
    init_point: Callable[[Ensemble, int], Any]
    metric: Callable[[Any], np.ndarray]
    defaults: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ScenarioRequest:
    family: str
    params: Dict[str, float]
    steps: int
    stream_every: int = 0        # 0: final frame only
    seed: int = 0


@dataclasses.dataclass
class RequestHandle:
    """Server-side record of one request's life."""

    rid: int
    request: ScenarioRequest
    status: str = "queued"       # queued | running | done | rejected
    frames: List[Any] = dataclasses.field(default_factory=list)
    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency_s(self) -> float:
        if self.finished_at <= 0:
            return 0.0
        return self.finished_at - self.submitted_at


def sir_mechanics_family(n_agents: int = 400, initial_infected: int = 20,
                         interior=(8, 8), mesh_shape=(1, 1),
                         name: str = "sir_mechanics") -> ScenarioFamily:
    """The shipped SIR-with-mechanics family: sweeps infection and
    mechanics knobs, streams per-replica S/I/R compartment counts."""
    from repro.sims import sir_mechanics as sm

    ens = sm.ensemble_family(interior=interior, mesh_shape=mesh_shape)
    return ScenarioFamily(
        name=name, ensemble=ens,
        init_point=lambda e, seed: sm.ensemble_point_state(
            e, seed=seed, n_agents=n_agents,
            initial_infected=initial_infected),
        metric=operations.batch_attr_counts("state", (sm.S, sm.I, sm.R)),
        defaults=sm.ensemble_defaults())


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------

class ScenarioServer:
    """Batching scenario server over registered ensemble families."""

    def __init__(self, families: Sequence[ScenarioFamily] = (),
                 slot_size: int = 8, mesh=None):
        if slot_size < 1:
            raise ValueError(f"slot_size must be >= 1, got {slot_size}")
        self.slot_size = int(slot_size)
        self.mesh = mesh
        self._families: Dict[str, ScenarioFamily] = {}
        self._admission: Dict[str, List[Diagnostic]] = {}
        self._queues: Dict[str, deque] = {}
        self._handles: Dict[int, RequestHandle] = {}
        self._next_rid = 0
        self._batches = 0
        self._occupancy_sum = 0.0
        for f in families:
            self.register(f)

    # -- registration / admission -------------------------------------

    def register(self, family: ScenarioFamily) -> List[Diagnostic]:
        """Register a family; its batch-safety contract
        (:func:`check_ensemble`) runs ONCE here and gates every later
        submit.  Returns the findings (errors make the family
        unservable, not unregistered — submits get the diagnostics)."""
        if family.name in self._families:
            raise ValueError(f"family {family.name!r} already registered")
        diags = check_ensemble(family.ensemble)
        self._families[family.name] = family
        self._admission[family.name] = diags
        self._queues[family.name] = deque()
        return diags

    def admission_report(self, name: str) -> List[Diagnostic]:
        return list(self._admission.get(name, ()))

    # -- submission ----------------------------------------------------

    def submit(self, request: ScenarioRequest) -> int:
        """Queue a request; returns its rid.  Incompatible requests are
        rejected immediately — ``handle(rid).status == "rejected"`` with
        the diagnostics attached — so a bad request can never poison the
        batch it would have joined."""
        rid = self._next_rid
        self._next_rid += 1
        h = RequestHandle(rid=rid, request=request,
                          submitted_at=time.monotonic())
        self._handles[rid] = h

        fam = self._families.get(request.family)
        if fam is None:
            h.status = "rejected"
            h.diagnostics = [Diagnostic(
                severity="error", contract="serve-unknown-family",
                message=f"no registered family {request.family!r}",
                hint=f"registered: {sorted(self._families)}")]
            h.finished_at = time.monotonic()
            return rid
        errors = [d for d in self._admission[request.family]
                  if d.severity == "error"]
        if errors:
            h.status = "rejected"
            h.diagnostics = errors
            h.finished_at = time.monotonic()
            return rid
        known = set(fam.ensemble.param_names) | {"seed"}
        unknown = set(request.params) - known
        if unknown:
            h.status = "rejected"
            h.diagnostics = [Diagnostic(
                severity="error", contract="serve-unknown-param",
                message=f"unknown parameter(s) {sorted(unknown)} for "
                        f"family {request.family!r}",
                hint=f"family sweeps {list(fam.ensemble.param_names)}")]
            h.finished_at = time.monotonic()
            return rid
        if request.steps < 1:
            h.status = "rejected"
            h.diagnostics = [Diagnostic(
                severity="error", contract="serve-bad-request",
                message=f"steps must be >= 1, got {request.steps}")]
            h.finished_at = time.monotonic()
            return rid
        self._queues[request.family].append(rid)
        return rid

    def handle(self, rid: int) -> RequestHandle:
        return self._handles[rid]

    # -- batching loop -------------------------------------------------

    def queue_depth(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def pump(self) -> int:
        """Run ONE batch: pop up to ``slot_size`` queued requests of the
        family with the deepest queue, pad the slot, and run it to
        completion (streaming frames at every member's cadence).
        Returns the number of requests completed (0 if idle)."""
        name = max((n for n, q in self._queues.items() if q),
                   key=lambda n: len(self._queues[n]), default=None)
        if name is None:
            return 0
        fam = self._families[name]
        q = self._queues[name]
        rids = [q.popleft() for _ in range(min(self.slot_size, len(q)))]
        handles = [self._handles[r] for r in rids]
        for h in handles:
            h.status = "running"

        ens = fam.ensemble
        points, states = [], []
        for h in handles:
            p = {**fam.defaults, **h.request.params}
            seed = int(p.pop("seed", h.request.seed))
            points.append({k: p[k] for k in ens.param_names})
            states.append(fam.init_point(ens, seed))
        estate = ens.init(states, points)
        estate = ens.pad_to(estate, self.slot_size)
        self._batches += 1
        self._occupancy_sum += len(handles) / self.slot_size

        # Segment boundaries: the union of every member's streaming
        # points and completion steps — each member reads its frames at
        # its own cadence out of the shared dispatches.
        marks = set()
        for h in handles:
            r = h.request
            if r.stream_every > 0:
                marks.update(range(r.stream_every, r.steps,
                                   r.stream_every))
            marks.add(r.steps)
        horizon = max(h.request.steps for h in handles)

        done = 0
        for mark in sorted(marks):
            estate, _ = ens.run(estate, mark - done, mesh=self.mesh)
            done = mark
            frame = fam.metric(estate.state)
            for lane, h in enumerate(handles):
                r = h.request
                due = (r.stream_every > 0 and done <= r.steps
                       and done % r.stream_every == 0)
                if due or done == r.steps:
                    h.frames.append((done, np.asarray(frame[lane])))
                if done == r.steps:
                    h.status = "done"
                    h.finished_at = time.monotonic()
        assert done == horizon
        return len(handles)

    def drain(self) -> int:
        """Pump until every queue is empty; returns requests completed."""
        total = 0
        while self.queue_depth():
            total += self.pump()
        return total

    # -- telemetry -----------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        states = [h.status for h in self._handles.values()]
        return {
            "queue_depth": self.queue_depth(),
            "queues": {n: len(q) for n, q in self._queues.items()},
            "slot_size": self.slot_size,
            "batches": self._batches,
            "mean_occupancy": (self._occupancy_sum / self._batches
                               if self._batches else 0.0),
            "requests": {s: states.count(s)
                         for s in ("queued", "running", "done",
                                   "rejected")},
            "caches": cache_stats(),
        }


# ---------------------------------------------------------------------------
# Smoke (the CI serve step)
# ---------------------------------------------------------------------------

def _smoke() -> int:
    server = ScenarioServer([sir_mechanics_family(n_agents=200)],
                            slot_size=4)

    # A family that cannot batch: its factory concretizes a parameter.
    from repro.core import Domain
    from repro.core.ensemble import Ensemble as _Ens
    from repro.sims import cell_clustering as cc

    def bad_factory(params):
        return dataclasses.replace(cc.behavior(),
                                   radius=float(params["radius"]))

    server.register(ScenarioFamily(
        name="bad_radius_sweep",
        ensemble=_Ens(geom=Domain(cell_size=2.0, interior=(8, 8),
                                  mesh_shape=(1, 1), cap=24,
                                  boundary="toroidal"),
                      behavior_fn=bad_factory, param_names=("radius",),
                      family="bad_radius_sweep"),
        init_point=lambda e, seed: None,
        metric=lambda s: np.zeros((1, 1))))

    rids = [server.submit(ScenarioRequest(
                family="sir_mechanics", params={"beta": b}, steps=12,
                stream_every=4, seed=i))
            for i, b in enumerate((0.02, 0.05, 0.08))]
    bad = server.submit(ScenarioRequest(
        family="bad_radius_sweep", params={"radius": 1.0}, steps=4))

    bad_h = server.handle(bad)
    assert bad_h.status == "rejected", bad_h.status
    assert any(d.contract == "ensemble-factory-static"
               for d in bad_h.diagnostics), bad_h.diagnostics
    print("rejected incompatible request with diagnostic:")
    print("  " + bad_h.diagnostics[0].format().splitlines()[0])

    server.drain()
    for rid in rids:
        h = server.handle(rid)
        assert h.status == "done", (rid, h.status)
        steps = [s for s, _ in h.frames]
        assert steps == [4, 8, 12], steps
        for _, f in h.frames:
            assert f.shape == (3,) and int(f.sum()) == 200, f
        print(f"  req {rid} beta={h.request.params['beta']}: "
              + " ".join(f"t={s}:{list(map(int, f))}"
                         for s, f in h.frames))

    st = server.stats()
    assert st["requests"]["done"] == 3 and st["requests"]["rejected"] == 1
    assert st["batches"] == 1 and st["mean_occupancy"] == 0.75
    assert st["caches"]["ensemble.runner"]["misses"] >= 1
    print(f"serve smoke OK: {st['batches']} batch at occupancy "
          f"{st['mean_occupancy']:.2f}, runner cache "
          f"{st['caches']['ensemble.runner']['hits']}h/"
          f"{st['caches']['ensemble.runner']['misses']}m")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="batching scenario server over ensemble families")
    ap.add_argument("--smoke", action="store_true",
                    help="in-process end-to-end smoke (the CI serve "
                         "step): 3 compatible requests batched into one "
                         "padded slot + 1 incompatible rejected")
    args = ap.parse_args(argv)
    if args.smoke:
        return _smoke()
    ap.print_help()
    return 0


if __name__ == "__main__":
    sys.exit(main())
