import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape) cell, lowers and compiles the
train/prefill/decode step against the production mesh — 16x16 single-pod and
2x16x16 multi-pod — with ShapeDtypeStruct inputs (no allocation), then
records memory_analysis, cost_analysis, and the loop-aware HLO cost model
(repro.roofline) into results/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs.base import get
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cells, input_specs, skip_reason
from repro.roofline import Roofline, analyze_hlo, model_flops_for_cell

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def build_fn(kind: str, model, accum_steps: int = 1, remat: str = "dots"):
    if kind == "train":
        from repro.training.optimizer import AdamW
        from repro.training.steps import make_train_step

        return make_train_step(model, AdamW(), accum_steps=accum_steps,
                               remat=remat)
    if kind == "prefill":
        if model.cfg.family == "audio":
            return lambda params, batch, cache: model.logits(
                params, batch, remat=remat)
        return lambda params, batch, cache: model.prefill(
            params, batch, cache)
    if kind == "decode":
        from repro.training.steps import make_serve_decode_step

        return make_serve_decode_step(model)
    raise ValueError(kind)


def run_cell(arch: str, shape: str, mesh_kind: str, accum_steps: int = 1,
             remat: str = "dots", save: bool = True, tag: str = "baseline",
             rules=None):
    cfg = get(arch).full
    reason = skip_reason(cfg, shape)
    if reason is not None:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
               "status": "skip", "reason": reason}
        if save:
            _save(rec, arch, shape, mesh_kind, tag)
        print(f"SKIP  {arch} x {shape}: {reason}")
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    from repro.launch.specs import rules_for

    rules = rules_for(cfg, rules)
    kind, model, args = input_specs(arch, shape, mesh, rules)
    fn = build_fn(kind, model, accum_steps=accum_steps, remat=remat)

    from repro.distributed.sharding import activation_sharding

    t0 = time.time()
    with mesh, activation_sharding(mesh, rules):
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = getattr(ma, k, None)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    ca = {}
    try:
        raw = compiled.cost_analysis()
        ca = {k: raw[k] for k in ("flops", "bytes accessed") if k in raw}
    except Exception as e:  # pragma: no cover
        ca["error"] = str(e)

    txt = compiled.as_text()
    cost = analyze_hlo(txt)
    per_dev_bytes = None
    if mem.get("argument_size_in_bytes") is not None:
        per_dev_bytes = (mem.get("argument_size_in_bytes", 0)
                         + (mem.get("temp_size_in_bytes") or 0))

    rl = Roofline(
        arch=arch, shape=shape, mesh=mesh_kind, chips=chips,
        flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.hbm_bytes,
        coll_bytes_per_device=cost.coll_bytes,
        coll_by_kind=cost.coll_by_kind,
        model_flops_global=model_flops_for_cell(arch, shape),
        per_device_memory_bytes=per_dev_bytes,
    )
    rec = {
        "status": "ok", "kind": kind, "tag": tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem, "cost_analysis": ca,
        "accum_steps": accum_steps, "remat": remat,
        "hlo_bytes": len(txt),
        **rl.row(),
    }
    if save:
        _save(rec, arch, shape, mesh_kind, tag)
    print(f"OK    {arch} x {shape} x {mesh_kind}: dominant={rl.dominant} "
          f"t=({rl.t_compute:.3f},{rl.t_memory:.3f},{rl.t_collective:.3f})s "
          f"frac={rl.roofline_fraction:.3f} compile={t_compile:.0f}s")
    return rec


def _save(rec, arch, shape, mesh_kind, tag):
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{arch}__{shape}__{mesh_kind}__{tag}.json"
    p.write_text(json.dumps(rec, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shape, reason in cells(include_skips=True):
            state = f"SKIP({reason})" if reason else "run"
            print(f"{arch:28s} {shape:12s} {state}")
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for arch, shape, reason in cells(include_skips=True):
            for mk in meshes:
                todo.append((arch, shape, mk))
    else:
        todo = [(args.arch, args.shape, mk) for mk in meshes]

    failures = []
    for arch, shape, mk in todo:
        out = RESULTS / f"{arch}__{shape}__{mk}__{args.tag}.json"
        if args.skip_done and out.exists():
            print(f"DONE  {arch} x {shape} x {mk} (cached)")
            continue
        try:
            run_cell(arch, shape, mk, accum_steps=args.accum,
                     remat=args.remat, tag=args.tag)
        except Exception as e:
            failures.append((arch, shape, mk, repr(e)))
            traceback.print_exc()
            print(f"FAIL  {arch} x {shape} x {mk}: {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        sys.exit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
