"""Supervised runs: periodic verified checkpoints + automatic rollback.

The supervisor turns the resilience primitives into one loop (the state
machine below): runtime health guards (core.guards) detect corruption at
host control points, checksummed async ABM checkpoints
(distributed.checkpoint) bound the blast radius, and elastic restore
(distributed.elastic) re-cuts the domain onto whatever device count
survives.  Faults stop being run-enders and become a bounded replay.

State machine::

    RUN ──chunk ok──────────────► CHECKPOINT ──► RUN ...
     │                                 (async, checksummed, pruned)
     └─guard trip / exception──► RECOVER
            │  retries exhausted ──► raise (give up, log says why)
            └─ wait for in-flight save, optional backoff,
               elastic restore from newest VERIFIED checkpoint
               (skipping torn/corrupt ones), onto the surviving
               device count, inheriting the run's ownership mode
               ──► RUN (replay from the checkpoint; fire-once fault
                    plans guarantee the replay is clean)

Recovery guarantee (tested in tests/test_resilience.py): the replayed
run is bit-exact with an uninterrupted run resumed from the same
checkpoint — rollback resets the facade exactly the way
``Simulation.restore`` would (fresh step functions, operation clock at
zero, first aura exchange full), so the two runs execute identical step
sequences.

Every transition lands in ``Supervisor.log`` (a list of dicts) so tests
and operators can assert on what actually happened.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.distributed import checkpoint as ckpt_lib
from repro.distributed.chaos import DeviceLost


@dataclasses.dataclass(frozen=True)
class Supervised:
    """Supervision policy for ``Simulation.run(supervised=...)``.

    ``dir``/``every``/``keep`` set the checkpoint cadence and retention;
    ``max_retries`` bounds consecutive failed recoveries (reset by any
    chunk that completes); ``backoff_s`` is the base of an exponential
    backoff between retries (0 disables sleeping — tests);
    ``async_save`` overlaps checkpoint writes with the next chunk;
    ``degrade`` allows restoring onto fewer devices after a device loss
    (when False, a :class:`repro.distributed.chaos.DeviceLost` is
    re-raised).
    """

    dir: str
    every: int = 10
    keep: int = 5
    max_retries: int = 3
    backoff_s: float = 0.0
    async_save: bool = True
    degrade: bool = True


class Supervisor:
    """Owns the RUN/CHECKPOINT/RECOVER loop around one
    :class:`repro.core.Simulation`.

    Construction gates the ``supervised-recovery`` contract
    (analysis.contracts.check_supervision) at the simulation's ``check``
    mode: supervising an unguarded run is an error — rollback would be
    blind to silent corruption.
    """

    def __init__(self, sim, cfg: Supervised, fault_plan=None):
        from repro.analysis.contracts import (
            check_supervision,
            enforce_diagnostics,
        )
        self.sim = sim
        self.cfg = cfg
        self.fault_plan = fault_plan
        self.log: List[Dict] = []
        enforce_diagnostics(check_supervision(sim.engine, cfg),
                            mode=getattr(sim, "_check", "error"))
        self.ckptr = ckpt_lib.AsyncCheckpointer(cfg.dir, keep=cfg.keep)
        if self.ckptr.swept:
            self._event("swept_stale_tmp", paths=list(self.ckptr.swept))

    # ------------------------------------------------------------------
    def _event(self, kind: str, **kw) -> None:
        self.log.append({"kind": kind, "wall_time": time.time(), **kw})

    def events(self, kind: str) -> List[Dict]:
        return [e for e in self.log if e["kind"] == kind]

    # ------------------------------------------------------------------
    def _save(self) -> None:
        sim = self.sim
        it = sim.iteration
        if self.cfg.async_save:
            self.ckptr.save_abm(it, sim.engine, sim.state)
        else:
            ckpt_lib.save_abm(self.cfg.dir, it, sim.engine, sim.state,
                              keep=self.cfg.keep)
        self._event("checkpoint", step=it)
        if self.fault_plan is not None:
            # a torn-write fault needs bytes on disk before it can tear
            self.ckptr.wait()
            torn = self.fault_plan.maybe_tear(self.cfg.dir, it)
            if torn:
                self._event("torn_checkpoint", path=torn)

    def _recover(self, err: BaseException, retry: int) -> None:
        import jax

        from repro.distributed.elastic import elastic_restore_abm

        sim = self.sim
        failed_at = sim.iteration
        try:
            self.ckptr.wait()  # surface an in-flight write failure too
        except Exception as werr:  # noqa: BLE001 - logged, not fatal
            self._event("checkpoint_write_failed", error=repr(werr))
        survivors: Optional[int] = getattr(err, "survivors", None)
        if survivors is not None and not self.cfg.degrade:
            raise err
        n = survivors if survivors is not None \
            else min(sim.engine.geom.n_devices, len(jax.devices()))
        if self.cfg.backoff_s > 0:
            time.sleep(self.cfg.backoff_s * 2 ** (retry - 1))
        engine0, state, step_ = elastic_restore_abm(
            self.cfg.dir, sim.behavior, n_devices=n,
            delta_cfg=sim.engine.delta_cfg, dt=sim.engine.dt,
            ownership=None)  # None inherits the checkpointed mode
        # keep the run's knobs (guards, sweep backend, rebalance policy):
        # only the geometry comes from the re-cut restore plan
        engine = dataclasses.replace(sim.engine, geom=engine0.geom)
        sim.with_state(engine, state)
        # reset the facade exactly like Simulation.restore: the operation
        # clock restarts at zero, so the replay is bit-exact with an
        # uninterrupted run resumed from this checkpoint
        sim._ticks = 0
        self._event(
            "recovered", error=repr(err), error_type=type(err).__name__,
            failed_at=failed_at, rolled_back_to=step_, devices=n,
            retry=retry, replay_steps=failed_at - step_)

    # ------------------------------------------------------------------
    def run(self, steps: int, fused: bool = True):
        """Supervise ``steps`` iterations; returns the simulation."""
        sim = self.sim
        cfg = self.cfg
        target = sim.iteration + int(steps)
        if ckpt_lib.latest_step(cfg.dir) is None:
            self._save()  # a rollback target must exist before step one
        retries = 0
        while True:
            it = sim.iteration
            if it >= target:
                break
            chunk = min(cfg.every - (it % cfg.every), target - it)
            try:
                sim.run(chunk, fused=fused, fault_plan=self.fault_plan)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as err:  # noqa: BLE001 - bounded retry below
                retries += 1
                self._event("fault", error=repr(err),
                            error_type=type(err).__name__,
                            iteration=sim.iteration, retry=retries)
                if retries > cfg.max_retries:
                    self._event("giving_up", retries=retries)
                    raise
                if isinstance(err, DeviceLost) and not cfg.degrade:
                    self._event("giving_up", retries=retries,
                                reason="degrade disabled")
                    raise
                self._recover(err, retries)
            else:
                retries = 0
                if sim.iteration % cfg.every == 0 or sim.iteration >= target:
                    self._save()
        self.ckptr.wait()
        self._event("completed", iteration=sim.iteration)
        return sim
