"""Input shape sets and ShapeDtypeStruct stand-ins for every dry-run cell.

Shapes (assigned to every LM arch):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (encoder fwd for audio)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token, KV cache)
  long_500k    seq 524,288 global_batch 1     -> serve_step; sub-quadratic archs only

Skip rules (recorded per DESIGN.md §Shape-skips):
  * decode shapes for encoder-only (audio) archs
  * long_500k for pure full-attention archs
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, get
from repro.distributed.sharding import spec_for
from repro.models.model import Model, build_model
from repro.models.params import abstract as abstract_params

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}


def skip_reason(cfg: ArchConfig, shape_name: str) -> Optional[str]:
    kind = SHAPES[shape_name]["kind"]
    if cfg.family == "audio" and kind == "decode":
        return "encoder-only arch has no autoregressive decode step"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 512k context requires a "
                "sub-quadratic mechanism the published arch lacks")
    return None


def cells(include_skips: bool = False):
    """Every (arch, shape) pair; skipped pairs carry their reason."""
    from repro.configs.base import names

    out = []
    for arch in names():
        cfg = get(arch).full
        for shape in SHAPES:
            reason = skip_reason(cfg, shape)
            if reason is None or include_skips:
                out.append((arch, shape, reason))
    return out


def _sds(shape, dtype, mesh, logical, rules=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=jax.sharding.NamedSharding(
            mesh, spec_for(shape, logical, mesh, rules)),
    )


def batch_specs(cfg: ArchConfig, shape_name: str, mesh, rules=None):
    """Abstract training/prefill batch with shardings attached."""
    s = SHAPES[shape_name]
    seq, b = s["seq"], s["batch"]
    i32, bf16 = jnp.int32, jnp.bfloat16
    if cfg.family == "audio":
        return {
            "frames": _sds((b, seq, cfg.frontend_dim), bf16, mesh,
                           ("batch", "seq_act", "frontend"), rules),
            "labels": _sds((b, seq), i32, mesh, ("batch", "seq_act"), rules),
        }
    if cfg.family == "vlm":
        st = seq - cfg.n_patches
        return {
            "tokens": _sds((b, st), i32, mesh, ("batch", "seq_act"), rules),
            "patches": _sds((b, cfg.n_patches, cfg.frontend_dim), bf16, mesh,
                            ("batch", "patches", "frontend"), rules),
            "labels": _sds((b, st), i32, mesh, ("batch", "seq_act"), rules),
        }
    return {
        "tokens": _sds((b, seq), i32, mesh, ("batch", "seq_act"), rules),
        "labels": _sds((b, seq), i32, mesh, ("batch", "seq_act"), rules),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_len: int, mesh, rules=None):
    """Abstract KV/state cache with shardings."""
    bf16, f32 = jnp.bfloat16, jnp.float32

    def sds(shape, dtype, logical):
        return _sds(shape, dtype, mesh, logical, rules)

    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attention == "mla":
            m = cfg.mla
            return sds((cfg.n_layers, batch, max_len,
                        m.kv_lora_rank + m.qk_rope_head_dim), bf16,
                       ("layers", "batch", "seq", "lora"))
        hd = cfg.hd
        kv = ("layers", "batch", "kv_heads", "seq", "head_dim")
        return (
            sds((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd), bf16, kv),
            sds((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd), bf16, kv),
        )
    if cfg.family == "ssm":
        xc = cfg.xlstm
        n_seg = cfg.n_layers // xc.slstm_every
        di = int(cfg.d_model * xc.proj_factor)
        h = cfg.n_heads
        p = di // h
        dc = xc.conv_kernel
        d = cfg.d_model
        lead = (n_seg, xc.slstm_every - 1)
        ll = ("layers", "layers2")
        ml = {
            "c": sds(lead + (batch, h, p, p), f32,
                     ll + ("batch", "heads", "head_dim", "mlp")),
            "n": sds(lead + (batch, h, p), f32,
                     ll + ("batch", "heads", "head_dim")),
            "m": sds(lead + (batch, h), f32, ll + ("batch", "heads")),
            "conv": sds(lead + (batch, dc - 1, di), bf16,
                        ll + ("batch", "conv", "mlp")),
        }
        sl = {
            "c": sds((n_seg, batch, d), f32, ("layers", "batch", "embed")),
            "n": sds((n_seg, batch, d), f32, ("layers", "batch", "embed")),
            "h": sds((n_seg, batch, d), f32, ("layers", "batch", "embed")),
            "m": sds((n_seg, batch, d), f32, ("layers", "batch", "embed")),
        }
        from repro.models.xlstm import MLSTMState, SLSTMState

        return {
            "mlstm": MLSTMState(c=ml["c"], n=ml["n"], m=ml["m"],
                                conv=ml["conv"]),
            "slstm": SLSTMState(c=sl["c"], n=sl["n"], h=sl["h"], m=sl["m"]),
        }
    if cfg.family == "hybrid":
        from repro.models.mamba2 import Mamba2State

        k = cfg.shared_attn_every
        n_full, rem = divmod(cfg.n_layers, k)
        sc = cfg.ssm
        di = sc.expand * cfg.d_model
        h, p, n = sc.n_heads, sc.expand * cfg.d_model // sc.n_heads, sc.d_state
        ll = ("layers", "layers2")

        def mstate(lead, lnames):
            return Mamba2State(
                ssm=sds(lead + (batch, h, p, n), f32,
                        lnames + ("batch", "heads", "head_dim", "state")),
                conv=sds(lead + (batch, sc.d_conv - 1, di + 2 * n), bf16,
                         lnames + ("batch", "conv", "mlp")),
            )

        hd = cfg.hd
        kvl = ("layers", "batch", "kv_heads", "seq", "head_dim")
        out = {
            "mamba": mstate((n_full, k), ll),
            "attn": (
                sds((n_full, batch, cfg.n_kv_heads, max_len, hd), bf16, kvl),
                sds((n_full, batch, cfg.n_kv_heads, max_len, hd), bf16, kvl),
            ),
        }
        if rem:
            out["mamba_tail"] = mstate((rem,), ("layers",))
        return out
    raise ValueError(cfg.family)


def params_specs(model: Model, mesh, rules=None):
    from repro.models.params import abstract_sharded

    if mesh is None:
        return abstract_params(model.spec)
    return abstract_sharded(model.spec, mesh, rules)


def opt_specs(params_abs, mesh=None):
    """AdamW state mirrors the param tree (f32) + scalar step."""
    from repro.training.optimizer import AdamWState

    def f32_like(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                    sharding=getattr(p, "sharding", None))

    t = jax.tree_util.tree_map(f32_like, params_abs)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return AdamWState(step=step, master=t,
                      m=jax.tree_util.tree_map(f32_like, params_abs),
                      v=jax.tree_util.tree_map(f32_like, params_abs))


# Per-family sharding-rule overrides (§Perf hc-xlstm-6): the xLSTM family
# has no TP-friendly dimension — its block-diagonal projections and
# sequential sLSTM recurrence turn every model-axis shard into per-step
# collectives.  Pure data parallelism over ALL mesh axes (batch 256 = 1 seq
# per chip) with FSDP weight sharding is strictly better: measured 56s ->
# see EXPERIMENTS.md §Perf.
# NOTE: a pure-DP profile for the ssm family (batch over all axes, no TP)
# was tried and REFUTED — the batch/FSDP axis conflict made GSPMD replicate
# the gate activations (t_mem 20s -> 81s); see EXPERIMENTS.md §Perf
# hc-xlstm-6.
FAMILY_RULES: Dict[str, Dict] = {}


def rules_for(cfg: ArchConfig, rules=None):
    fam = FAMILY_RULES.get(cfg.family, {})
    return {**fam, **(rules or {})} if (fam or rules) else None


def input_specs(arch: str, shape_name: str, mesh=None, rules=None):
    """All abstract inputs for one dry-run cell.

    Returns (kind, model, args) where args feed the lowered callable:
      train   -> (params, opt_state, batch)
      prefill -> (params, batch, cache)
      decode  -> (params, cache, tokens, index)
    """
    cfg = get(arch).full
    model = build_model(cfg)
    s = SHAPES[shape_name]
    kind = s["kind"]
    rules = rules_for(cfg, rules)
    params = params_specs(model, mesh, rules)

    if kind == "train":
        return kind, model, (params, opt_specs(params, mesh),
                             batch_specs(cfg, shape_name, mesh, rules))
    if kind == "prefill":
        cache = None
        if cfg.family != "audio":
            cache = cache_specs(cfg, s["batch"], s["seq"], mesh, rules)
        batch = batch_specs(cfg, shape_name, mesh, rules)
        batch.pop("labels", None)
        return kind, model, (params, batch, cache)
    # decode
    b = s["batch"]
    cache = cache_specs(cfg, b, s["seq"], mesh, rules)
    tokens = _sds((b, 1), jnp.int32, mesh, ("batch", None), rules)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return kind, model, (params, cache, tokens, index)
