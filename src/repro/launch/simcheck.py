"""simcheck — static distributed-correctness audit of simulations + repo.

    PYTHONPATH=src python -m repro.launch.simcheck --sim tumor_spheroid --strict
    PYTHONPATH=src python -m repro.launch.simcheck --sim all --lint --strict
    PYTHONPATH=src python -m repro.launch.simcheck --lint src/repro --format json

Three passes (docs/contracts.md catalogues every contract):

* **contracts** — stencil soundness, one-hop migration, aura sufficiency,
  codec headroom, partition validity, over each sim's geometry + behavior
  stack — including *virtual* multi-device variants (an equal split and an
  uneven RCB cut of the same global domain), so a sim that only ships a
  single-device default still gets its distributed contracts checked
  without any devices present.
* **jaxpr audit** — the step body traced with ``jax.make_jaxpr`` under the
  mesh axis environment: ppermute permutation validity, host-sync
  primitives, dtype drift, int8 arithmetic, cache-key stability.
* **lint** — AST checks over source files and behavior hot functions.

Exit code 0 when clean; 1 on any error (or, with ``--strict``, warning).
Everything here is static — no simulation steps run, no devices needed.
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib
import pathlib
import sys
from typing import List, Optional, Sequence, Tuple

from repro.analysis import (
    ContractError,
    Report,
    audit_engine,
    check_engine,
    check_ensemble,
    lint_behavior,
    lint_paths,
    with_context,
)

SIMS = ["cell_clustering", "cell_proliferation", "epidemiology",
        "oncology", "sir_mechanics", "tumor_spheroid"]


def virtual_variants(engine) -> List[Tuple[str, object]]:
    """Multi-device variants of a single-device engine's geometry — an
    equal split and an uneven RCB-style cut over the same global domain.

    The static checks and ``make_jaxpr`` tracing need no devices, so the
    distributed contracts of a sim are checked on any host, exactly as
    they would bind on a real mesh."""
    geom = engine.geom
    if geom.n_devices > 1 or geom.partition is not None:
        return []  # already distributed: the base engine covers it
    out: List[Tuple[str, object]] = []
    g = geom.global_cells
    mesh2 = tuple(2 if gc >= 2 and gc % 2 == 0 else 1 for gc in g)
    if any(m > 1 for m in mesh2):
        label = "mesh=" + "x".join(str(m) for m in mesh2)
        out.append((label, dataclasses.replace(
            engine, geom=geom.with_mesh_shape(mesh2))))
    # Uneven two-slab cut per axis with enough cells: the narrower slab
    # tightens the one-hop bound the way a real RCB plan would.
    widths = []
    for gc in g:
        if gc >= 4:
            lo = gc // 2 - 1
            widths.append((lo, gc - lo))
        elif gc >= 3:
            widths.append((1, gc - 1))
        else:
            widths.append((gc,))
    from repro.core import Partition
    part = Partition.from_widths(widths)
    if any(len(w) > 1 for w in widths) and not part.is_equal:
        out.append(("rcb=" + "/".join(
            "+".join(str(v) for v in w) for w in widths),
            dataclasses.replace(engine, geom=geom.repartition(part))))
    return out


def check_simulation(sim, *, jaxpr: bool = True,
                     variants: bool = True) -> Report:
    """Full simcheck over a built :class:`repro.core.Simulation`: the base
    engine plus (optionally) its virtual distributed variants."""
    rep = Report()
    rep.extend(check_engine(sim.engine))
    rep.extend(lint_behavior(sim.behavior))
    if jaxpr:
        rep.extend(audit_engine(sim.engine))
    if variants:
        for label, eng in virtual_variants(sim.engine):
            diags = check_engine(eng)
            if jaxpr:
                diags = diags + audit_engine(eng)
            rep.extend(with_context(diags, label))
    return rep


def check_sim_module(name: str, *, jaxpr: bool = True,
                     variants: bool = True) -> Report:
    """Build ``repro.sims.<name>.simulation()`` and simcheck it.  A
    construction-time :class:`ContractError` (the facade's own gate)
    becomes the report's findings instead of a stack trace."""
    mod = importlib.import_module(f"repro.sims.{name}")
    try:
        sim = mod.simulation()
    except ContractError as e:
        rep = Report()
        rep.extend(with_context(e.diagnostics, f"sims.{name}"))
        return rep
    rep = check_simulation(sim, jaxpr=jaxpr, variants=variants)
    rep.diagnostics = with_context(rep.diagnostics, f"sims.{name}")
    return rep


def ensemble_families() -> List[str]:
    """Sims that publish an ensemble compatibility family (a module-level
    ``ensemble_family()`` builder, see core.ensemble)."""
    out = []
    for name in SIMS:
        mod = importlib.import_module(f"repro.sims.{name}")
        if hasattr(mod, "ensemble_family"):
            out.append(name)
    return out


def check_ensemble_module(name: str) -> Report:
    """Batch-safety contract over a sim's published ensemble family —
    the same :func:`repro.analysis.check_ensemble` pass the scenario
    server runs before admitting a family's requests."""
    rep = Report()
    mod = importlib.import_module(f"repro.sims.{name}")
    fam = getattr(mod, "ensemble_family", None)
    if fam is None:
        from repro.analysis import Diagnostic
        rep.add(Diagnostic(
            severity="info", contract="ensemble-batch-safe",
            message=f"sims.{name} publishes no ensemble family "
                    "(no ensemble_family() builder)",
            location=f"sims.{name}"))
        return rep
    rep.extend(with_context(check_ensemble(fam()), f"ensemble.{name}"))
    return rep


def _default_lint_root() -> str:
    import repro
    return str(pathlib.Path(repro.__file__).parent)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.simcheck",
        description="static contract checker, jaxpr auditor, and repo "
                    "lint (docs/contracts.md)")
    ap.add_argument("--sim", action="append", default=[],
                    choices=SIMS + ["all"], metavar="SIM",
                    help="sim to check (repeatable; 'all' checks every "
                         f"shipped sim: {', '.join(SIMS)})")
    ap.add_argument("--lint", nargs="*", metavar="PATH",
                    help="lint source paths (flag alone lints the "
                         "installed repro package)")
    ap.add_argument("--ensemble", action="append", default=[],
                    choices=SIMS + ["all"], metavar="SIM",
                    help="check a sim's ensemble family for batch "
                         "safety ('all' checks every published family)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail (errors always do)")
    ap.add_argument("--format", default="text", choices=["text", "json"])
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the make_jaxpr step audit (faster)")
    ap.add_argument("--no-variants", action="store_true",
                    help="skip the virtual multi-device variants")
    args = ap.parse_args(argv)

    sims = list(args.sim)
    if "all" in sims:
        sims = SIMS
    ensembles = list(args.ensemble)
    if "all" in ensembles:
        ensembles = ensemble_families()
    if not sims and args.lint is None and not ensembles:
        # bare invocation: audit everything
        sims = SIMS
        ensembles = ensemble_families()
        args.lint = []

    rep = Report()
    if args.lint is not None:
        paths = list(args.lint) or [_default_lint_root()]
        rep.extend(lint_paths(paths))
    for name in sims:
        rep.extend(check_sim_module(
            name, jaxpr=not args.no_jaxpr,
            variants=not args.no_variants))
    for name in ensembles:
        rep.extend(check_ensemble_module(name))

    out = rep.format_json() if args.format == "json" else rep.format_text()
    print(out)
    return rep.exit_code(strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
