"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; callers must have set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
jax initialization if they need placeholder devices (dryrun.py does this in
its first two lines).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.compat import axis_types_kwargs as _axis_types_kwargs


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh helper (tests, elastic re-shard, ABM spatial meshes)."""
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_abm_mesh(mesh_shape: Tuple[int, ...],
                  axes: Optional[Tuple[str, ...]] = None):
    """Spatial device mesh for the ABM engine (paper Fig. 1 rank grid),
    version-compat across JAX releases: ``(sx, sy)`` for 2-D domains,
    ``(sx, sy, sz)`` for 3-D ones.  The canonical way to build the mesh
    passed to ``Engine.make_sharded_step`` and the re-shard runtime."""
    mesh_shape = tuple(mesh_shape)
    if axes is None:
        # deferred: keeps this module importable without the core layer
        from repro.core.domain import spatial_axis_names
        axes = spatial_axis_names(len(mesh_shape))
    return make_mesh(mesh_shape, tuple(axes))


# TPU v5e hardware model used by the roofline analysis (per-chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
}
