"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 50 --seq 128 --batch 8 --ckpt-dir /tmp/ckpt

On a real TPU cluster the same entry point runs per host (jax.distributed
initializes from the standard TPU environment); device placeholders are
never forced here — only dryrun.py does that.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get
from repro.data.pipeline import SyntheticLM
from repro.distributed import checkpoint as ck
from repro.distributed.elastic import choose_lm_mesh
from repro.distributed.grad_compress import DeltaEFCompressor
from repro.distributed.sharding import activation_sharding
from repro.launch.mesh import make_mesh
from repro.models import params as P
from repro.models.model import build_model
from repro.training.optimizer import AdamW, WSDSchedule
from repro.training.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--grad-compress", action="store_true",
                    help="delta+error-feedback int8 gradient compression")
    args = ap.parse_args()

    spec = get(args.arch)
    cfg = spec.smoke if args.smoke else spec.full
    model = build_model(cfg)
    opt = AdamW(schedule=WSDSchedule(
        warmup_steps=max(args.steps // 10, 1),
        stable_steps=max(args.steps * 8 // 10, 1),
        decay_steps=max(args.steps // 10, 1)))

    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        shape, axes = choose_lm_mesh(n_dev)
        mesh = make_mesh(shape, axes)
        print(f"mesh: {dict(zip(axes, shape))}")

    compressor = DeltaEFCompressor() if args.grad_compress else None
    step_fn = make_train_step(model, opt, accum_steps=args.accum,
                              remat=args.remat,
                              grad_transform=compressor)
    pipe = SyntheticLM(cfg, seq_len=args.seq, global_batch=args.batch)

    params = P.init(model.spec, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    grad_ctx = compressor.init(params) if compressor else None
    start = 0
    ckpt = ck.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and ck.latest_step(args.ckpt_dir) is not None:
        start, restored, _ = ck.restore(
            args.ckpt_dir, like={"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from step {start}")

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    ctx = activation_sharding(mesh) if mesh is not None else None
    t0 = time.time()
    for i in range(start, args.steps):
        batch = pipe.batch_for_step(i)
        if ctx is not None:
            with ctx:
                out = jit_step(params, opt_state, batch, grad_ctx) \
                    if compressor else jit_step(params, opt_state, batch)
        else:
            out = jit_step(params, opt_state, batch, grad_ctx) \
                if compressor else jit_step(params, opt_state, batch)
        if compressor:
            params, opt_state, metrics, grad_ctx = out
        else:
            params, opt_state, metrics = out
        if (i + 1) % 10 == 0:
            tps = (args.batch * args.seq * (i + 1 - start)
                   / (time.time() - t0))
            print(f"step {i+1:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  tok/s {tps:.0f}")
        if ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.wait()


if __name__ == "__main__":
    main()
