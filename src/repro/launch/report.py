"""Aggregate dry-run records into the EXPERIMENTS.md roofline table."""

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load(tag="baseline"):
    rows = []
    for p in sorted(RESULTS.glob(f"*__{tag}.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def fmt_row(r):
    if r.get("status") == "skip":
        return None
    mem = r.get("memory_analysis", {})
    hbm_gb = ((mem.get("argument_size_in_bytes") or 0)
              + (mem.get("temp_size_in_bytes") or 0)) / 1e9
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "kind": r.get("kind", "?"),
        "tc": r["t_compute_s"], "tm": r["t_memory_s"],
        "tx": r["t_collective_s"], "dom": r["dominant"],
        "useful": r["useful_flops_ratio"], "frac": r["roofline_fraction"],
        "mem_gb": hbm_gb, "compile_s": r.get("compile_s", 0),
    }


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    rows = [fmt_row(r) for r in load(tag)]
    rows = [r for r in rows if r]
    print(f"| arch | shape | mesh | kind | t_comp(s) | t_mem(s) | t_coll(s) "
          f"| dominant | 6ND/HLO | frac | mem(GB) |")
    print("|" + "---|" * 11)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} "
              f"| {r['tc']:.4f} | {r['tm']:.4f} | {r['tx']:.4f} "
              f"| {r['dom']} | {r['useful']:.3f} | {r['frac']:.4f} "
              f"| {r['mem_gb']:.1f} |")
    # summary stats
    n_skip = sum(1 for r in load(tag) if r.get("status") == "skip")
    print(f"\n{len(rows)} compiled cells, {n_skip} recorded skips")


if __name__ == "__main__":
    main()
