"""Shared neural layers: norms, rotary embeddings, MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

Array = jax.Array


# -- Norms -------------------------------------------------------------

def rmsnorm_spec(d: int):
    return {"scale": ParamSpec((d,), ("embed",), jnp.float32, "ones")}


def rmsnorm(params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def layernorm_spec(d: int):
    return {
        "scale": ParamSpec((d,), ("embed",), jnp.float32, "ones"),
        "bias": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
    }


def layernorm(params, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def nonparametric_ln(params, x: Array, eps: float = 1e-5) -> Array:
    """OLMo-style LayerNorm without scale/bias (non-parametric)."""
    del params
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


NORM_SPECS = {
    "rmsnorm": rmsnorm_spec,
    "layernorm": layernorm_spec,
    "nonparametric_ln": lambda d: {},
}
NORM_FNS = {
    "rmsnorm": rmsnorm,
    "layernorm": layernorm,
    "nonparametric_ln": nonparametric_ln,
}


# -- Rotary ------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """Apply rotary embedding.  x: (..., S, hd), positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head axis: x (..., H, S, hd) vs ang (..., S, half)
    while cos.ndim < x.ndim - 1:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLPs --------------------------------------------------------------

def swiglu_spec(d: int, f: int):
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp")),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def swiglu(params, x: Array) -> Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def gelu_mlp_spec(d: int, f: int):
    return {
        "w_in": ParamSpec((d, f), ("embed", "mlp")),
        "b_in": ParamSpec((f,), ("mlp",), jnp.float32, "zeros"),
        "w_out": ParamSpec((f, d), ("mlp", "embed")),
        "b_out": ParamSpec((d,), ("embed",), jnp.float32, "zeros"),
    }


def gelu_mlp(params, x: Array) -> Array:
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"].astype(
        x.dtype
    )
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params[
        "b_out"
    ].astype(x.dtype)
