"""Mamba2 (State-Space Duality) block — chunked-parallel train/prefill path
plus a single-step recurrence for decode.

Structure follows the published block: in-projection to (z, x, B, C, dt),
short causal depthwise conv on (x, B, C), SSD state-space mixing with
per-head scalar decay A, gated (SiLU(z)) RMS-normed out-projection.

The chunked SSD algorithm scans over sequence chunks carrying the (H, P, N)
state — O(S) compute and memory, which is what makes the ``long_500k`` cell
runnable for the SSM/hybrid architectures while full-attention archs skip it.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import rmsnorm
from repro.models.params import ParamSpec

Array = jax.Array


class Mamba2State(NamedTuple):
    ssm: Array    # (B, H, P, N) carried SSD state
    conv: Array   # (B, d_conv-1, d_inner + 2*N) conv tail cache


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = s.n_heads
    p = d_inner // n_heads
    return d_inner, n_heads, p, s.d_state, s.d_conv, s.chunk


def mamba2_spec(cfg: ArchConfig):
    d = cfg.d_model
    di, h, p, n, dc, _ = _dims(cfg)
    conv_ch = di + 2 * n
    return {
        "w_in": ParamSpec((d, 2 * di + 2 * n + h), ("embed", "mlp")),
        "conv_w": ParamSpec((dc, conv_ch), ("conv", "mlp"), jnp.float32,
                            "scaled"),
        "conv_b": ParamSpec((conv_ch,), ("mlp",), jnp.float32, "zeros"),
        "a_log": ParamSpec((h,), ("heads",), jnp.float32, "zeros"),
        "dt_bias": ParamSpec((h,), ("heads",), jnp.float32, "zeros"),
        "d_skip": ParamSpec((h,), ("heads",), jnp.float32, "ones"),
        "norm_scale": ParamSpec((di,), ("mlp",), jnp.float32, "ones"),
        "w_out": ParamSpec((di, d), ("mlp", "embed")),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: Array):
    di, h, p, n, _, _ = _dims(cfg)
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    bb = zxbcdt[..., 2 * di:2 * di + n]
    cc = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, x, bb, cc, dt


def _conv(params, u: Array, tail: Optional[Array]) -> Tuple[Array, Array]:
    """Causal depthwise conv over (B, S, C) with cached tail for decode."""
    dc = params["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], dc - 1, u.shape[-1]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)                # (B, S+dc-1, C)
    w = params["conv_w"].astype(u.dtype)                    # (dc, C)
    out = sum(
        ext[:, i:i + u.shape[1]] * w[i][None, None] for i in range(dc)
    ) + params["conv_b"].astype(u.dtype)
    new_tail = ext[:, -(dc - 1):] if dc > 1 else ext[:, :0]
    return jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype), new_tail


def mamba2_apply(
    params,
    cfg: ArchConfig,
    xin: Array,                      # (B, S, D)
    state: Optional[Mamba2State] = None,
) -> Tuple[Array, Optional[Mamba2State]]:
    di, h, p, n, dc, chunk = _dims(cfg)
    b, s, d = xin.shape

    zxbcdt = jnp.einsum("bsd,de->bse", xin, params["w_in"])
    z, xproj, _, _, dt_raw = _split_proj(cfg, zxbcdt)
    conv_in = zxbcdt[..., di:2 * di + 2 * n]                # x ++ B ++ C
    conv_out, new_tail = _conv(params, conv_in,
                               state.conv if state is not None else None)
    x = conv_out[..., :di]
    bb = conv_out[..., di:di + n]
    cc = conv_out[..., di + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])               # (B, S, H)
    a = -jnp.exp(params["a_log"])                           # (H,) negative
    da = dt * a[None, None]                                 # (B, S, H) log-decay
    xh = x.reshape(b, s, h, p)

    if s == 1 and state is not None:
        # -- decode recurrence ----------------------------------------
        dta = jnp.exp(da[:, 0])                             # (B, H)
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0],
                         xh[:, 0].astype(jnp.float32),
                         bb[:, 0].astype(jnp.float32))
        ssm = state.ssm * dta[..., None, None] + dbx
        y = jnp.einsum("bhpn,bn->bhp", ssm, cc[:, 0].astype(jnp.float32))
        y = y + params["d_skip"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, di).astype(xin.dtype)
        new_state = Mamba2State(ssm=ssm, conv=new_tail)
    else:
        # -- chunked SSD scan ------------------------------------------
        l = min(chunk, s)
        assert s % l == 0, f"S={s} not divisible by chunk={l}"
        nc = s // l

        def reshape_c(t):  # (B, S, ...) -> (nc, B, L, ...)
            return t.reshape(b, nc, l, *t.shape[2:]).swapaxes(0, 1)

        da_c = reshape_c(da)                                # (nc, B, L, H)
        dt_c = reshape_c(dt)
        x_c = reshape_c(xh.astype(jnp.float32))             # (nc, B, L, H, P)
        b_c = reshape_c(bb.astype(jnp.float32))             # (nc, B, L, N)
        c_c = reshape_c(cc.astype(jnp.float32))

        ssm0 = (state.ssm if state is not None
                else jnp.zeros((b, h, p, n), jnp.float32))

        def body(carry, inp):
            ssm = carry
            dac, dtc, xc, bc, ccc = inp
            cum = jnp.cumsum(dac, axis=1)                   # (B, L, H)
            # intra-chunk "attention": decay(i<-j) = exp(cum_i - cum_j)
            rel = cum[:, :, None, :] - cum[:, None, :, :]   # (B, L, L, H)
            tri = jnp.tril(jnp.ones((l, l), jnp.float32))
            seg = jnp.exp(rel) * tri[None, :, :, None]
            scores = jnp.einsum("bin,bjn->bij", ccc, bc)    # (B, L, L)
            w = scores[..., None] * seg * dtc[:, None]      # (B,L,L,H)
            y_intra = jnp.einsum("bijh,bjhp->bihp", w, xc)
            # inter-chunk: contribution of carried state
            y_inter = jnp.einsum(
                "bin,bhpn,bih->bihp", ccc, ssm, jnp.exp(cum)
            )
            # state update: decay whole chunk + inject chunk outer products
            tail_decay = jnp.exp(cum[:, -1:, :] - cum)      # (B, L, H)
            inject = jnp.einsum(
                "blh,blhp,bln->bhpn", dtc * tail_decay, xc, bc
            )
            # cum[:, -1] is (B, H) -> broadcast to the (B, H, P, N) state
            ssm_new = ssm * jnp.exp(cum[:, -1])[..., None, None] + inject
            return ssm_new, (y_intra + y_inter)

        ssm_f, y_chunks = jax.lax.scan(
            body, ssm0, (da_c, dt_c, x_c, b_c, c_c)
        )
        y = y_chunks.swapaxes(0, 1).reshape(b, s, h, p)
        y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, s, di).astype(xin.dtype)
        new_state = Mamba2State(ssm=ssm_f, conv=new_tail) if (
            state is not None) else None

    # gated output
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, new_state


def init_state(cfg: ArchConfig, batch: int) -> Mamba2State:
    di, h, p, n, dc, _ = _dims(cfg)
    return Mamba2State(
        ssm=jnp.zeros((batch, h, p, n), jnp.float32),
        conv=jnp.zeros((batch, dc - 1, di + 2 * n), jnp.bfloat16),
    )
