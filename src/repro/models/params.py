"""Parameter specification trees: shape + dtype + logical axis names + init.

Models declare their parameters as a pytree of ``ParamSpec``; ``init`` turns
the tree into arrays (optionally already placed with NamedShardings so giant
models can be *created* sharded), and ``abstract`` turns it into
ShapeDtypeStructs for the allocation-free dry-run path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: object = jnp.bfloat16
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract(spec_tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree, is_leaf=is_spec,
    )


def abstract_sharded(spec_tree, mesh, rules=None):
    from repro.distributed.sharding import sharding_for

    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=sharding_for(s.shape, s.logical, mesh, rules)
        ),
        spec_tree, is_leaf=is_spec,
    )


def init(spec_tree, key: jax.Array):
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[-1], 1)
        scale = s.scale if s.init == "normal" else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(s.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(s, k) for s, k in zip(leaves, keys)]
    )


def count_params(spec_tree) -> int:
    """Exact parameter count from a spec tree."""
    import math

    total = 0
    for s in jax.tree_util.tree_leaves(
            spec_tree, is_leaf=is_spec):
        total += math.prod(s.shape)
    return total
