"""Mixture-of-Experts FFN with grouped, capacity-bounded token-choice routing.

Dispatch is *grouped* (GShard-style): tokens are grouped by batch row, each
group routes its S tokens independently with per-group capacity
C = ceil(k * S / E * cf).  All gathers/scatters are then batched over the
group dim, which is sharded over the data axes — so GSPMD keeps token
movement local to the data shard and the only cross-device collective is the
expert combine over the "model" (expert-parallel) axis: exactly the
all-to-all-class traffic the paper's byte-minimization insight targets.

Routing semantics: tokens pick top-k experts (normalized weights); each
expert serves at most C tokens per group, selected by router weight
(capacity truncation, overflow dropped — standard Switch/GShard behavior).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec

Array = jax.Array


def moe_spec(cfg: ArchConfig):
    d = cfg.d_model
    m = cfg.moe
    e, f = m.n_experts, m.expert_d_ff
    return {
        "router": ParamSpec((d, e), ("embed", "experts"), jnp.float32),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def capacity(cfg: ArchConfig, group_tokens: int) -> int:
    m = cfg.moe
    c = int(math.ceil(
        m.top_k * group_tokens / m.n_experts * m.capacity_factor))
    return max(1, min(max(c, 4), group_tokens))


def moe_apply(params, cfg: ArchConfig, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, D) -> (y, aux_loss).  Groups = batch rows."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (B, S, E)
    topk_p, topk_i = jax.lax.top_k(probs, k)                   # (B, S, k)
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, -1, keepdims=True), 1e-9)
    gate = jnp.zeros((b, s, e), jnp.float32)
    gate = gate.at[
        jnp.arange(b)[:, None, None], jnp.arange(s)[None, :, None], topk_i
    ].set(topk_p)                                              # (B, S, E)
    gate = constrain(gate, ("batch", "seq_act", "experts"))

    c = capacity(cfg, s)
    # per group, per expert: top-C tokens by gate weight
    w_ec, idx_ec = jax.lax.top_k(gate.swapaxes(1, 2), c)       # (B, E, C)
    live = (w_ec > 0.0).astype(x.dtype)

    # batched gather within each group: xe[g, e, c] = x[g, idx[g, e, c]]
    xe = jnp.take_along_axis(
        x[:, None, :, :],                                      # (B, 1, S, D)
        idx_ec[..., None],                                     # (B, E, C, 1)
        axis=2,
    )                                                          # (B, E, C, D)
    xe = constrain(xe, ("batch", "experts", "capacity", "embed_act"))

    g = jnp.einsum("becd,edf->becf", xe, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"])     # (B, E, C, D)
    ye = constrain(ye, ("batch", "experts", "capacity", "embed_act"))
    ye = ye * (w_ec * live.astype(jnp.float32))[..., None].astype(ye.dtype)

    # batched scatter-add back to token order (combine over experts)
    y = jnp.zeros((b, s, d), ye.dtype)
    y = y.at[
        jnp.arange(b)[:, None, None, None],
        idx_ec[..., None],
        jnp.arange(d)[None, None, None, :],
    ].add(ye)
    y = constrain(y, ("batch", "seq_act", "embed_act"))

    # Switch-style load-balancing auxiliary loss.
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    assigned = jnp.zeros((b, s, e), jnp.float32).at[
        jnp.arange(b)[:, None, None], jnp.arange(s)[None, :, None], topk_i
    ].set(1.0)
    fe = jnp.mean(assigned, axis=(0, 1))
    aux = m.router_aux_weight * e * jnp.sum(me * fe)
    return y, aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map + all_to_all (production path)
# ---------------------------------------------------------------------------
#
# GSPMD cannot shard the general scatter in the grouped combine (it
# replicates the full-batch (B, S, D) tensor and all-reduces it per layer —
# measured 17 GB x 94 layers on qwen3).  The production path therefore
# expresses expert parallelism explicitly: tokens are routed locally within
# each data shard, dispatched to expert-owning model shards with a single
# all_to_all, processed, and returned with the inverse all_to_all.  This is
# the minimal-bytes collective schedule (2 x dispatched-token bytes per
# layer) — the paper's "minimize exchanged bytes" insight applied to MoE.

def _moe_shard_body(x, router, w_gate, w_up, w_down, *, cfg: ArchConfig,
                    ep: int, fsdp_axes, model_axis: str):
    """Runs per-device inside shard_map.

    x: (B_loc, S/ep, D) — batch sharded over the data axes AND sequence
    sharded over the model axis, so every device routes a disjoint token
    slice (routing replicated over model would multiply dispatch bytes and
    expert FLOPs by ep — measured 16x on qwen3 before this layout).
    router: (D, E) replicated.  w_*: (E/ep, D, F) local expert blocks.
    """
    import jax

    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    e_loc = e // ep
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, -1, keepdims=True), 1e-9)
    gate = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], topk_i].set(topk_p)

    c = capacity(cfg, t)
    w_ec, idx_ec = jax.lax.top_k(gate.T, c)            # (E, C) local tokens
    live = w_ec > 0.0
    xe = xt[idx_ec]                                    # (E, C, D) local gather
    xe = xe * live[..., None].astype(xe.dtype)

    # dispatch: (E, C, D) -> (ep, e_loc, C, D) --a2a--> (peer, e_loc, C, D)
    # (all_to_all with split_axis=concat_axis=0 is the self-inverse
    # "transpose over the mesh axis" — verified in tests)
    xa = xe.reshape(ep, e_loc, c, d)
    xa = jax.lax.all_to_all(xa, model_axis, split_axis=0, concat_axis=0)
    xa = xa.transpose(1, 0, 2, 3).reshape(e_loc, ep * c, d)

    g = jnp.einsum("ecd,edf->ecf", xa, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xa, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xa.dtype) * u
    ya = jnp.einsum("ecf,efd->ecd", h, w_down)         # (e_loc, ep*C, D)

    # return: inverse all_to_all -> (E, C, D) back on the owning data shard
    ya = ya.reshape(e_loc, ep, c, d).transpose(1, 0, 2, 3)
    ye = jax.lax.all_to_all(ya, model_axis, split_axis=0, concat_axis=0)
    ye = ye.reshape(e, c, d)
    ye = ye * (w_ec * live.astype(jnp.float32))[..., None].astype(ye.dtype)

    y = jnp.zeros((t, d), ye.dtype).at[idx_ec.reshape(-1)].add(
        ye.reshape(e * c, d))

    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(
        jnp.zeros((t, e), jnp.float32).at[
            jnp.arange(t)[:, None], topk_i].set(1.0), axis=0)
    aux = m.router_aux_weight * e * jnp.sum(me * fe)
    aux = jax.lax.pmean(aux, (model_axis,) + tuple(fsdp_axes))
    return y.reshape(b, s, d), aux


def _moe_dense_decode_body(x, router, w_gate, w_up, w_down, *,
                           cfg: ArchConfig, ep: int, model_axis: str,
                           fsdp_axes=()):
    """Tiny-token path (decode): every model shard runs its local experts
    densely over all local tokens and psums the gated partials — cheaper
    than any dispatch when tokens-per-device is O(1)."""
    import jax

    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    e_loc = e // ep
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, -1, keepdims=True), 1e-9)
    gate = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], topk_i].set(topk_p)
    eidx = jax.lax.axis_index(model_axis) * e_loc + jnp.arange(e_loc)
    gate_loc = gate[:, eidx]                               # (T, e_loc)

    g = jnp.einsum("td,edf->tef", xt, w_gate)
    u = jnp.einsum("td,edf->tef", xt, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
    ye = jnp.einsum("tef,efd->ted", h, w_down)             # (T, e_loc, D)
    y = jnp.einsum("ted,te->td", ye.astype(jnp.float32), gate_loc)
    y = jax.lax.psum(y, model_axis).astype(x.dtype)

    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(
        jnp.zeros((t, e), jnp.float32).at[
            jnp.arange(t)[:, None], topk_i].set(1.0), axis=0)
    aux = m.router_aux_weight * e * jnp.sum(me * fe)
    aux = jax.lax.pmean(aux, (model_axis,) + tuple(fsdp_axes))
    return y.reshape(b, s, d), aux


def moe_apply_ep(params, cfg: ArchConfig, x: Array) -> Tuple[Array, Array]:
    """Expert-parallel MoE via shard_map; requires an active
    activation_sharding context with a mesh that has a 'model' axis dividing
    n_experts.  Falls back to the GSPMD grouped path otherwise."""
    import functools

    import jax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map_compat
    from repro.distributed import sharding as shlib

    active = getattr(shlib._ACTIVE, "v", None)
    if active is None:
        return moe_apply(params, cfg, x)
    mesh, _ = active
    if "model" not in mesh.shape or cfg.moe.n_experts % mesh.shape["model"]:
        return moe_apply(params, cfg, x)

    ep = mesh.shape["model"]
    fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    b, s, d = x.shape
    # expert weights enter sharded over (experts=model, embed=fsdp); the
    # body receives the fsdp-gathered block (XLA inserts the all-gather at
    # the shard_map boundary, once per layer scan step).
    w_spec = P("model", None, None)

    if s % ep != 0:
        # decode / tiny sequences: dense-local-experts + psum
        body = functools.partial(
            _moe_dense_decode_body, cfg=cfg, ep=ep, model_axis="model",
            fsdp_axes=fsdp_axes)
        spec = P(fsdp_axes, None, None)
        return shard_map_compat(
            body, mesh=mesh,
            in_specs=(spec, P(None, None), w_spec, w_spec, w_spec),
            out_specs=(spec, P()),
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])

    body = functools.partial(
        _moe_shard_body, cfg=cfg, ep=ep, fsdp_axes=fsdp_axes,
        model_axis="model")
    # tokens: batch over data axes, sequence over model — disjoint routing
    seq_spec = P(fsdp_axes, "model", None)
    out = shard_map_compat(
        body, mesh=mesh,
        in_specs=(seq_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(seq_spec, P()),
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return out
