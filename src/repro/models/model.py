"""Model assembly: builds every assigned architecture family from ArchConfig.

Families and their block stacks:
  dense   — [GQA|MLA attention + SwiGLU MLP] x L, scanned over layers
  moe     — [GQA attention + MoE FFN] x L, scanned
  ssm     — xLSTM: segments of (slstm_every-1) mLSTM blocks + 1 sLSTM block
  hybrid  — zamba2: Mamba2 blocks with one *shared* attention block applied
            every ``shared_attn_every`` layers (weight re-use)
  audio   — hubert: encoder-only bidirectional attention + GeLU MLP; the conv
            frontend is a stub — inputs are precomputed frame embeddings
  vlm     — llava: Mistral decoder over [patch-embedding prefix ++ tokens];
            the vision tower is a stub — inputs are precomputed anyres patch
            embeddings

Layers are stacked and scanned (jax.lax.scan) with configurable remat policy:
essential for HLO size / compile time at 94 layers, and the unit the
dry-run's roofline reads.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import (
    NORM_FNS,
    NORM_SPECS,
    gelu_mlp,
    gelu_mlp_spec,
    swiglu,
    swiglu_spec,
)
from repro.models.params import ParamSpec, is_spec

Array = jax.Array


def _stack_specs(spec_tree, n: int):
    """Add a leading scanned-layers dim to every ParamSpec leaf."""
    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.dtype,
                         s.init, s.scale)
    return jax.tree_util.tree_map(one, spec_tree, is_leaf=is_spec)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if policy == "dots+moe":
        # §Perf hc-qwen-1: additionally save the MoE block output so the
        # backward pass does NOT re-execute the expert-parallel shard_map
        # (its all_to_all + FSDP weight gathers were re-issued during
        # rematerialization — measured 3x the forward collective bill).
        pol = jax.checkpoint_policies.save_from_both_policies(
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            jax.checkpoint_policies.save_only_these_names("moe_out"),
        )
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full"


# ---------------------------------------------------------------------------
# Decoder/encoder transformer block (dense / moe / audio / vlm)
# ---------------------------------------------------------------------------

def _block_spec(cfg: ArchConfig):
    spec: Dict[str, Any] = {
        "ln1": NORM_SPECS[cfg.norm](cfg.d_model),
        "ln2": NORM_SPECS[cfg.norm](cfg.d_model),
    }
    if cfg.attention == "gqa":
        spec["attn"] = attn_mod.gqa_spec(cfg)
    elif cfg.attention == "mla":
        spec["attn"] = attn_mod.mla_spec(cfg)
    if cfg.moe is not None:
        spec["ffn"] = moe_mod.moe_spec(cfg)
    elif cfg.family == "audio":
        spec["ffn"] = gelu_mlp_spec(cfg.d_model, cfg.d_ff)
    else:
        spec["ffn"] = swiglu_spec(cfg.d_model, cfg.d_ff)
    return spec


def _block_apply(params, cfg: ArchConfig, x, positions, cache=None,
                 cache_index=None, length_mask=None, backend="chunked"):
    norm = NORM_FNS[cfg.norm]
    attn_fn = attn_mod.gqa_apply if cfg.attention == "gqa" else (
        attn_mod.mla_apply)
    h, new_cache = attn_fn(
        params["attn"], cfg, norm(params["ln1"], x), positions,
        cache=cache, cache_index=cache_index, length_mask=length_mask,
        backend=backend,
    )
    x = x + h
    z = norm(params["ln2"], x)
    aux = jnp.float32(0.0)
    if cfg.moe is not None:
        f, aux = moe_mod.moe_apply_ep(params["ffn"], cfg, z)
        from jax.ad_checkpoint import checkpoint_name
        f = checkpoint_name(f, "moe_out")
    elif cfg.family == "audio":
        f = gelu_mlp(params["ffn"], z)
    else:
        f = swiglu(params["ffn"], z)
    return x + f, new_cache, aux


# ---------------------------------------------------------------------------
# Model spec + apply
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    spec: Any                 # pytree of ParamSpec

    # logits over the full input sequence (training / prefill-no-cache)
    def logits(self, params, batch: Dict[str, Array],
               backend: str = "chunked", remat: str = "dots") -> Array:
        return _forward(params, self.cfg, batch, backend, remat)

    def prefill(self, params, batch, cache):
        return _prefill(params, self.cfg, batch, cache)

    def decode_step(self, params, tokens, cache, index, length_mask):
        return _decode(params, self.cfg, tokens, cache, index, length_mask)

    def init_cache(self, batch: int, max_len: int):
        return _init_cache(self.cfg, batch, max_len)


def build_model(cfg: ArchConfig) -> Model:
    d, v = cfg.d_model, cfg.padded_vocab
    spec: Dict[str, Any] = {}
    if cfg.family == "audio":
        spec["frontend"] = {
            "w": ParamSpec((cfg.frontend_dim, d), ("frontend", "embed"))
        }
        spec["embed"] = {"w": ParamSpec((v, d), ("vocab", "embed"))}
    elif cfg.family == "vlm":
        spec["embed"] = {"w": ParamSpec((v, d), ("vocab", "embed"))}
        spec["frontend"] = {
            "w": ParamSpec((cfg.frontend_dim, d), ("frontend", "embed"))
        }
    else:
        spec["embed"] = {"w": ParamSpec((v, d), ("vocab", "embed"))}

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        spec["blocks"] = _stack_specs(_block_spec(cfg), cfg.n_layers)
    elif cfg.family == "ssm":      # xLSTM
        xc = cfg.xlstm
        n_seg = cfg.n_layers // xc.slstm_every
        spec["mlstm"] = _stack_specs(
            _stack_specs(xl.mlstm_spec(cfg), xc.slstm_every - 1), n_seg)
        spec["slstm"] = _stack_specs(xl.slstm_spec(cfg), n_seg)
        spec["ln_m"] = _stack_specs(
            _stack_specs(NORM_SPECS[cfg.norm](d), xc.slstm_every - 1), n_seg)
        spec["ln_s"] = _stack_specs(NORM_SPECS[cfg.norm](d), n_seg)
    elif cfg.family == "hybrid":   # zamba2
        k = cfg.shared_attn_every
        n_full, rem = divmod(cfg.n_layers, k)
        spec["mamba"] = _stack_specs(
            _stack_specs(m2.mamba2_spec(cfg), k), n_full)
        spec["ln_mamba"] = _stack_specs(
            _stack_specs(NORM_SPECS[cfg.norm](d), k), n_full)
        if rem:
            spec["mamba_tail"] = _stack_specs(m2.mamba2_spec(cfg), rem)
            spec["ln_tail"] = _stack_specs(NORM_SPECS[cfg.norm](d), rem)
        spec["shared_attn"] = _block_spec(cfg)  # ONE set of weights, reused
    else:
        raise ValueError(cfg.family)

    spec["ln_f"] = NORM_SPECS[cfg.norm](d)
    if not cfg.tie_embeddings:
        spec["head"] = {"w": ParamSpec((d, v), ("embed", "vocab"))}
    return Model(cfg=cfg, spec=spec)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

ACT = ("batch", "seq_act", "embed_act")


def _embed_inputs(params, cfg: ArchConfig, batch) -> Array:
    if cfg.family == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["frames"],
                       params["frontend"]["w"])
        return constrain(x, ACT)
    emb = params["embed"]["w"]
    x = emb[batch["tokens"]]
    if cfg.family == "vlm":
        p = jnp.einsum("bnf,fd->bnd", batch["patches"],
                       params["frontend"]["w"])
        x = jnp.concatenate([p, x], axis=1)
    return constrain(x, ACT)


def _head(params, cfg: ArchConfig, x: Array) -> Array:
    x = NORM_FNS[cfg.norm](params["ln_f"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"])
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return constrain(logits, ("batch", "seq_act", "vocab_act"))


def _forward(params, cfg: ArchConfig, batch, backend: str, remat: str
             ) -> Array:
    x = _embed_inputs(params, cfg, batch)
    b, s, d = x.shape
    positions = jnp.arange(s)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(carry, layer_params):
            y, _, aux = _block_apply(layer_params, cfg, carry, positions,
                                     backend=backend)
            return constrain(y, ACT), aux

        x, _ = jax.lax.scan(_remat(body, remat), x, params["blocks"])
    elif cfg.family == "ssm":
        def seg(carry, seg_params):
            mp, sp, lm, ls = seg_params

            def m_body(c, lp):
                blk, ln = lp
                h, _ = xl.mlstm_apply(blk, cfg, NORM_FNS[cfg.norm](ln, c))
                return constrain(c + h, ACT), None

            carry, _ = jax.lax.scan(_remat(m_body, remat), carry, (mp, lm))
            h, _ = xl.slstm_apply(sp, cfg, NORM_FNS[cfg.norm](ls, carry))
            return constrain(carry + h, ACT), None

        x, _ = jax.lax.scan(
            seg, x,
            (params["mlstm"], params["slstm"], params["ln_m"],
             params["ln_s"]),
        )
    elif cfg.family == "hybrid":
        def group(carry, gp):
            mp, ln = gp

            def m_body(c, lp):
                blk, lnp = lp
                h, _ = m2.mamba2_apply(blk, cfg, NORM_FNS[cfg.norm](lnp, c))
                return constrain(c + h, ACT), None

            carry, _ = jax.lax.scan(_remat(m_body, remat), carry, (mp, ln))
            y, _, _ = _block_apply(params["shared_attn"], cfg, carry,
                                   positions, backend=backend)
            return constrain(y, ACT), None

        x, _ = jax.lax.scan(group, x,
                            (params["mamba"], params["ln_mamba"]))
        if "mamba_tail" in params:
            def t_body(c, lp):
                blk, lnp = lp
                h, _ = m2.mamba2_apply(blk, cfg, NORM_FNS[cfg.norm](lnp, c))
                return c + h, None

            x, _ = jax.lax.scan(
                _remat(t_body, remat), x,
                (params["mamba_tail"], params["ln_tail"]))
    else:
        raise ValueError(cfg.family)

    return _head(params, cfg, x)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _init_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attention == "mla":
            m = cfg.mla
            return jnp.zeros(
                (cfg.n_layers, batch, max_len,
                 m.kv_lora_rank + m.qk_rope_head_dim), jnp.bfloat16)
        hd = cfg.hd
        return (
            jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd),
                      jnp.bfloat16),
            jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd),
                      jnp.bfloat16),
        )
    if cfg.family == "ssm":
        xc = cfg.xlstm
        n_seg = cfg.n_layers // xc.slstm_every
        ml = xl.mlstm_init_state(cfg, batch)
        ml = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None, None],
                (n_seg, xc.slstm_every - 1) + a.shape).copy(), ml)
        sl = xl.slstm_init_state(cfg, batch)
        sl = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_seg,) + a.shape).copy(),
            sl)
        return {"mlstm": ml, "slstm": sl}
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        n_full, rem = divmod(cfg.n_layers, k)
        ms = m2.init_state(cfg, batch)
        groups = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None, None], (n_full, k) + a.shape).copy(), ms)
        hd = cfg.hd
        attn = (
            jnp.zeros((n_full, batch, cfg.n_kv_heads, max_len, hd),
                      jnp.bfloat16),
            jnp.zeros((n_full, batch, cfg.n_kv_heads, max_len, hd),
                      jnp.bfloat16),
        )
        out = {"mamba": groups, "attn": attn}
        if rem:
            out["mamba_tail"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (rem,) + a.shape).copy(),
                ms)
        return out
    raise ValueError(f"no cache for family {cfg.family}")


def _prefill(params, cfg: ArchConfig, batch, cache):
    """Run the full prompt, filling the cache; returns (last_logits, cache)."""
    x = _embed_inputs(params, cfg, batch)
    b, s, d = x.shape
    positions = jnp.arange(s)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, inp):
            layer_params, layer_cache = inp
            y, new_c, _ = _block_apply(
                layer_params, cfg, carry, positions,
                cache=layer_cache, cache_index=0)
            return y, new_c

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return _head(params, cfg, x[:, -1:]), new_cache

    if cfg.family == "ssm":
        def seg(carry, inp):
            (mp, sp, lm, ls), (mc, sc) = inp

            def m_body(c, lp):
                (blk, ln), st = lp
                h, st2 = xl.mlstm_apply(blk, cfg, NORM_FNS[cfg.norm](ln, c),
                                        state=st)
                return c + h, st2

            carry, mc2 = jax.lax.scan(m_body, carry, ((mp, lm), mc))
            h, sc2 = xl.slstm_apply(sp, cfg, NORM_FNS[cfg.norm](ls, carry),
                                    state=sc)
            return carry + h, (mc2, sc2)

        x, (mc, sc) = jax.lax.scan(
            seg, x,
            ((params["mlstm"], params["slstm"], params["ln_m"],
              params["ln_s"]),
             (cache["mlstm"], cache["slstm"])))
        return _head(params, cfg, x[:, -1:]), {"mlstm": mc, "slstm": sc}

    if cfg.family == "hybrid":
        def group(carry, inp):
            (mp, ln), mst, ac = inp

            def m_body(c, lp):
                (blk, lnp), st = lp
                h, st2 = m2.mamba2_apply(blk, cfg,
                                         NORM_FNS[cfg.norm](lnp, c), state=st)
                return c + h, st2

            carry, mst2 = jax.lax.scan(m_body, carry, ((mp, ln), mst))
            y, ac2, _ = _block_apply(params["shared_attn"], cfg, carry,
                                     positions, cache=ac, cache_index=0)
            return y, (mst2, ac2)

        x, (mst, ac) = jax.lax.scan(
            group, x,
            ((params["mamba"], params["ln_mamba"]), cache["mamba"],
             cache["attn"]))
        new_cache = {"mamba": mst, "attn": ac}
        if "mamba_tail" in params:
            def t_body(c, lp):
                (blk, lnp), st = lp
                h, st2 = m2.mamba2_apply(blk, cfg,
                                         NORM_FNS[cfg.norm](lnp, c), state=st)
                return c + h, st2

            x, tst = jax.lax.scan(
                t_body, x,
                ((params["mamba_tail"], params["ln_tail"]),
                 cache["mamba_tail"]))
            new_cache["mamba_tail"] = tst
        return _head(params, cfg, x[:, -1:]), new_cache

    raise ValueError(cfg.family)


def _decode(params, cfg: ArchConfig, tokens, cache, index, length_mask):
    """One autoregressive step.  tokens: (B, 1); index: scalar write offset."""
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        # decode beyond the image prefix: plain token embedding
        x = params["embed"]["w"][tokens]
    else:
        x = _embed_inputs(params, cfg, batch)
    positions = jnp.full((1,), index)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, inp):
            layer_params, layer_cache = inp
            y, new_c, _ = _block_apply(
                layer_params, cfg, carry, positions,
                cache=layer_cache, cache_index=index,
                length_mask=length_mask)
            return y, new_c

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return _head(params, cfg, x), new_cache

    if cfg.family == "ssm":
        def seg(carry, inp):
            (mp, sp, lm, ls), (mc, sc) = inp

            def m_body(c, lp):
                (blk, ln), st = lp
                h, st2 = xl.mlstm_apply(blk, cfg, NORM_FNS[cfg.norm](ln, c),
                                        state=st)
                return c + h, st2

            carry, mc2 = jax.lax.scan(m_body, carry, ((mp, lm), mc))
            h, sc2 = xl.slstm_apply(sp, cfg, NORM_FNS[cfg.norm](ls, carry),
                                    state=sc)
            return carry + h, (mc2, sc2)

        x, (mc, sc) = jax.lax.scan(
            seg, x,
            ((params["mlstm"], params["slstm"], params["ln_m"],
              params["ln_s"]),
             (cache["mlstm"], cache["slstm"])))
        return _head(params, cfg, x), {"mlstm": mc, "slstm": sc}

    if cfg.family == "hybrid":
        def group(carry, inp):
            (mp, ln), mst, ac = inp

            def m_body(c, lp):
                (blk, lnp), st = lp
                h, st2 = m2.mamba2_apply(blk, cfg,
                                         NORM_FNS[cfg.norm](lnp, c), state=st)
                return c + h, st2

            carry, mst2 = jax.lax.scan(m_body, carry, ((mp, ln), mst))
            y, ac2, _ = _block_apply(params["shared_attn"], cfg, carry,
                                     positions, cache=ac, cache_index=index,
                                     length_mask=length_mask)
            return y, (mst2, ac2)

        x, (mst, ac) = jax.lax.scan(
            group, x,
            ((params["mamba"], params["ln_mamba"]), cache["mamba"],
             cache["attn"]))
        new_cache = {"mamba": mst, "attn": ac}
        if "mamba_tail" in params:
            def t_body(c, lp):
                (blk, lnp), st = lp
                h, st2 = m2.mamba2_apply(blk, cfg,
                                         NORM_FNS[cfg.norm](lnp, c), state=st)
                return c + h, st2

            x, tst = jax.lax.scan(
                t_body, x,
                ((params["mamba_tail"], params["ln_tail"]),
                 cache["mamba_tail"]))
            new_cache["mamba_tail"] = tst
        return _head(params, cfg, x), new_cache

    raise ValueError(cfg.family)
