"""Attention variants: GQA (chunked-flash + decode) and MLA.

Two execution paths share one math definition:

* ``chunked`` — lax.scan over KV blocks with online softmax; memory-bounded,
  lowers on every backend — this is the dry-run/default path, and on TPU it
  compiles to the same blocked dataflow a hand-written kernel would use.
* ``pallas``  — repro.kernels.flash_attention, the TPU kernel (validated
  against the reference in interpret mode); selected via ``backend=``.

Decode (single query token against a long, possibly sequence-sharded KV
cache) uses a single-shot softmax so GSPMD can keep the cache sharded along
sequence and insert the partial-softmax all-reduces automatically.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.layers import rope
from repro.models.params import ParamSpec

# q: shard heads over "model" when divisible, else fall back to sharding the
# query sequence (sequence-parallel attention).  k/v stay on their kv-head
# sharding (or replicated) so the KV-block scan never slices across shards.
Q_ACT = ("batch", "heads_act", "qseq_act", None)
KV_ACT = ("batch", "kv_heads", None, None)

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA head grouping
# ---------------------------------------------------------------------------

def _group_heads(q: Array, n_kv: int) -> Array:
    """(B, Hq, S, hd) -> (B, Hkv, G, S, hd)."""
    b, hq, s, hd = q.shape
    return q.reshape(b, n_kv, hq // n_kv, s, hd)


def sdpa_chunked(
    q: Array,           # (B, Hq, Sq, hd)
    k: Array,           # (B, Hkv, Skv, hd)
    v: Array,           # (B, Hkv, Skv, hdv)
    causal: bool,
    q_offset: int = 0,
    chunk: int = 512,
    scale: Optional[float] = None,
) -> Array:
    """Online-softmax attention, scanning KV in blocks (flash-style)."""
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    hdv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5

    chunk = min(chunk, skv)
    n_chunks = skv // chunk
    rem = skv - n_chunks * chunk
    assert rem == 0, f"Skv={skv} not divisible by chunk={chunk}"

    qg = _group_heads(q, hkv) * jnp.asarray(scale, q.dtype)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, start = inputs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc).astype(jnp.float32)
        if causal:
            k_pos = start + jnp.arange(chunk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    kc = k.reshape(b, hkv, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, hdv).transpose(2, 0, 1, 3, 4)
    starts = jnp.arange(n_chunks) * chunk

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, hdv).astype(q.dtype)


def sdpa_decode(
    q: Array,           # (B, Hq, 1, hd)
    k: Array,           # (B, Hkv, S, hd)
    v: Array,           # (B, Hkv, S, hdv)
    length_mask: Array, # (B, S) bool — valid cache positions
    scale: Optional[float] = None,
) -> Array:
    """Single-shot decode attention; keeps a sequence-sharded cache sharded."""
    b, hq, _, hd = q.shape
    hkv = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    qg = _group_heads(q, hkv) * jnp.asarray(scale, q.dtype)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k).astype(jnp.float32)
    s = jnp.where(length_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(b, hq, 1, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def gqa_spec(cfg: ArchConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": ParamSpec((d, hq, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((hq, hd, d), ("heads", "head_dim", "embed")),
    }


def gqa_apply(
    params,
    cfg: ArchConfig,
    x: Array,                     # (B, S, D)
    positions: Array,             # (S,) or (B, S)
    cache: Optional[Tuple[Array, Array]] = None,   # (k, v): (B, Hkv, T, hd)
    cache_index: Optional[Array] = None,           # scalar int — write offset
    length_mask: Optional[Array] = None,           # (B, T) for decode
    backend: str = "chunked",
    chunk: int = 512,
):
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"])
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, Q_ACT)
    k = constrain(k, KV_ACT)
    v = constrain(v, KV_ACT)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, 0, cache_index, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, 0, cache_index, 0))
        new_cache = (ck, cv)
        if s == 1:  # decode
            out = sdpa_decode(q, ck, cv, length_mask)
        else:       # prefill into cache
            out = sdpa_chunked(q, k, v, cfg.causal, q_offset=0, chunk=chunk)
    else:
        if backend == "pallas":
            from repro.kernels import ops as kernel_ops

            out = kernel_ops.flash_attention_bhsd(q, k, v, causal=cfg.causal)
        else:
            out = sdpa_chunked(q, k, v, cfg.causal, chunk=chunk)
    y = jnp.einsum("bhsk,hkd->bsd", out, params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def mla_spec(cfg: ArchConfig):
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_down": ParamSpec((d, m.q_lora_rank), ("embed", "lora")),
        "wq_up": ParamSpec((m.q_lora_rank, h, qk_hd),
                           ("lora", "heads", "head_dim")),
        "wkv_down": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                              ("embed", "lora")),
        "wk_up": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim),
                           ("lora", "heads", "head_dim")),
        "wv_up": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                           ("lora", "heads", "head_dim")),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def mla_apply(
    params,
    cfg: ArchConfig,
    x: Array,
    positions: Array,
    cache: Optional[Array] = None,          # latent cache (B, T, r + rope_hd)
    cache_index: Optional[Array] = None,
    length_mask: Optional[Array] = None,
    backend: str = "chunked",
    chunk: int = 512,
):
    """MLA: the KV cache stores only the compressed latent (the paper-analogue
    'small slowly-varying state'), up-projected per use."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    r = m.kv_lora_rank

    cq = jnp.einsum("bsd,dr->bsr", x, params["wq_down"])
    q = jnp.einsum("bsr,rhk->bhsk", cq, params["wq_up"])
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_down"])  # (B,S,r+rope)
    latent, k_rope_flat = ckv[..., :r], ckv[..., r:]
    k_rope = rope(k_rope_flat[:, None], positions, cfg.rope_theta)  # (B,1,S,rp)

    new_cache = None
    if cache is not None:
        packed = jnp.concatenate(
            [latent, k_rope[:, 0]], axis=-1
        )  # (B, S, r+rope)
        cache = jax.lax.dynamic_update_slice(
            cache, packed.astype(cache.dtype), (0, cache_index, 0)
        )
        new_cache = cache
        latent_all = cache[..., :r].astype(x.dtype)
        k_rope_all = cache[:, None, :, r:].astype(x.dtype)
    else:
        latent_all, k_rope_all = latent, k_rope

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if s == 1 and new_cache is not None:
        # §Perf hc-mla-2: absorbed decode.  Fold wk_up into the query and
        # wv_up into the output so attention runs directly against the
        # compressed latent cache — K/V are never materialized (the naive
        # path reconstructs (B, H, T, 160) per layer per token: ~3.3 GB of
        # traffic per layer at 32k, measured).  This is the latent-space
        # analogue of the paper's "use the received buffer directly".
        t = latent_all.shape[1]
        q_abs = jnp.einsum("bhsk,rhk->bhsr", q_nope, params["wk_up"])
        s_nope = jnp.einsum("bhsr,btr->bhst", q_abs, latent_all)
        s_rope = jnp.einsum("bhsk,btk->bhst", q_rope,
                            cache[:, :, r:].astype(x.dtype))
        logits_att = (s_nope + s_rope).astype(jnp.float32) * scale
        lm = length_mask if length_mask is not None else jnp.ones(
            (b, t), jnp.bool_)
        logits_att = jnp.where(lm[:, None, None, :], logits_att, NEG_INF)
        probs = jax.nn.softmax(logits_att, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhst,btr->bhsr", probs, latent_all)
        out = jnp.einsum("bhsr,rhk->bhsk", ctx, params["wv_up"])
        y = jnp.einsum("bhsk,hkd->bsd", out, params["wo"])
        return y, new_cache

    k_nope = jnp.einsum("btr,rhk->bhtk", latent_all, params["wk_up"])
    vv = jnp.einsum("btr,rhk->bhtk", latent_all, params["wv_up"])
    t = latent_all.shape[1]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all, (b, h, t, m.qk_rope_head_dim))],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_full = constrain(q_full, Q_ACT)
    if cache is not None:
        # decode/prefill: the up-projected K/V inherit the latent cache's
        # sequence sharding (heads 40 don't divide the model axis; forcing
        # head/replicated layout here all-gathered 2 GB x 62 layers of
        # reconstructed KV per decode step — §Perf hc-mla-1)
        k_full = constrain(k_full, ("batch", None, "qseq_act", None))
        vv = constrain(vv, ("batch", None, "qseq_act", None))
    else:
        k_full = constrain(k_full, ("batch", "heads_act", None, None))
        vv = constrain(vv, ("batch", "heads_act", None, None))

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if s == 1 and cache is not None:
        lm = length_mask if length_mask is not None else jnp.ones(
            (b, t), jnp.bool_
        )
        out = sdpa_decode(q_full, k_full, vv, lm, scale=scale)
    else:
        out = sdpa_chunked(q_full, k_full, vv, cfg.causal, chunk=chunk,
                           scale=scale)
    y = jnp.einsum("bhsk,hkd->bsd", out, params["wo"])
    return y, new_cache
