"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, sequential scan), following arXiv:2405.04517.

mLSTM is exponential-gated linear attention: state C (P x Pv matrix per
head), normalizer n, and a running log-stabilizer m.  We implement the
stabilized chunkwise-parallel form (the TPU-friendly formulation — compute
is dense matmuls over (L, L) chunk tiles plus an O(S/L) state scan), with a
single-step recurrence for decode.  sLSTM keeps per-head scalar memory with
block-diagonal recurrence and is scanned over time.

xlstm-1.3b assembles 48 blocks, every ``slstm_every``-th an sLSTM, the rest
mLSTM (the published 7:1 mixing).  Linear-time state makes the arch
``long_500k``-eligible.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import rmsnorm
from repro.models.params import ParamSpec

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: Array   # (B, H, P, Pv)
    n: Array   # (B, H, P)
    m: Array   # (B, H)
    conv: Array  # (B, dc-1, di)


def _mdims(cfg: ArchConfig):
    di = int(cfg.d_model * cfg.xlstm.proj_factor)
    h = cfg.n_heads
    p = di // h
    return di, h, p, cfg.xlstm.conv_kernel


def mlstm_spec(cfg: ArchConfig):
    d = cfg.d_model
    di, h, p, dc = _mdims(cfg)
    return {
        "w_up": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamSpec((dc, di), ("conv", "mlp"), jnp.float32, "scaled"),
        "conv_b": ParamSpec((di,), ("mlp",), jnp.float32, "zeros"),
        # block-diagonal per-head projections (official xLSTM BlockDiagonal).
        # §Perf hc-xlstm-7: replicated over "model" (4 MB each) — sharding
        # their output dim forced a per-layer (B,S,H,P) all-reduce in the
        # backward pass (1.07 GB x 42 measured); FSDP over "data" only.
        "wq": ParamSpec((h, p, p), ("heads", "head_dim", None)),
        "wk": ParamSpec((h, p, p), ("heads", "head_dim", None)),
        "wv": ParamSpec((h, p, p), ("heads", "head_dim", None)),
        "w_if": ParamSpec((di, 2 * h), ("mlp", "heads"), jnp.float32),
        "b_if": ParamSpec((2 * h,), ("heads",), jnp.float32, "zeros"),
        "lskip": ParamSpec((di,), ("mlp",), jnp.float32, "ones"),
        "norm_scale": ParamSpec((di,), ("mlp",), jnp.float32, "ones"),
        "w_down": ParamSpec((di, d), ("mlp", "embed")),
    }


def _causal_conv(params, u: Array, tail: Optional[Array]):
    dc = params["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], dc - 1, u.shape[-1]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)
    w = params["conv_w"].astype(u.dtype)
    out = sum(ext[:, i:i + u.shape[1]] * w[i][None, None] for i in range(dc))
    out = out + params["conv_b"].astype(u.dtype)
    new_tail = ext[:, -(dc - 1):] if dc > 1 else ext[:, :0]
    return jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype), new_tail


def mlstm_apply(
    params,
    cfg: ArchConfig,
    xin: Array,                    # (B, S, D)
    state: Optional[MLSTMState] = None,
    chunk: int = 256,
) -> Tuple[Array, Optional[MLSTMState]]:
    di, h, p, dc = _mdims(cfg)
    b, s, d = xin.shape

    up = jnp.einsum("bsd,de->bse", xin, params["w_up"])
    xi, gate = up[..., :di], up[..., di:]
    xc, new_tail = _causal_conv(params, xi,
                                state.conv if state is not None else None)

    xch = xc.reshape(b, s, h, p)
    xih = xi.reshape(b, s, h, p)
    q = jnp.einsum("bshp,hpq->bshq", xch, params["wq"]) * (p ** -0.5)
    k = jnp.einsum("bshp,hpq->bshq", xch, params["wk"])
    v = jnp.einsum("bshp,hpq->bshq", xih, params["wv"])
    # NOTE(perf/§Perf hc-xlstm-3): an earlier val_act->model constraint on v
    # triggered involuntary full rematerialization copies in the SPMD
    # partitioner (state-dim resharding against the chunk scan); batch/data
    # sharding alone is strictly better here.
    gates = jnp.einsum("bse,eg->bsg", xc.astype(jnp.float32), params["w_if"]
                       ) + params["b_if"]
    li = gates[..., :h]                                  # input gate (log)
    lf = jax.nn.log_sigmoid(gates[..., h:])              # forget gate (log)

    # §Perf hc-xlstm-2: keep q/k/v bf16 through the chunk scan — the scanned
    # xs and their backward dus-stacks are the dominant HBM term; f32
    # promotion happens only where the stabilized math needs it.
    qf = q.astype(jnp.float32)  # decode path still uses f32 directly
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if s == 1 and state is not None:
        m_new = jnp.maximum(state.m + lf[:, 0], li[:, 0])        # (B, H)
        decay = jnp.exp(state.m + lf[:, 0] - m_new)
        w_in = jnp.exp(li[:, 0] - m_new)
        c_new = state.c * decay[..., None, None] + jnp.einsum(
            "bhp,bhq->bhpq", kf[:, 0] * w_in[..., None], vf[:, 0]
        )
        n_new = state.n * decay[..., None] + kf[:, 0] * w_in[..., None]
        num = jnp.einsum("bhp,bhpq->bhq", qf[:, 0], c_new)
        den = jnp.abs(jnp.einsum("bhp,bhp->bh", qf[:, 0], n_new))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        y = (num / den).reshape(b, 1, di)
        new_state = MLSTMState(c=c_new, n=n_new, m=m_new, conv=new_tail)
    else:
        l = min(chunk, s)
        assert s % l == 0, f"S={s} %% chunk {l}"
        nc = s // l

        def rc(t):
            return t.reshape(b, nc, l, *t.shape[2:]).swapaxes(0, 1)

        q_c, k_c, v_c = rc(q), rc(k), rc(v)   # bf16 scan xs (hc-xlstm-2)
        li_c, lf_c = rc(li), rc(lf)

        c0 = (state.c if state is not None
              else jnp.zeros((b, h, p, p), jnp.float32))
        n0 = (state.n if state is not None
              else jnp.zeros((b, h, p), jnp.float32))
        m0 = (state.m if state is not None
              else jnp.full((b, h), -1e30, jnp.float32))

        tri = jnp.tril(jnp.ones((l, l), jnp.float32))

        def body(carry, inp):
            c, n, m = carry
            qc, kc, vc, lic, lfc = inp
            cum = jnp.cumsum(lfc, axis=1)                    # (B, L, H)
            total = cum[:, -1]                               # (B, H)
            # log survival of j's write at chunk end
            w_end = total[:, None] - cum + lic               # (B, L, H)
            m_c = jnp.max(w_end, axis=1)                     # (B, H)
            m_new = jnp.maximum(m + total, m_c)
            sc_old = jnp.exp(m + total - m_new)
            wj = jnp.exp(w_end - m_new[:, None])             # (B, L, H)
            # §Perf hc-xlstm-1: gates/state/stabilizers stay f32; the dense
            # chunk matmuls run on bf16 operands with f32 accumulation
            # (flash-attention-style) — halves chunk HBM traffic.
            qb, kb, vb = qc, kc, vc           # already bf16
            f32 = jnp.float32
            # XLA:CPU cannot execute bf16 x bf16 -> f32 dots (DotThunk);
            # accumulate in f32 on accelerators, bf16+cast on CPU.
            pe = f32 if jax.default_backend() != "cpu" else jnp.bfloat16
            kwj = kc.astype(f32) * wj[..., None]
            c_new = c * sc_old[..., None, None] + jnp.einsum(
                "blhp,blhq->bhpq", kwj.astype(jnp.bfloat16), vb,
                preferred_element_type=pe).astype(f32)
            n_new = n * sc_old[..., None] + jnp.einsum("blhp->bhp", kwj)
            # per-position stabilizers
            rel = cum[:, :, None, :] - cum[:, None, :, :] + lic[:, None]
            rel = jnp.where(tri[None, :, :, None] > 0, rel, -1e30)
            m_i = jnp.maximum(jnp.max(rel, axis=2), m[:, None] + cum)
            # intra-chunk
            sc_rel = jnp.exp(rel - m_i[:, :, None])          # (B,L,L,H)
            scores = jnp.einsum("blhp,bjhp->bljh", qb, kb,
                                preferred_element_type=pe).astype(f32)
            num_intra = jnp.einsum(
                "bljh,bjhq->blhq",
                (scores * sc_rel).astype(jnp.bfloat16), vb,
                preferred_element_type=pe).astype(f32)
            den_intra = jnp.einsum("bljh->blh", scores * sc_rel)
            # inter-chunk (old state)
            sc_i = jnp.exp(m[:, None] + cum - m_i)           # (B, L, H)
            num_inter = jnp.einsum(
                "blhp,bhpq->blhq", qb, c.astype(jnp.bfloat16),
                preferred_element_type=pe).astype(f32) * sc_i[..., None]
            den_inter = jnp.einsum("blhp,bhp->blh", qc.astype(f32), n) * sc_i
            num = num_intra + num_inter
            den = jnp.maximum(jnp.abs(den_intra + den_inter),
                              jnp.exp(-m_i))
            # bf16 chunk outputs: halves the scan's output-stacking traffic
            return (c_new, n_new, m_new), (
                num / den[..., None]).astype(jnp.bfloat16)

        (cf, nf, mf), y_c = jax.lax.scan(
            body, (c0, n0, m0), (q_c, k_c, v_c, li_c, lf_c))
        y = y_c.swapaxes(0, 1).reshape(b, s, di)
        new_state = (MLSTMState(c=cf, n=nf, m=mf, conv=new_tail)
                     if state is not None else None)

    y = y.astype(xin.dtype) + params["lskip"].astype(xin.dtype) * xc
    y = y * jax.nn.silu(gate.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y)
    out = jnp.einsum("bse,ed->bsd", y, params["w_down"])
    return out, new_state


def mlstm_init_state(cfg: ArchConfig, batch: int) -> MLSTMState:
    di, h, p, dc = _mdims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, h, p, p), jnp.float32),
        n=jnp.zeros((batch, h, p), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        conv=jnp.zeros((batch, dc - 1, di), jnp.bfloat16),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: Array   # (B, di)
    n: Array   # (B, di)
    h: Array   # (B, di)
    m: Array   # (B, di)


def slstm_spec(cfg: ArchConfig):
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    return {
        "w_gates": ParamSpec((d, 4 * d), ("embed", "mlp")),
        # §Perf hc-xlstm-4: recurrent matrix REPLICATED (8 MB) — sharding it
        # over "model" forced an all-reduce every timestep of the sequential
        # scan (2.1 MB x 24576 steps measured)
        "r_gates": ParamSpec((h, p, 4 * p), ("heads", "head_dim", None),
                             jnp.float32, "scaled"),
        "b_gates": ParamSpec((4 * d,), ("mlp",), jnp.float32, "zeros"),
        "norm_scale": ParamSpec((d,), ("embed",), jnp.float32, "ones"),
        "w_mlp_in": ParamSpec((d, 2 * d), ("embed", "mlp")),
        "w_mlp_out": ParamSpec((d, d), ("mlp", "embed")),
    }


def slstm_apply(
    params,
    cfg: ArchConfig,
    xin: Array,
    state: Optional[SLSTMState] = None,
) -> Tuple[Array, Optional[SLSTMState]]:
    b, s, d = xin.shape
    h = cfg.n_heads
    p = d // h

    gx = jnp.einsum("bsd,dg->bsg", xin.astype(jnp.float32),
                    params["w_gates"].astype(jnp.float32)
                    ) + params["b_gates"]

    if state is None:
        st = SLSTMState(
            c=jnp.zeros((b, d), jnp.float32),
            n=jnp.zeros((b, d), jnp.float32),
            h=jnp.zeros((b, d), jnp.float32),
            m=jnp.full((b, d), -1e30, jnp.float32),
        )
    else:
        st = state

    r = params["r_gates"]                                   # (H, P, 4P)

    def step(carry: SLSTMState, g_t: Array):
        # NOTE(§Perf hc-xlstm-8, REFUTED): pinning the carry sharding per
        # step forced a reshard inside the checkpointed segment and doubled
        # both memory and collective terms — per-step constraints inside
        # scan bodies fight the partitioner; leave the carry layout to
        # propagation.
        hh = carry.h.reshape(b, h, p)
        gr = jnp.einsum("bhp,hpq->bhq", hh, r)              # (B, H, 4P)
        z_r, i_r, f_r, o_r = jnp.split(gr, 4, axis=-1)      # (B, H, P)
        g = g_t.reshape(b, 4, d)
        z = jnp.tanh(g[:, 0] + z_r.reshape(b, d))
        li = g[:, 1] + i_r.reshape(b, d)                    # log input gate
        lf = jax.nn.log_sigmoid(g[:, 2] + f_r.reshape(b, d))
        o = jax.nn.sigmoid(g[:, 3] + o_r.reshape(b, d))
        m_new = jnp.maximum(lf + carry.m, li)
        ig = jnp.exp(li - m_new)
        fg = jnp.exp(lf + carry.m - m_new)
        c_new = fg * carry.c + ig * z
        n_new = fg * carry.n + ig
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return SLSTMState(c=c_new, n=n_new, h=h_new, m=m_new), h_new

    gx_t = gx.swapaxes(0, 1)                                # (S, B, 4d)
    # §Perf hc-xlstm-5: segment-checkpointed recurrence — the backward pass
    # of a flat 4096-step scan stacks every per-step intermediate (the
    # dominant HBM term, measured 1.65 TB/device); checkpointing 64-step
    # segments saves only the (B, d) boundary states and recomputes inside.
    seg = 64
    if s % seg == 0 and s > seg:
        gseg = gx_t.reshape(s // seg, seg, b, 4 * d)

        @jax.checkpoint
        def outer(carry, g):
            return jax.lax.scan(step, carry, g)

        st_f, hs = jax.lax.scan(outer, st, gseg)
        hs = hs.reshape(s, b, d)
    else:
        st_f, hs = jax.lax.scan(step, st, gx_t)
    y = hs.swapaxes(0, 1).astype(xin.dtype)                 # (B, S, d)
    y = rmsnorm({"scale": params["norm_scale"]}, y)
    u = jnp.einsum("bsd,de->bse", y, params["w_mlp_in"])
    u1, u2 = jnp.split(u, 2, axis=-1)                       # GeGLU halves
    z = jax.nn.gelu(u1.astype(jnp.float32)).astype(u2.dtype) * u2
    out = jnp.einsum("bse,ed->bsd", z, params["w_mlp_out"])
    new_state = st_f if state is not None else None
    return out, new_state


def slstm_init_state(cfg: ArchConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    return SLSTMState(
        c=jnp.zeros((batch, d), jnp.float32),
        n=jnp.zeros((batch, d), jnp.float32),
        h=jnp.zeros((batch, d), jnp.float32),
        m=jnp.full((batch, d), -1e30, jnp.float32),
    )
