"""Jaxpr auditor: trace the step body, walk the equations, flag hazards.

``jax.make_jaxpr(..., axis_env=[("sx", 2), ...])`` traces a sharded step
body — collectives included — on any host, with zero devices of the target
mesh: the audit inspects exactly the program the engine will run, without
running it.  The auditor feeds the engine's ``local_step`` a synthetic
all-zeros :class:`SimState` shaped like one device's shard and then walks
every equation (recursing into scan/cond/pjit sub-jaxprs) checking:

* **collective-matching** — every ``ppermute`` edge list must be a valid
  (partial) permutation over a live mesh axis: sources unique, destinations
  unique, all in range.  A duplicated source or a dead axis name deadlocks
  or corrupts the exchange on a real mesh; XLA only rejects some of these
  at lowering time, on the target runtime.  (The engine's open-chain halo
  permutations are intentionally *partial* — bijectivity is not required.)
* **host-sync** — callback/infeed/outfeed primitives inside the hot loop
  serialize the device pipeline; a traced-value escape (``.item()``,
  ``float()``, ``if`` on a tracer) surfaces as a
  ``ConcretizationTypeError`` at trace time and is converted into the same
  diagnostic instead of a stack trace.
* **dtype-drift** — float64/complex128 equation outputs (silent x64
  upcasts double wire and memory traffic on codec paths).
* **int8-overflow** — integer arithmetic carried out *in* int8/int16
  (wraparound territory); the delta codec must widen to f32 first.
* **cache-key** — ``hash(engine)`` must work and be stable, or the
  module-level compiled-step caches silently churn one compile per call.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.diagnostics import Diagnostic

try:  # jax >= 0.4.33
    from jax.extend import core as jex_core
except ImportError:  # pragma: no cover - older jax
    import jax.core as jex_core

CONTRACT_COLLECTIVE = "collective-matching"
CONTRACT_HOST_SYNC = "host-sync"
CONTRACT_DTYPE = "dtype-drift"
CONTRACT_INT8 = "int8-overflow"
CONTRACT_CACHE = "cache-key"

# Primitives that round-trip through the host every iteration.
_HOST_SYNC_ERROR = {"pure_callback", "io_callback", "outside_call",
                    "host_callback_call", "infeed", "outfeed"}
_HOST_SYNC_WARN = {"debug_callback", "debug_print"}

# Integer arithmetic that wraps around silently in narrow dtypes.
_NARROW_ARITH = {"add", "sub", "mul", "dot_general"}
_NARROW_DTYPES = (jnp.int8, jnp.int16)

_WIDE_DTYPES = (jnp.float64, jnp.complex128)


# ---------------------------------------------------------------------------
# Jaxpr walking
# ---------------------------------------------------------------------------

def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for u in vs:
            if isinstance(u, jex_core.ClosedJaxpr):
                yield u.jaxpr
            elif isinstance(u, jex_core.Jaxpr):
                yield u


def iter_eqns(jaxpr):
    """All equations of a jaxpr, recursing into sub-jaxprs (scan bodies,
    cond branches, pjit/remat calls)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _check_ppermute(eqn, axis_sizes: Dict[str, int],
                    context: str) -> List[Diagnostic]:
    out = []
    axis = eqn.params.get("axis_name")
    names = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
    size = 1
    for nm in names:
        if nm not in axis_sizes:
            out.append(Diagnostic(
                severity="error", contract=CONTRACT_COLLECTIVE,
                message=(f"ppermute over axis {nm!r} which is not a live "
                         f"mesh axis (live: {sorted(axis_sizes) or 'none'})"),
                hint="collectives must name an axis of the spatial mesh "
                     "the step runs under",
                location=f"{context}: {eqn}"))
            return out
        size *= axis_sizes[nm]
    perm = tuple(eqn.params.get("perm", ()))
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    bad = []
    if len(set(srcs)) != len(srcs):
        bad.append("duplicate sources")
    if len(set(dsts)) != len(dsts):
        bad.append("duplicate destinations")
    if any(not (0 <= v < size) for v in srcs + dsts):
        bad.append(f"indices outside [0, {size})")
    if bad:
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_COLLECTIVE,
            message=(f"ppermute edge list {perm} over axis "
                     f"{'x'.join(names)} (size {size}) is not a "
                     f"permutation: {', '.join(bad)}"),
            hint="each device may send to at most one destination and "
                 "receive from at most one source",
            location=f"{context}: ppermute"))
    return out


def audit_jaxpr(closed, axis_sizes: Optional[Dict[str, int]] = None,
                context: str = "step") -> List[Diagnostic]:
    """Walk a (Closed)Jaxpr and return every hazard found."""
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    axis_sizes = dict(axis_sizes or {})
    out: List[Diagnostic] = []
    seen_dtype = set()
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "ppermute":
            out.extend(_check_ppermute(eqn, axis_sizes, context))
        elif name in _HOST_SYNC_ERROR:
            out.append(Diagnostic(
                severity="error", contract=CONTRACT_HOST_SYNC,
                message=(f"host callback primitive {name!r} inside the "
                         "compiled step: every iteration round-trips "
                         "through the host, serializing the device "
                         "pipeline"),
                hint="move host work to segment boundaries (scheduled "
                     "operations) or express it in jax ops",
                location=f"{context}: {name}"))
        elif name in _HOST_SYNC_WARN:
            out.append(Diagnostic(
                severity="warning", contract=CONTRACT_HOST_SYNC,
                message=f"debug callback {name!r} inside the compiled "
                        "step body",
                hint="strip jax.debug.* calls from production behaviors",
                location=f"{context}: {name}"))
        if name in _NARROW_ARITH and eqn.invars and all(
                getattr(v.aval, "dtype", None) is not None
                and any(v.aval.dtype == jnp.dtype(d)
                        for d in _NARROW_DTYPES)
                for v in eqn.invars if hasattr(v, "aval")):
            out.append(Diagnostic(
                severity="warning", contract=CONTRACT_INT8,
                message=(f"{name} computed in "
                         f"{eqn.invars[0].aval.dtype}: narrow integer "
                         "arithmetic wraps around silently (codec deltas "
                         "must accumulate in f32)"),
                hint="widen with .astype(jnp.float32) before arithmetic, "
                     "narrow only for the wire payload",
                location=f"{context}: {name}"))
        for v in eqn.outvars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is None:
                continue
            for wide in _WIDE_DTYPES:
                if dt == jnp.dtype(wide) and (name, str(dt)) not in seen_dtype:
                    seen_dtype.add((name, str(dt)))
                    out.append(Diagnostic(
                        severity="warning", contract=CONTRACT_DTYPE,
                        message=(f"{name} produces {dt}: a silent x64 "
                                 "upcast doubles memory and wire traffic "
                                 "on this path"),
                        hint="pin f32 (check weak-typed Python scalars "
                             "and np.float64 constants)",
                        location=f"{context}: {name}"))
    return out


# ---------------------------------------------------------------------------
# Engine tracing
# ---------------------------------------------------------------------------

def probe_state(engine):
    """Synthetic all-zeros SimState shaped like ONE device's shard (leading
    mesh dims all ones) — exactly what ``local_step`` sees inside
    shard_map.  Never executed, only traced."""
    from repro.core.agent_soa import AgentSoA
    from repro.core.engine import SimState
    from repro.core.guards import NUM_GUARDS
    from repro.core.halo import init_refs

    geom = engine.geom
    nd = geom.ndim
    lead = (1,) * nd
    soa = AgentSoA.empty(engine.behavior.schema, geom.local_shape, geom.cap)
    refs0 = init_refs(geom, soa)
    refs = {d: {f: jnp.broadcast_to(v, lead + v.shape)
                for f, v in slab.items()}
            for d, slab in refs0.items()}
    z = jnp.zeros(lead, jnp.int32)
    key = jnp.broadcast_to(jax.random.PRNGKey(0), lead + (2,))
    return SimState(soa=soa, refs=refs, it=z, key=key, gid_counter=z,
                    dropped=z, halo_bytes=z, codec_overflow=z,
                    health=jnp.zeros(lead + (NUM_GUARDS,), jnp.int32))


def _comm_and_env(engine) -> Tuple[object, Tuple[Tuple[str, int], ...]]:
    from repro.core.domain import spatial_axis_names
    from repro.core.halo import LocalComm, ShardComm

    geom = engine.geom
    if geom.n_devices == 1:
        return LocalComm(toroidal=geom.toroidal), ()
    names = spatial_axis_names(geom.ndim)
    comm = ShardComm(axis_names=names, mesh_shape=geom.mesh_shape,
                     toroidal=geom.toroidal)
    return comm, tuple(zip(names, geom.mesh_shape))


def trace_step(engine, full_halo: bool = True):
    """Trace one per-device step to a ClosedJaxpr (raises jax trace errors;
    :func:`audit_engine` converts them to diagnostics)."""
    comm, axis_env = _comm_and_env(engine)
    state = probe_state(engine)
    fn = lambda s: engine.local_step(s, comm, full_halo)  # noqa: E731
    return jax.make_jaxpr(fn, axis_env=list(axis_env))(state), dict(axis_env)


def audit_fn(fn, *example_args,
             axis_env: Tuple[Tuple[str, int], ...] = (),
             context: str = "fn") -> List[Diagnostic]:
    """Audit an arbitrary function by tracing it over example arguments."""
    try:
        closed = jax.make_jaxpr(fn, axis_env=list(axis_env))(*example_args)
    except jax.errors.ConcretizationTypeError as e:
        return [_concretization_diag(e, context)]
    except NameError as e:
        # jax rejects an unbound axis name at trace time ("unbound axis
        # name: ..."); surface it as the collective-matching finding it is
        # instead of a stack trace.
        return [Diagnostic(
            severity="error", contract=CONTRACT_COLLECTIVE,
            message=f"collective references a dead mesh axis: {e} "
                    f"(live: {sorted(dict(axis_env)) or 'none'})",
            hint="collectives must name an axis of the spatial mesh the "
                 "step runs under",
            location=context)]
    return audit_jaxpr(closed, dict(axis_env), context)


def _concretization_diag(err, context: str) -> Diagnostic:
    first = str(err).strip().splitlines()
    return Diagnostic(
        severity="error", contract=CONTRACT_HOST_SYNC,
        message=("the step forces a traced value to a Python value "
                 "(`.item()`, `float()`, or branching on a traced array): "
                 + (first[0] if first else repr(err))),
        hint="replace host conversions with jnp ops (jnp.where instead of "
             "if, lax.cond for traced branches)",
        location=context)


def audit_cache_key(engine) -> List[Diagnostic]:
    out = []
    try:
        h0 = hash(engine)
        h1 = hash(dataclasses.replace(engine))
    except TypeError as e:
        return [Diagnostic(
            severity="error", contract=CONTRACT_CACHE,
            message=(f"engine is not hashable ({e}): the module-level "
                     "compiled step/segment caches cannot memoize it, so "
                     "every Simulation rebuild re-traces and re-compiles"),
            hint="Engine fields must be hashable (frozen dataclasses, "
                 "tuples, scalars; Behavior hashes by identity)",
            location="engine")]
    if h0 != h1:
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_CACHE,
            message="hash(engine) is unstable across structurally equal "
                    "copies: compiled-step caches churn one compile per "
                    "rebuild",
            hint="check custom __hash__/__eq__ on engine fields",
            location="engine"))
    return out


def audit_engine(engine) -> List[Diagnostic]:
    """Full jaxpr audit of an engine: cache key, full-refresh step, and —
    when delta encoding is on — the delta codec step."""
    out = audit_cache_key(engine)
    variants = [(True, "step[full]")]
    if engine.delta_cfg.enabled:
        variants.append((False, "step[delta]"))
    for full, context in variants:
        try:
            closed, axis_sizes = trace_step(engine, full_halo=full)
        except jax.errors.ConcretizationTypeError as e:
            out.append(_concretization_diag(e, context))
            continue
        out.extend(audit_jaxpr(closed, axis_sizes, context))
    return out
