"""AST repo lint + hot-path behavior lint.

Two layers, one diagnostic currency:

**Module lint** (:func:`lint_paths` / :func:`lint_source`) — repo hygiene
checks over source files:

* ``lint-unused-import``  — imported name never referenced (re-export files
  — ``__init__.py`` — are skipped; ``# noqa`` lines are honored; names in
  ``__all__`` count as used).
* ``lint-mutable-default`` — a mutable literal (``{}``/``[]``/``set()``/
  ``dict()``/``list()``) as a default parameter value: shared across calls,
  and unhashable if it feeds a cache key.
* ``lint-shadowed-import`` — a module-level import later rebound at module
  level.

**Hot-path lint** (:func:`lint_behavior` / :func:`lint_hot_fn`) — the
static complement of the jaxpr auditor, over a behavior's ``pair_fn`` /
``update_fn`` source:

* ``hot-python-branch`` — Python ``if``/``while`` whose test references a
  *traced* argument (agent attrs, accumulators, masks, keys).  Inside jit
  this raises at trace time at best; at worst it silently bakes in one
  branch.  ``params`` and ``dt`` are static Python values, so branching on
  them is legal and not flagged; ``x is None`` structure checks are
  whitelisted.
* ``hot-host-sync`` — ``.item()`` anywhere, or ``float()``/``int()``/
  ``bool()`` applied to a traced value: a device round-trip per call, or a
  trace-time error.
* ``hot-numpy`` — ``np.*`` / ``numpy.*`` inside a hot function: host
  numpy silently materializes the traced array (or fails), and never runs
  on the device.

Tainting is first-order and deliberately conservative: a name assigned
from an expression that references a traced name *outside any call* is
traced too; values returned by calls are not tainted (so structure checks
on results like ``child is not None`` stay clean).  The jaxpr auditor
catches what this heuristic misses.
"""

from __future__ import annotations

import ast
import inspect
import pathlib
import textwrap
from typing import Iterable, List, Optional, Sequence, Set

from repro.analysis.diagnostics import Diagnostic

CONTRACT_UNUSED_IMPORT = "lint-unused-import"
CONTRACT_MUTABLE_DEFAULT = "lint-mutable-default"
CONTRACT_SHADOWED_IMPORT = "lint-shadowed-import"
CONTRACT_HOT_BRANCH = "hot-python-branch"
CONTRACT_HOT_SYNC = "hot-host-sync"
CONTRACT_HOT_NUMPY = "hot-numpy"

# behavior arguments that are static Python values, not tracers
_STATIC_ARGS = {"params", "dt", "self", "cls"}

_MUTABLE_CTORS = {"dict", "list", "set"}


# ---------------------------------------------------------------------------
# Module lint
# ---------------------------------------------------------------------------

def _noqa_lines(src: str) -> Set[int]:
    return {i + 1 for i, line in enumerate(src.splitlines())
            if "# noqa" in line}


def _import_bindings(tree: ast.AST):
    """Yield (name, lineno) for every module-scope import binding."""
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if isinstance(node, ast.Import):
            for alias in node.names:
                name = alias.asname or alias.name.split(".")[0]
                yield name, node.lineno
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                yield (alias.asname or alias.name), node.lineno
        elif isinstance(node, ast.If):
            # imports under `if TYPE_CHECKING:` and friends
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Import, ast.ImportFrom)):
                    fake = ast.Module(body=[sub], type_ignores=[])
                    yield from _import_bindings(fake)


def _used_names(tree: ast.AST) -> Set[str]:
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # module attribute chains root at a Name, already collected
            pass
        elif (isinstance(node, ast.Constant)
              and isinstance(node.value, str)):
            continue
    # names re-exported through __all__ count as used
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets)):
            for sub in ast.walk(node.value):
                if (isinstance(sub, ast.Constant)
                        and isinstance(sub.value, str)):
                    used.add(sub.value)
    return used


def _check_unused_imports(tree, filename: str,
                          noqa: Set[int]) -> List[Diagnostic]:
    if pathlib.Path(filename).name == "__init__.py":
        return []  # re-export modules import on purpose
    used = _used_names(tree)
    out = []
    for name, lineno in _import_bindings(tree):
        if lineno in noqa or name in used or name == "_":
            continue
        out.append(Diagnostic(
            severity="warning", contract=CONTRACT_UNUSED_IMPORT,
            message=f"import {name!r} is never used",
            hint="delete the import (or mark an intentional re-export "
                 "with `# noqa`)",
            location=f"{filename}:{lineno}"))
    return out


def _is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.Dict, ast.List, ast.Set, ast.DictComp,
                         ast.ListComp, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in _MUTABLE_CTORS)


def _check_mutable_defaults(tree, filename: str,
                            noqa: Set[int]) -> List[Diagnostic]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            if _is_mutable_default(d) and d.lineno not in noqa:
                out.append(Diagnostic(
                    severity="warning", contract=CONTRACT_MUTABLE_DEFAULT,
                    message=(f"function {node.name!r} has a mutable "
                             "default argument: it is shared across "
                             "calls and unhashable as a cache key"),
                    hint="default to None and construct inside, or use a "
                         "frozen/tuple default",
                    location=f"{filename}:{d.lineno}"))
    return out


def _bound_names(target: ast.AST):
    """Names an assignment target actually (re)binds — Subscript/Attribute
    targets mutate an object, they do not rebind the name."""
    if isinstance(target, ast.Name):
        yield target.id, target.lineno
    elif isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            yield from _bound_names(e)
    elif isinstance(target, ast.Starred):
        yield from _bound_names(target.value)


def _check_shadowed_imports(tree, filename: str,
                            noqa: Set[int]) -> List[Diagnostic]:
    imports = {name: lineno for name, lineno in _import_bindings(tree)}
    out = []
    body = tree.body if isinstance(tree, ast.Module) else []
    for node in body:
        names = []
        if isinstance(node, ast.Assign):
            for t in node.targets:
                names.extend(_bound_names(t))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.append((node.name, node.lineno))
        for name, lineno in names:
            if (name in imports and lineno > imports[name]
                    and lineno not in noqa):
                out.append(Diagnostic(
                    severity="warning", contract=CONTRACT_SHADOWED_IMPORT,
                    message=(f"module-level {name!r} shadows the import "
                             f"at line {imports[name]}"),
                    hint="rename one of the two bindings",
                    location=f"{filename}:{lineno}"))
    return out


def lint_source(src: str, filename: str = "<source>") -> List[Diagnostic]:
    """Module-level lint over one source string."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Diagnostic(
            severity="error", contract="lint-syntax",
            message=f"syntax error: {e.msg}",
            location=f"{filename}:{e.lineno}")]
    noqa = _noqa_lines(src)
    out: List[Diagnostic] = []
    out.extend(_check_unused_imports(tree, filename, noqa))
    out.extend(_check_mutable_defaults(tree, filename, noqa))
    out.extend(_check_shadowed_imports(tree, filename, noqa))
    return out


def lint_paths(paths: Iterable[str]) -> List[Diagnostic]:
    """Module lint over files and directories (recursing into ``*.py``)."""
    files: List[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        else:
            files.append(path)
    out: List[Diagnostic] = []
    for f in files:
        out.extend(lint_source(f.read_text(), str(f)))
    return out


# ---------------------------------------------------------------------------
# Hot-path lint (behavior pair/update functions)
# ---------------------------------------------------------------------------

def _names_in(node: ast.AST, *, skip_calls: bool) -> Set[str]:
    """Names referenced in an expression; ``skip_calls`` prunes call
    subtrees (used by the taint propagation so call *results* stay
    untainted)."""
    found: Set[str] = set()

    def visit(n):
        if skip_calls and isinstance(n, ast.Call):
            return
        if isinstance(n, ast.Name):
            found.add(n.id)
        for child in ast.iter_child_nodes(n):
            visit(child)

    visit(node)
    return found


def _is_none_check(test: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` (static structure checks)."""
    if not isinstance(test, ast.Compare):
        return False
    return all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)


def _traced_args(fdef, static_args: Optional[Set[str]] = None) -> Set[str]:
    static = _STATIC_ARGS if static_args is None else static_args
    names = [a.arg for a in fdef.args.args + fdef.args.kwonlyargs]
    return {n for n in names
            if n not in static and not n.startswith("_")}


def _propagate_taint(fdef, traced: Set[str]) -> Set[str]:
    """First-order fixpoint: a name assigned from an expression that
    references a traced name outside any call is traced too."""
    traced = set(traced)
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fdef):
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            else:
                continue
            if not (_names_in(value, skip_calls=True) & traced):
                continue
            for t in targets:
                for sub in ast.walk(t):
                    if (isinstance(sub, ast.Name)
                            and sub.id not in traced):
                        traced.add(sub.id)
                        changed = True
    return traced


def lint_hot_fn(fn, label: str = "",
                static_args: Optional[Set[str]] = None) -> List[Diagnostic]:
    """Hot-path lint of one pair/update function via its source.

    ``static_args`` overrides the default set of non-traced argument names
    (:data:`_STATIC_ARGS`).  The ensemble contract passes a set *without*
    ``params``: under the vmapped runner parameters are traced per-replica
    scalars, so branching on them — legal in a solo engine — becomes a
    batch hazard."""
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError):
        return []  # no retrievable/parsable source (lambda, C ext, REPL)
    fdef = next((n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef,
                                   ast.AsyncFunctionDef))), None)
    if fdef is None:
        return []
    code = getattr(fn, "__code__", None)
    base_line = (code.co_firstlineno - fdef.lineno) if code else 0
    filename = code.co_filename if code else "<source>"

    def loc(node) -> str:
        return f"{label or fn.__name__} ({filename}:" \
               f"{node.lineno + base_line})"

    traced = _propagate_taint(fdef, _traced_args(fdef, static_args))
    out: List[Diagnostic] = []
    for node in ast.walk(fdef):
        if isinstance(node, (ast.If, ast.While)):
            if _is_none_check(node.test):
                continue
            if _names_in(node.test, skip_calls=False) & traced:
                kw = "while" if isinstance(node, ast.While) else "if"
                out.append(Diagnostic(
                    severity="error", contract=CONTRACT_HOT_BRANCH,
                    message=(f"Python `{kw}` on a traced value inside a "
                             "hot function: inside jit this raises at "
                             "trace time or silently freezes one branch"),
                    hint="use jnp.where / jax.lax.cond instead of Python "
                         "control flow on agent data",
                    location=loc(node)))
        elif isinstance(node, ast.Call):
            func = node.func
            if isinstance(func, ast.Attribute) and func.attr == "item":
                out.append(Diagnostic(
                    severity="error", contract=CONTRACT_HOT_SYNC,
                    message="`.item()` in a hot function: forces a "
                            "device->host transfer (or a trace-time "
                            "error inside jit)",
                    hint="keep the value as a traced array; reduce with "
                         "jnp ops",
                    location=loc(node)))
            elif (isinstance(func, ast.Name)
                  and func.id in ("float", "int", "bool")
                  and any(_names_in(a, skip_calls=False) & traced
                          for a in node.args)):
                out.append(Diagnostic(
                    severity="error", contract=CONTRACT_HOT_SYNC,
                    message=(f"`{func.id}()` applied to a traced value: "
                             "host conversion inside the hot path"),
                    hint="use .astype(...) / jnp casts on arrays",
                    location=loc(node)))
        elif (isinstance(node, ast.Name)
              and node.id in ("np", "numpy")
              and isinstance(node.ctx, ast.Load)):
            out.append(Diagnostic(
                severity="warning", contract=CONTRACT_HOT_NUMPY,
                message="host numpy used inside a hot function: the call "
                        "runs on the host every step (or fails on "
                        "tracers)",
                hint="use jax.numpy (jnp) in behavior kernels",
                location=loc(node)))
    return out


def lint_behavior(behavior, name: str = "behavior",
                  static_args: Optional[Set[str]] = None
                  ) -> List[Diagnostic]:
    """Hot-path lint over every leaf pair/update function of a behavior
    stack (composed wrappers are framework code and recursed through, not
    linted themselves)."""
    out: List[Diagnostic] = []

    def rec(b, path):
        children = tuple(getattr(b, "children", ()) or ())
        if children:
            for i, c in enumerate(children):
                rec(c, f"{path}.b{i}")
            return
        out.extend(lint_hot_fn(b.pair_fn, f"{path}.pair_fn",
                               static_args=static_args))
        out.extend(lint_hot_fn(b.update_fn, f"{path}.update_fn",
                               static_args=static_args))

    rec(behavior, name)
    return out


def lint_behaviors(behaviors: Sequence, name: str = "behavior"
                   ) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    for i, b in enumerate(behaviors):
        out.extend(lint_behavior(b, f"{name}[{i}]"))
    return out
