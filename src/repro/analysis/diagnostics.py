"""Structured findings shared by every simcheck pass.

A :class:`Diagnostic` is one finding: a severity, the *contract* it belongs
to (a stable kebab-case name — ``docs/contracts.md`` catalogues them all),
a human message, an actionable fix hint, and a location (a behavior path,
a ``file:line``, or a jaxpr equation).  A :class:`Report` aggregates the
findings of one simcheck run and owns the exit-code / formatting policy:

* ``error``   — the simulation is (or will be) silently wrong; always fails.
* ``warning`` — probable hazard (e.g. a stochastic displacement bound);
  fails only under ``--strict``.
* ``info``    — advisory (memory overheads, unverifiable bounds); never
  fails.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List, Sequence

SEVERITIES = ("info", "warning", "error")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One simcheck finding."""

    severity: str        # "error" | "warning" | "info"
    contract: str        # stable contract name, e.g. "one-hop-migration"
    message: str         # what is wrong
    hint: str = ""       # how to fix it
    location: str = ""   # behavior path, file:line, or jaxpr equation

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}")

    def format(self) -> str:
        loc = f" [{self.location}]" if self.location else ""
        hint = f"\n    hint: {self.hint}" if self.hint else ""
        return f"{self.severity}: {self.contract}{loc}: {self.message}{hint}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def with_context(diags: Iterable[Diagnostic], context: str
                 ) -> List[Diagnostic]:
    """Prefix every diagnostic's location with a run context label."""
    out = []
    for d in diags:
        loc = f"{context}: {d.location}" if d.location else context
        out.append(dataclasses.replace(d, location=loc))
    return out


class Report:
    """An ordered collection of diagnostics with exit-code policy."""

    def __init__(self, diagnostics: Sequence[Diagnostic] = ()):
        self.diagnostics: List[Diagnostic] = list(diagnostics)

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def __len__(self) -> int:
        return len(self.diagnostics)

    def __iter__(self):
        return iter(self.diagnostics)

    def by_severity(self, severity: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == severity]

    @property
    def errors(self) -> List[Diagnostic]:
        return self.by_severity("error")

    @property
    def warnings(self) -> List[Diagnostic]:
        return self.by_severity("warning")

    def failed(self, strict: bool = False) -> bool:
        """Errors always fail; warnings fail under strict; info never."""
        if self.errors:
            return True
        return bool(strict and self.warnings)

    def exit_code(self, strict: bool = False) -> int:
        return 1 if self.failed(strict) else 0

    def summary(self) -> str:
        counts = {s: len(self.by_severity(s)) for s in SEVERITIES}
        return (f"{counts['error']} error(s), {counts['warning']} "
                f"warning(s), {counts['info']} info")

    def format_text(self) -> str:
        order = {s: i for i, s in enumerate(reversed(SEVERITIES))}
        lines = [d.format() for d in sorted(
            self.diagnostics, key=lambda d: order[d.severity])]
        lines.append(f"simcheck: {self.summary()}")
        return "\n".join(lines)

    def format_json(self) -> str:
        return json.dumps({
            "diagnostics": [d.to_dict() for d in self.diagnostics],
            "summary": {s: len(self.by_severity(s)) for s in SEVERITIES},
        }, indent=1)
