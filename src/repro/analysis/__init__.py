"""repro.analysis — simcheck: static contract checker, jaxpr auditor,
and repo lint for distributed-correctness hazards.

Three passes over one diagnostic currency (:class:`Diagnostic` /
:class:`Report`):

* :mod:`repro.analysis.contracts` — static contracts on a geometry +
  behavior stack (stencil soundness, one-hop migration, aura sufficiency,
  codec headroom, partition validity).
* :mod:`repro.analysis.jaxpr_audit` — trace the step runners with
  ``jax.make_jaxpr`` and audit the equations (ppermute permutation
  validity, host syncs, dtype drift, int8 overflow, cache-key stability).
* :mod:`repro.analysis.lint` — AST lint over source files and behavior
  pair/update functions (Python branches on traced values, ``.item()``,
  host numpy, mutable defaults, dead imports).

Run everything via ``python -m repro.launch.simcheck`` or
``Simulation.validate()``.  See ``docs/contracts.md`` for the catalogue.
"""

from repro.analysis.diagnostics import (  # noqa: F401
    SEVERITIES,
    Diagnostic,
    Report,
    with_context,
)
from repro.analysis.contracts import (  # noqa: F401
    ContractError,
    DisplacementBound,
    check_contracts,
    check_engine,
    check_ensemble,
    check_supervision,
    displacement_bound,
    enforce,
    enforce_diagnostics,
    min_slab_width_cells,
)
from repro.analysis.jaxpr_audit import (  # noqa: F401
    audit_engine,
    audit_fn,
    audit_jaxpr,
    trace_step,
)
from repro.analysis.lint import (  # noqa: F401
    lint_behavior,
    lint_behaviors,
    lint_hot_fn,
    lint_paths,
    lint_source,
)

__all__ = [
    "SEVERITIES",
    "Diagnostic",
    "Report",
    "with_context",
    "ContractError",
    "DisplacementBound",
    "check_contracts",
    "check_engine",
    "check_ensemble",
    "check_supervision",
    "displacement_bound",
    "enforce",
    "enforce_diagnostics",
    "min_slab_width_cells",
    "audit_engine",
    "audit_fn",
    "audit_jaxpr",
    "trace_step",
    "lint_behavior",
    "lint_behaviors",
    "lint_hot_fn",
    "lint_paths",
    "lint_source",
]
