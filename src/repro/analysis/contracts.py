"""Static contract checker: Domain x Partition x behavior-stack invariants.

The engine's distributed correctness rests on contracts the code documents
but (before this module) never enforced:

* **stencil-soundness** — every ``Behavior.radius`` must be <= the Domain's
  ``cell_size``: the ``3**ndim`` neighborhood sweep only visits adjacent
  cells, so a larger radius silently drops interacting pairs.
* **aura-sufficiency** — on a multi-device mesh the same bound guarantees
  the one-cell aura ring holds every *remote* neighbor a pair kernel may
  read; past it, remote pairs vanish entirely (worse than the local miss).
* **one-hop-migration** — migration is a single ring exchange per axis per
  step: an agent may cross at most into the *adjacent* device's slab.  The
  binding bound is per-axis: per-step displacement must stay under
  ``min_slab_width_cells(axis) * cell_size`` for every axis the device
  mesh shards (crossing two cuts in one step requires traversing an entire
  intermediate slab).  Narrow RCB slabs tighten it — the hazard from
  docs/load_balancing.md.
* **codec-headroom** — with a *fixed* delta-codec scale, the representable
  per-step delta is ``scale * qmax``; a worst-case displacement past it
  clips silently at the int8/int16 rail (core.delta counts the overflow at
  runtime; this contract rejects configurations that make it inevitable).
* **partition-validity** — geometry sanity: positive cell size, partition
  cut coverage, padded-grid memory overhead, device availability.

Displacement bounds are derived statically from behavior parameters, per
leaf behavior and summed across a composed stack:

* ``Behavior.max_displacement`` — an explicitly declared per-step bound
  (wins over inference; the escape hatch for custom update functions).
* ``params["max_step"]`` — a hard norm clamp (the
  :func:`repro.core.behaviors.displacement_update` convention).
* ``params["sigma"]`` — a per-step, per-component Gaussian scale; bounded
  at the 4-sigma quantile (probabilistic, so violations are *warnings*).
* ``params["div_offset"]`` — a spawning behavior's Gaussian child offset,
  also bounded at 4 sigma.

A spawning behavior with no declared offset, or an update with none of the
recognized parameters, makes the bound *unverifiable*: the checker emits an
info diagnostic instead of guessing.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp

from repro.analysis.diagnostics import Diagnostic

CONTRACT_STENCIL = "stencil-soundness"
CONTRACT_AURA = "aura-sufficiency"
CONTRACT_ONE_HOP = "one-hop-migration"
CONTRACT_HEADROOM = "codec-headroom"
CONTRACT_PARTITION = "partition-validity"
CONTRACT_SUPERVISION = "supervised-recovery"

# severity ordering for displacement-bound kinds
_KIND_RANK = {"hard": 0, "stochastic": 1, "unknown": 2}

# Gaussian tail quantile used to bound stochastic per-step displacements.
SIGMA_QUANTILE = 4.0


@dataclasses.dataclass(frozen=True)
class DisplacementBound:
    """Worst-case per-step, per-component displacement of a behavior stack.

    ``kind``: "hard" (provable clamp), "stochastic" (a ``SIGMA_QUANTILE``
    tail bound), or "unknown" (at least one term is unverifiable — ``value``
    then only sums the known terms).
    """

    value: float
    kind: str
    detail: str


def _leaf_bound(behavior) -> DisplacementBound:
    declared = getattr(behavior, "max_displacement", None)
    if declared is not None:
        return DisplacementBound(float(declared), "hard",
                                 "declared max_displacement")
    params = behavior.params
    terms: List[Tuple[float, str, str]] = []  # (value, kind, label)
    if "max_step" in params:
        terms.append((float(params["max_step"]), "hard", "max_step"))
    if "sigma" in params:
        v = SIGMA_QUANTILE * float(params["sigma"])
        terms.append((v, "stochastic",
                      f"{SIGMA_QUANTILE:g}*sigma"))
    unknown = []
    if behavior.can_spawn:
        if "div_offset" in params:
            v = SIGMA_QUANTILE * float(params["div_offset"])
            terms.append((v, "stochastic",
                          f"{SIGMA_QUANTILE:g}*div_offset"))
        else:
            unknown.append("spawn offset not declared "
                           "(no div_offset param)")
    if not terms and not unknown:
        unknown.append("no recognized displacement params "
                       "(max_step / sigma / div_offset)")
    value = sum(v for v, _, _ in terms)
    detail = " + ".join(f"{lbl}={v:g}" for v, _, lbl in terms) or "0"
    if unknown:
        return DisplacementBound(value, "unknown",
                                 detail + "; " + "; ".join(unknown))
    kind = max((k for _, k, _ in terms), key=_KIND_RANK.__getitem__)
    return DisplacementBound(value, kind, detail)


def displacement_bound(behavior, dt: float = 1.0) -> DisplacementBound:
    """Worst-case per-step displacement of a (possibly composed) behavior.

    Composed stacks sum their children's bounds (updates chain within one
    step, so displacements add); the overall kind is the weakest child
    kind.  ``dt`` is accepted for symmetry with the engine signature — the
    recognized parameters are all per-step quantities (``max_step`` is a
    norm clamp; ``sigma``/``div_offset`` scale per-step Gaussian draws).
    """
    children = tuple(getattr(behavior, "children", ()) or ())
    if not children:
        return _leaf_bound(behavior)
    bounds = [displacement_bound(c, dt) for c in children]
    value = sum(b.value for b in bounds)
    kind = max((b.kind for b in bounds), key=_KIND_RANK.__getitem__)
    detail = " + ".join(f"b{i}({b.detail})" for i, b in enumerate(bounds))
    return DisplacementBound(value, kind, detail)


def leaf_behaviors(behavior, path: str = "behavior"):
    """Yield ``(path, leaf)`` for every leaf of a composed behavior stack."""
    children = tuple(getattr(behavior, "children", ()) or ())
    if not children:
        yield path, behavior
        return
    for i, child in enumerate(children):
        yield from leaf_behaviors(child, f"{path}.b{i}")


def min_slab_width_cells(geom, axis: int) -> int:
    """Narrowest owned slab along ``axis``, in cells."""
    if geom.partition is not None:
        return min(geom.partition.widths[axis])
    return geom.interior[axis]


def _behavior_label(behavior, path: str) -> str:
    fn = getattr(behavior, "update_fn", None)
    name = getattr(fn, "__name__", None)
    return f"{path} ({name})" if name else path


# ---------------------------------------------------------------------------
# The contract checks
# ---------------------------------------------------------------------------

def check_stencil(geom, behavior) -> List[Diagnostic]:
    """radius <= cell_size per leaf behavior, plus the multi-device aura
    framing of the same bound."""
    out = []
    sharded = geom.n_devices > 1
    for path, leaf in leaf_behaviors(behavior):
        r = float(leaf.radius)
        if r > float(geom.cell_size):
            loc = _behavior_label(leaf, path)
            out.append(Diagnostic(
                severity="error", contract=CONTRACT_STENCIL,
                message=(f"interaction radius {r:g} exceeds cell_size "
                         f"{geom.cell_size:g}: the {3 ** geom.ndim}-cell "
                         "neighborhood sweep only sees adjacent cells, so "
                         "pairs between non-adjacent cells are silently "
                         "dropped"),
                hint=(f"raise cell_size to >= {r:g} (one cell must cover "
                      "the interaction radius) or reduce the behavior's "
                      "radius"),
                location=loc))
            if sharded:
                out.append(Diagnostic(
                    severity="error", contract=CONTRACT_AURA,
                    message=(f"radius {r:g} does not fit the one-cell aura "
                             f"ring ({geom.cell_size:g} world units): "
                             "remote neighbors beyond the ring are never "
                             "exchanged, so cross-device pairs past "
                             "cell_size are invisible"),
                    hint=("the aura ring is one cell wide by construction; "
                          f"raise cell_size to >= {r:g}"),
                    location=loc))
    return out


def check_one_hop(geom, behavior, dt: float = 1.0) -> List[Diagnostic]:
    """Per-step displacement vs the narrowest owned slab, per sharded axis."""
    out = []
    constrained = [a for a in range(geom.ndim) if geom.mesh_shape[a] > 1]
    if not constrained:
        return out
    bound = displacement_bound(behavior, dt)
    if bound.kind == "unknown":
        out.append(Diagnostic(
            severity="info", contract=CONTRACT_ONE_HOP,
            message=("per-step displacement bound is unverifiable "
                     f"({bound.detail}); the one-hop migration contract "
                     "cannot be checked statically"),
            hint=("declare Behavior(max_displacement=...) with the "
                  "worst-case per-step displacement, or carry max_step / "
                  "sigma / div_offset in params"),
            location=_behavior_label(behavior, "behavior")))
        return out
    severity = "error" if bound.kind == "hard" else "warning"
    for a in constrained:
        width = min_slab_width_cells(geom, a)
        limit = width * float(geom.cell_size)
        if bound.value >= limit:
            what = ("hard displacement bound" if bound.kind == "hard" else
                    f"{SIGMA_QUANTILE:g}-sigma displacement bound")
            out.append(Diagnostic(
                severity=severity, contract=CONTRACT_ONE_HOP,
                message=(f"axis {a}: {what} {bound.value:g} "
                         f"({bound.detail}) reaches the narrowest owned "
                         f"slab ({width} cells = {limit:g} world units); "
                         "an agent crossing a whole slab in one step "
                         "skips the intermediate device, lands in the "
                         "receiver's migration ring, and is destroyed by "
                         "the next aura rebuild"),
                hint=("reduce the per-step displacement (max_step / sigma "
                      "/ dt), widen the narrowest partition slab, or use "
                      f"fewer devices along axis {a}"),
                location=_behavior_label(behavior, "behavior")))
    return out


def check_codec_headroom(geom, behavior, delta_cfg,
                         dt: float = 1.0) -> List[Diagnostic]:
    """Fixed quantization scale vs the worst-case per-step delta."""
    out = []
    if delta_cfg is None or not delta_cfg.enabled:
        return out
    scale = getattr(delta_cfg, "scale", None)
    if scale is None:
        return out  # adaptive per-slab scale: clipping impossible
    qmax = float(jnp.iinfo(delta_cfg.qdtype).max)
    representable = float(scale) * qmax
    bound = displacement_bound(behavior, dt)
    if bound.kind == "unknown":
        out.append(Diagnostic(
            severity="info", contract=CONTRACT_HEADROOM,
            message=(f"fixed delta scale {scale:g} (representable delta "
                     f"{representable:g}) cannot be checked: per-step "
                     f"displacement bound is unverifiable ({bound.detail})"),
            hint="declare Behavior(max_displacement=...)",
            location="delta_cfg"))
        return out
    if bound.value <= 0:
        return out
    headroom = representable / bound.value
    if headroom < 1.0:
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_HEADROOM,
            message=(f"fixed delta scale {scale:g} represents at most "
                     f"+/-{representable:g} per step, but the worst-case "
                     f"per-step displacement is {bound.value:g} "
                     f"({bound.detail}): headroom {headroom:.2f} < 1.0, "
                     "the int"
                     f"{jnp.iinfo(delta_cfg.qdtype).bits} encode will "
                     "clip deltas silently"),
            hint=(f"raise scale to >= {bound.value / qmax:g}, or drop "
                  "scale=None to use the adaptive per-slab scale"),
            location="delta_cfg"))
    elif headroom < 1.5:
        out.append(Diagnostic(
            severity="warning", contract=CONTRACT_HEADROOM,
            message=(f"fixed delta scale {scale:g}: headroom "
                     f"{headroom:.2f} over the worst-case per-step "
                     f"displacement {bound.value:g} leaves little margin "
                     "before the quantizer clips"),
            hint=f"consider scale >= {1.5 * bound.value / qmax:g}",
            location="delta_cfg"))
    return out


def check_partition(geom) -> List[Diagnostic]:
    """Geometry / partition sanity."""
    out = []
    if float(geom.cell_size) <= 0:
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_PARTITION,
            message=f"cell_size {geom.cell_size!r} must be positive",
            hint="set cell_size to at least the max interaction radius",
            location="geom"))
        return out
    part = geom.partition
    if part is not None:
        for a, cuts in enumerate(part.cuts):
            if cuts[-1] != geom.global_cells[a]:
                out.append(Diagnostic(
                    severity="error", contract=CONTRACT_PARTITION,
                    message=(f"axis {a} cuts {cuts} end at {cuts[-1]} but "
                             f"the global grid has "
                             f"{geom.global_cells[a]} cells"),
                    hint="partition cuts must cover the global cell grid",
                    location="geom.partition"))
        pad = part.pad_fraction()
        if pad > 1.0:
            out.append(Diagnostic(
                severity="info", contract=CONTRACT_PARTITION,
                message=(f"padded per-device grids allocate "
                         f"{pad:.0%} more cells than are owned "
                         "(docs/load_balancing.md memory model)"),
                hint=("prefer cuts with less width spread, or a larger "
                      "box_factor"),
                location="geom.partition"))
    n_dev = geom.n_devices
    if n_dev > 1:
        import jax
        have = len(jax.devices())
        if have < n_dev:
            out.append(Diagnostic(
                severity="info", contract=CONTRACT_PARTITION,
                message=(f"geometry spans {n_dev} devices but this host "
                         f"exposes {have}; running it here needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         f"count={n_dev}"),
                hint="static checks still apply; only execution needs "
                     "the devices",
                location="geom"))
    return out


def check_supervision(engine, supervised) -> List[Diagnostic]:
    """Guard policy vs checkpoint cadence for a supervised run
    (launch.supervise): rollback can only trigger on something *raising*.

    With guards off, silent corruption (a NaN burst, a lost halo slab)
    never raises, so the supervisor can only react to hard exceptions —
    the checkpoints it writes may themselves capture corrupted state.
    That combination defeats the recovery guarantee, hence an error.
    """
    out = []
    policy = getattr(getattr(engine, "guards", None), "policy", "off")
    if policy == "off":
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_SUPERVISION,
            message=("supervised run with guard policy 'off': silent "
                     "corruption (NaN burst, lost or corrupted halo "
                     "slab, conservation break) is never detected, so "
                     "periodic checkpoints can capture corrupted state "
                     "and rollback restores the corruption"),
            hint=("construct the Simulation with guards=\"error\" (or a "
                  "GuardConfig with policy=\"error\") so guard trips "
                  "raise HealthError at the next host control point"),
            location="supervised"))
    elif policy == "warn":
        out.append(Diagnostic(
            severity="warning", contract=CONTRACT_SUPERVISION,
            message=("supervised run with guard policy 'warn': trips are "
                     "logged but never raise, so the supervisor only "
                     "rolls back on hard exceptions (device loss, "
                     "injected raises) — guard-detected corruption "
                     "passes through into the next checkpoint"),
            hint="use guards=\"error\" for rollback on guard trips",
            location="supervised"))
    keep = int(getattr(supervised, "keep", 0) or 0)
    if keep < 2:
        out.append(Diagnostic(
            severity="warning", contract=CONTRACT_SUPERVISION,
            message=(f"checkpoint retention keep={keep}: a single torn "
                     "or corrupted write leaves no verified checkpoint "
                     "to roll back to"),
            hint="keep at least 2 checkpoints on a supervised run",
            location="supervised"))
    every = int(getattr(supervised, "every", 0) or 0)
    if every < 1:
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_SUPERVISION,
            message=f"checkpoint cadence every={every} must be >= 1",
            hint="set Supervised(every=N) with N >= 1",
            location="supervised"))
    return out


def enforce_diagnostics(diagnostics: List[Diagnostic],
                        mode: str = "error") -> List[Diagnostic]:
    """Gate an arbitrary diagnostic list the way :func:`enforce` gates the
    engine contracts: error-severity findings raise (``mode="error"``) or
    warn (``mode="warn"``); warnings/infos never gate."""
    if mode not in ("off", "warn", "error"):
        raise ValueError(
            f"check mode {mode!r} not in ('off', 'warn', 'error')")
    if mode == "off":
        return []
    errors = [d for d in diagnostics if d.severity == "error"]
    if not errors:
        return []
    if mode == "error":
        raise ContractError(errors)
    import warnings
    for d in errors:
        warnings.warn(f"simcheck contract: {d.format()}", stacklevel=3)
    return errors


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def check_contracts(geom, behavior, delta_cfg=None,
                    dt: float = 1.0) -> List[Diagnostic]:
    """Run every static contract over a (geom, behavior, delta) triple."""
    out: List[Diagnostic] = []
    out.extend(check_partition(geom))
    out.extend(check_stencil(geom, behavior))
    out.extend(check_one_hop(geom, behavior, dt))
    out.extend(check_codec_headroom(geom, behavior, delta_cfg, dt))
    return out


def check_engine(engine) -> List[Diagnostic]:
    """Contract pass over an :class:`repro.core.Engine` (duck-typed)."""
    return check_contracts(engine.geom, engine.behavior,
                           engine.delta_cfg, engine.dt)


class ContractError(ValueError):
    """Raised by :func:`enforce` when error-severity contracts fail.

    Carries the offending diagnostics in ``self.diagnostics``.
    """

    def __init__(self, diagnostics):
        self.diagnostics = list(diagnostics)
        lines = [d.format() for d in self.diagnostics]
        super().__init__(
            "simulation contracts violated "
            "(pass check=\"warn\" or check=\"off\" to bypass):\n"
            + "\n".join(lines))


def enforce(engine, mode: str = "error") -> List[Diagnostic]:
    """Construction-time gate: raise (or warn) on error-severity findings.

    Only *definite* hazards gate construction — warnings and infos are
    surfaced through ``Simulation.validate()`` / the simcheck CLI, never
    here, so probabilistic bounds cannot break existing runs.
    """
    if mode not in ("off", "warn", "error"):
        raise ValueError(
            f"check mode {mode!r} not in ('off', 'warn', 'error')")
    if mode == "off":
        return []
    errors = [d for d in check_engine(engine) if d.severity == "error"]
    if not errors:
        return []
    if mode == "error":
        raise ContractError(errors)
    import warnings
    for d in errors:
        warnings.warn(f"simcheck contract: {d.format()}", stacklevel=3)
    return errors


# ---------------------------------------------------------------------------
# Ensemble batch-safety (core.ensemble / launch.serve)
# ---------------------------------------------------------------------------

CONTRACT_ENSEMBLE = "ensemble-batch-safe"
CONTRACT_ENSEMBLE_FACTORY = "ensemble-factory-static"

# jax host-callback entry points: legal in a solo engine's cold path, but
# inside a vmapped lane they fire once per replica per step on the host —
# and several have no batching rule at all.
_HOST_CALLBACK_NAMES = {"pure_callback", "io_callback", "host_callback",
                        "callback", "debug_callback"}


def _scan_host_callbacks(behavior, name: str) -> List[Diagnostic]:
    import ast
    import inspect
    import textwrap

    out: List[Diagnostic] = []

    def scan_fn(fn, label):
        try:
            src = textwrap.dedent(inspect.getsource(fn))
            tree = ast.parse(src)
        except (OSError, TypeError, SyntaxError):
            return
        code = getattr(fn, "__code__", None)
        filename = code.co_filename if code else "<source>"
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            attr = func.attr if isinstance(func, ast.Attribute) else (
                func.id if isinstance(func, ast.Name) else None)
            if attr in _HOST_CALLBACK_NAMES:
                out.append(Diagnostic(
                    severity="error", contract=CONTRACT_ENSEMBLE,
                    message=(f"host callback `{attr}` in a behavior "
                             "kernel: under the vmapped ensemble runner "
                             "it fires per replica per step on the host "
                             "(or fails to batch entirely)"),
                    hint="compute on-device with jnp ops; read metrics "
                         "through per-replica reducers "
                         "(operations.batch_*) at segment boundaries",
                    location=f"{label} ({filename}:{node.lineno})"))

    def rec(b, path):
        children = tuple(getattr(b, "children", ()) or ())
        if children:
            for i, c in enumerate(children):
                rec(c, f"{path}.b{i}")
            return
        scan_fn(b.pair_fn, f"{path}.pair_fn")
        scan_fn(b.update_fn, f"{path}.update_fn")

    rec(behavior, name)
    return out


def check_ensemble(ensemble) -> List[Diagnostic]:
    """Batch-safety contract of one ensemble family (duck-typed: needs
    ``behavior_fn``, ``param_names``, ``proto_engine()``).

    Four passes, all static — this is what lets ``launch.serve`` reject an
    incompatible scenario request with a diagnostic instead of a trace
    error mid-batch:

    1. the solo engine contracts over the family's proto engine (a family
       whose solo runs are broken is broken batched, too);
    2. an abstract-trace probe of the behavior factory: `eval_shape` with
       parameter *tracers* catches factories that branch on or concretize
       parameter values (``float(params[...])`` radii, ``if beta > 0``) —
       legal with solo floats, fatal under vmap;
    3. structural stability: the behavior built at two different concrete
       parameter points must agree on schema, radius, pair attrs,
       accumulators, and spawn capability (per-replica shape divergence
       cannot batch);
    4. the hot-path lint re-run with ``params`` *traced* (the ensemble
       threads them as per-replica scalars), every finding escalated to an
       ensemble error.
    """
    import jax

    out: List[Diagnostic] = []
    try:
        proto = ensemble.proto_engine()
    except Exception as e:  # noqa: BLE001 — any factory failure is a finding
        return [Diagnostic(
            severity="error", contract=CONTRACT_ENSEMBLE_FACTORY,
            message=f"behavior factory failed at the zero parameter "
                    f"point: {type(e).__name__}: {e}",
            hint="the factory must build at any parameter value — "
                 "structure may not depend on the point",
            location=_fn_label(ensemble.behavior_fn))]
    out.extend(check_engine(proto))

    names = tuple(ensemble.param_names)

    def probe(params):
        ensemble.behavior_fn(params)
        return jnp.zeros(())

    try:
        jax.eval_shape(probe, {n: jax.ShapeDtypeStruct((), jnp.float32)
                               for n in names})
    except Exception as e:  # noqa: BLE001
        msg = str(e).splitlines()[0] if str(e) else type(e).__name__
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_ENSEMBLE_FACTORY,
            message=(f"behavior factory concretizes a per-replica "
                     f"parameter ({type(e).__name__}: {msg})"),
            hint="parameters are tracers under the ensemble runner: no "
                 "float()/if on them; keep radii and shapes static and "
                 "gate numerically inside the kernel",
            location=_fn_label(ensemble.behavior_fn)))
        return out  # the remaining probes need a working factory

    lo = ensemble.behavior_fn({n: jnp.float32(0.25) for n in names})
    hi = ensemble.behavior_fn({n: jnp.float32(0.75) for n in names})
    drift = []
    if lo.schema != hi.schema:
        drift.append("schema")
    if float(lo.radius) != float(hi.radius):
        drift.append("radius")
    if tuple(lo.pair_attrs) != tuple(hi.pair_attrs):
        drift.append("pair_attrs")
    if sorted(lo.acc_spec) != sorted(hi.acc_spec):
        drift.append("accumulators")
    if bool(lo.can_spawn) != bool(hi.can_spawn):
        drift.append("can_spawn")
    if drift:
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_ENSEMBLE_FACTORY,
            message=("behavior structure varies with the parameter "
                     f"point ({', '.join(drift)}): replicas of one "
                     "family must share one trace"),
            hint="move structural choices (schema, radii, accumulator "
                 "specs) out of the swept parameters",
            location=_fn_label(ensemble.behavior_fn)))

    from repro.analysis.lint import lint_behavior
    for d in lint_behavior(lo, "ensemble",
                           static_args={"dt", "self", "cls"}):
        out.append(Diagnostic(
            severity="error", contract=CONTRACT_ENSEMBLE,
            message=f"[{d.contract}] {d.message} (params are traced "
                    "per-replica scalars under the ensemble runner)",
            hint=d.hint, location=d.location))

    out.extend(_scan_host_callbacks(lo, "ensemble"))
    return out


def _fn_label(fn) -> str:
    mod = getattr(fn, "__module__", "")
    name = getattr(fn, "__qualname__", getattr(fn, "__name__", repr(fn)))
    return f"{mod}.{name}" if mod else name
