"""JAX version-compat shims shared by the ABM core and the LM stack.

The pinned environment may run an older JAX (0.4.x) than the code was
written against; these wrappers paper over the renamed/moved APIs so both
layers import one neutral module instead of each other.
"""

from __future__ import annotations

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``jax.make_mesh(..., axis_types=...)`` kwargs, version-compat.

    ``jax.sharding.AxisType`` only exists on newer JAX releases (>= 0.5);
    older ones reject the kwarg entirely, and their meshes are implicitly
    Auto — so omitting it is behavior-preserving.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-compat ``shard_map`` without replication checking.

    Newer JAX (>= 0.5) exposes ``jax.shard_map`` with a ``check_vma`` flag;
    older releases only have ``jax.experimental.shard_map.shard_map`` with
    the equivalent ``check_rep`` flag.  Every shard_map in this repo goes
    through here so the engine runs on both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
