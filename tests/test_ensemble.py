"""Ensemble runner + scenario server tests.

The load-bearing property is *bit-exactness*: replica r of a vmapped
ensemble run must equal the solo run of the same parameter point — same
f32 arithmetic, same RNG stream, same guard words — locally, on a sharded
mesh, and on an uneven RCB partition (the sharded cases run in
subprocesses, as the engine tests do, because XLA placeholder devices
must be configured before jax initializes).
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def _tree_equal(a, b):
    import jax

    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (ka, xa), (_, xb) in zip(la, lb):
        assert np.array_equal(np.asarray(xa), np.asarray(xb)), ka


POINTS = [{"beta": 0.02}, {"beta": 0.08, "sigma": 0.5},
          {"gamma": 0.3, "sir_radius": 1.0}]


def _solo_chunked(ens, eng, s0, n_steps):
    """Solo reference with the exact segment schedule Ensemble.run uses
    (refresh-interval chunks when delta encoding is on)."""
    seg = eng.make_segment_runner(None)
    if not ens.delta_cfg.enabled:
        return seg(s0, n_steps, True)
    r = max(int(ens.delta_cfg.refresh_interval), 1)
    done, s = 0, s0
    while done < n_steps:
        n = min(r, n_steps - done)
        s = seg(s, n, True)
        done += n
    return s


# ---------------------------------------------------------------------------
# Local bit-exactness + padding + cache
# ---------------------------------------------------------------------------

def test_ensemble_bitexact_local_vs_solo():
    from repro.core import GuardConfig
    from repro.core.ensemble import replica_state
    from repro.sims import sir_mechanics as sm

    ens = sm.ensemble_family(interior=(8, 8),
                             guards=GuardConfig(policy="warn"))
    estate = sm.ensemble_init(ens, POINTS, n_agents=200,
                              initial_infected=10)
    out, _ = ens.run(estate, 12)
    for r, p in enumerate(POINTS):
        eng = ens.solo_engine({**sm.ensemble_defaults(), **p})
        solo = eng.make_segment_runner(None)(
            replica_state(estate.state, r), 12, True)
        _tree_equal(solo, replica_state(out.state, r))


def test_ensemble_bitexact_local_delta():
    import jax.numpy as jnp

    from repro.core import DeltaConfig
    from repro.core.ensemble import replica_state
    from repro.sims import sir_mechanics as sm

    delta = DeltaConfig(enabled=True, qdtype=jnp.int16,
                        refresh_interval=4)
    ens = sm.ensemble_family(interior=(8, 8), delta=delta)
    estate = sm.ensemble_init(ens, POINTS, n_agents=150,
                              initial_infected=8)
    out, _ = ens.run(estate, 10)  # 3 refresh chunks: 4 + 4 + 2
    for r, p in enumerate(POINTS):
        eng = ens.solo_engine({**sm.ensemble_defaults(), **p})
        solo = _solo_chunked(ens, eng, replica_state(estate.state, r), 10)
        _tree_equal(solo, replica_state(out.state, r))


def test_padding_is_inert():
    from repro.core.ensemble import replica_state
    from repro.sims import sir_mechanics as sm

    ens = sm.ensemble_family(interior=(8, 8))
    estate = sm.ensemble_init(ens, POINTS, n_agents=120,
                              initial_infected=6)
    out, _ = ens.run(estate, 8)
    padded = ens.pad_to(estate, 8)
    assert padded.replicas == 8 and padded.n_active == 3
    assert list(padded.active) == [True] * 3 + [False] * 5
    out_p, _ = ens.run(padded, 8)
    for r in range(len(POINTS)):
        _tree_equal(replica_state(out.state, r),
                    replica_state(out_p.state, r))


def test_runner_cache_hits_on_same_family():
    from repro.core.ensemble import _RUNNER_CACHE
    from repro.sims import sir_mechanics as sm

    ens = sm.ensemble_family(interior=(8, 8))
    estate = sm.ensemble_init(ens, POINTS[:2], n_agents=100,
                              initial_infected=5)
    s0 = _RUNNER_CACHE.stats()
    ens.run(estate, 4)
    s1 = _RUNNER_CACHE.stats()
    # a second run — and a *rebuilt* Ensemble of the same family — hit
    ens.run(estate, 4)
    ens2 = sm.ensemble_family(interior=(8, 8))
    assert ens2.fingerprint == ens.fingerprint
    ens2.run(estate, 4)
    s2 = _RUNNER_CACHE.stats()
    assert s1.misses >= s0.misses  # first run may build or reuse
    assert s2.misses == s1.misses  # no rebuilds after the first
    assert s2.hits >= s1.hits + 2


def test_per_replica_reducers_and_health():
    from repro.core import GuardConfig, health_counts, operations
    from repro.core.ensemble import ensemble_health_counts, replica_state
    from repro.sims import sir_mechanics as sm

    ens = sm.ensemble_family(interior=(8, 8),
                             guards=GuardConfig(policy="warn"))
    estate = sm.ensemble_init(ens, POINTS, n_agents=150,
                              initial_infected=8)
    out, _ = ens.run(estate, 6)
    counts = operations.batch_attr_counts("state", (sm.S, sm.I, sm.R))(
        out.state)
    assert counts.shape == (3, 3)
    assert (counts.sum(axis=1) == 150).all()
    h = ensemble_health_counts(out)
    assert h.shape[0] == 3
    for r in range(3):
        solo = replica_state(out.state, r)
        np.testing.assert_array_equal(h[r], health_counts(solo))
        st = np.asarray(solo.soa.attrs["state"]).ravel()
        v = np.asarray(solo.soa.valid).ravel()
        expect = [int(((st == s) & v).sum()) for s in (sm.S, sm.I, sm.R)]
        assert list(counts[r]) == expect


# ---------------------------------------------------------------------------
# Sharded + uneven-partition bit-exactness (subprocess: needs devices)
# ---------------------------------------------------------------------------

ENSEMBLE_COMMON = """
import numpy as np, jax
from repro.core import GuardConfig
from repro.core.ensemble import replica_state
from repro.launch.mesh import make_abm_mesh
from repro.sims import sir_mechanics as sm

POINTS = [{"beta": 0.02}, {"beta": 0.08, "sigma": 0.5},
          {"gamma": 0.3, "sir_radius": 1.0}]

def tree_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (ka, xa), (_, xb) in zip(la, lb):
        assert np.array_equal(np.asarray(xa), np.asarray(xb)), ka
"""


def test_ensemble_bitexact_sharded_mesh():
    run_sub(ENSEMBLE_COMMON + """
ens = sm.ensemble_family(interior=(5, 5), mesh_shape=(2, 2),
                         guards=GuardConfig(policy="warn"))
mesh = make_abm_mesh((2, 2))
estate = sm.ensemble_init(ens, POINTS, n_agents=240, initial_infected=12)
out, _ = ens.run(estate, 10, mesh=mesh)
for r, p in enumerate(POINTS):
    eng = ens.solo_engine({**sm.ensemble_defaults(), **p})
    seg = eng.make_segment_runner(mesh)
    solo = seg(replica_state(estate.state, r), 10, True)
    tree_equal(solo, replica_state(out.state, r))
print("sharded ensemble bit-exact")
""")


def test_ensemble_bitexact_uneven_partition():
    run_sub(ENSEMBLE_COMMON + """
from repro.core import Partition
part = Partition.from_widths([(4, 8), (7, 5)])
ens = sm.ensemble_family(partition=part,
                         guards=GuardConfig(policy="warn"))
assert ens.geom.mesh_shape == (2, 2)
mesh = make_abm_mesh((2, 2))
estate = sm.ensemble_init(ens, POINTS[:2], n_agents=200,
                          initial_infected=10)
out, _ = ens.run(estate, 8, mesh=mesh)
from repro.core.ensemble import ensemble_health_counts
h = ensemble_health_counts(out)
assert h.shape[0] == 2 and (h == 0).all(), h
for r, p in enumerate(POINTS[:2]):
    eng = ens.solo_engine({**sm.ensemble_defaults(), **p})
    seg = eng.make_segment_runner(mesh)
    solo = seg(replica_state(estate.state, r), 8, True)
    tree_equal(solo, replica_state(out.state, r))
print("uneven-partition ensemble bit-exact")
""")


# ---------------------------------------------------------------------------
# check_ensemble contract
# ---------------------------------------------------------------------------

def test_check_ensemble_accepts_shipped_family():
    from repro.analysis import check_ensemble
    from repro.sims import sir_mechanics as sm

    assert check_ensemble(sm.ensemble_family()) == []


def test_check_ensemble_rejects_concretizing_factory():
    import dataclasses

    from repro.analysis import check_ensemble
    from repro.core import Domain
    from repro.core.ensemble import Ensemble
    from repro.sims import cell_clustering as cc

    def bad(params):
        return dataclasses.replace(cc.behavior(),
                                   radius=float(params["radius"]))

    ens = Ensemble(
        geom=Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                    cap=24, boundary="toroidal"),
        behavior_fn=bad, param_names=("radius",))
    diags = check_ensemble(ens)
    assert any(d.contract == "ensemble-factory-static"
               and d.severity == "error" for d in diags)


def test_check_ensemble_rejects_param_branch():
    import dataclasses

    import jax.numpy as jnp

    from repro.analysis import check_ensemble
    from repro.core import Domain
    from repro.core.ensemble import Ensemble
    from repro.sims import cell_clustering as cc

    def branching_update(attrs, valid, acc, key, params, dt):
        if params["gain"] > 1.0:  # legal solo (params static), not batched
            f = acc["force"] * 2.0
        else:
            f = acc["force"]
        new = dict(attrs)
        new["pos"] = attrs["pos"] + jnp.where(valid[..., None], f * dt, 0.0)
        return new, valid, jnp.zeros_like(valid), None

    def fam(params):
        return dataclasses.replace(
            cc.behavior(), update_fn=branching_update,
            params={"repulsion": 2.0, "adhesion": 0.6,
                    "same_type_only": 1.0, "gain": params["gain"]})

    ens = Ensemble(
        geom=Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                    cap=24, boundary="toroidal"),
        behavior_fn=fam, param_names=("gain",))
    diags = check_ensemble(ens)
    assert any(d.contract == "ensemble-batch-safe"
               and "hot-python-branch" in d.message for d in diags)


def test_simcheck_cli_ensemble_flag():
    from repro.launch.simcheck import main

    assert main(["--ensemble", "sir_mechanics", "--no-jaxpr"]) == 0


# ---------------------------------------------------------------------------
# Scenario server
# ---------------------------------------------------------------------------

def _server(slot=4, n_agents=120):
    from repro.launch.serve import ScenarioServer, sir_mechanics_family

    return ScenarioServer([sir_mechanics_family(n_agents=n_agents)],
                          slot_size=slot)


def test_serve_streams_frames_per_request():
    from repro.launch.serve import ScenarioRequest

    server = _server()
    rids = [server.submit(ScenarioRequest(
                family="sir_mechanics", params={"beta": b}, steps=9,
                stream_every=3, seed=i))
            for i, b in enumerate((0.02, 0.06))]
    assert server.queue_depth() == 2
    done = server.drain()
    assert done == 2 and server.queue_depth() == 0
    for rid in rids:
        h = server.handle(rid)
        assert h.status == "done"
        assert [s for s, _ in h.frames] == [3, 6, 9]
        for _, f in h.frames:
            assert f.shape == (3,) and int(f.sum()) == 120
    st = server.stats()
    assert st["batches"] == 1 and st["mean_occupancy"] == 0.5


def test_serve_mixed_budgets_share_batch():
    from repro.launch.serve import ScenarioRequest

    server = _server()
    a = server.submit(ScenarioRequest(family="sir_mechanics",
                                      params={}, steps=4))
    b = server.submit(ScenarioRequest(family="sir_mechanics",
                                      params={}, steps=10,
                                      stream_every=4, seed=1))
    server.drain()
    ha, hb = server.handle(a), server.handle(b)
    assert [s for s, _ in ha.frames] == [4]
    assert [s for s, _ in hb.frames] == [4, 8, 10]
    assert server.stats()["batches"] == 1


def test_serve_rejections():
    from repro.launch.serve import ScenarioRequest

    server = _server()
    r1 = server.submit(ScenarioRequest(family="nope", params={}, steps=4))
    h1 = server.handle(r1)
    assert h1.status == "rejected"
    assert h1.diagnostics[0].contract == "serve-unknown-family"
    r2 = server.submit(ScenarioRequest(
        family="sir_mechanics", params={"not_a_knob": 1.0}, steps=4))
    h2 = server.handle(r2)
    assert h2.status == "rejected"
    assert h2.diagnostics[0].contract == "serve-unknown-param"
    assert "not_a_knob" in h2.diagnostics[0].message
    r3 = server.submit(ScenarioRequest(
        family="sir_mechanics", params={}, steps=0))
    assert server.handle(r3).status == "rejected"
    assert server.queue_depth() == 0
    assert server.stats()["requests"]["rejected"] == 3


def test_serve_rejects_unsafe_family_with_diagnostic():
    import dataclasses

    from repro.core import Domain
    from repro.core.ensemble import Ensemble
    from repro.launch.serve import (
        ScenarioFamily, ScenarioRequest, ScenarioServer)
    from repro.sims import cell_clustering as cc

    def bad(params):
        return dataclasses.replace(cc.behavior(),
                                   radius=float(params["radius"]))

    server = ScenarioServer(slot_size=2)
    diags = server.register(ScenarioFamily(
        name="bad", ensemble=Ensemble(
            geom=Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                        cap=24, boundary="toroidal"),
            behavior_fn=bad, param_names=("radius",)),
        init_point=lambda e, seed: None,
        metric=lambda s: np.zeros((1, 1))))
    assert any(d.severity == "error" for d in diags)
    rid = server.submit(ScenarioRequest(family="bad",
                                        params={"radius": 1.0}, steps=2))
    h = server.handle(rid)
    assert h.status == "rejected"
    assert any(d.contract == "ensemble-factory-static"
               for d in h.diagnostics)


def test_serve_second_request_hits_runner_cache():
    from repro.core.ensemble import _RUNNER_CACHE
    from repro.launch.serve import ScenarioRequest

    server = _server(slot=2)
    req = ScenarioRequest(family="sir_mechanics", params={}, steps=3)
    server.submit(req)
    server.drain()
    s1 = _RUNNER_CACHE.stats()
    server.submit(ScenarioRequest(family="sir_mechanics",
                                  params={"beta": 0.09}, steps=3))
    server.drain()
    s2 = _RUNNER_CACHE.stats()
    assert s2.misses == s1.misses
    assert s2.hits > s1.hits
    assert server.stats()["caches"]["ensemble.runner"]["hits"] == s2.hits


# ---------------------------------------------------------------------------
# Satellite units: instrumented caches, bench-row merge
# ---------------------------------------------------------------------------

def test_memoize_counters_and_bound():
    from repro.core.compile_cache import CompiledCache, get_cache, memoize

    calls = []

    @memoize("test.ensemble.memo", maxsize=2)
    def build(x):
        calls.append(x)
        return x * 10

    assert build(1) == 10 and build(1) == 10
    st = get_cache("test.ensemble.memo").stats()
    assert (st.hits, st.misses, st.evictions) == (1, 1, 0)
    build(2), build(3)  # evicts key 1
    assert get_cache("test.ensemble.memo").stats().evictions == 1
    build(1)
    assert calls == [1, 2, 3, 1]

    c = CompiledCache("test.ensemble.raw", maxsize=1)
    assert c.get_or_build("a", lambda: 1) == 1
    assert c.get_or_build("b", lambda: 2) == 2
    assert "a" not in c and "b" in c
    assert c.stats().evictions == 1


def test_engine_and_sims_caches_registered():
    from repro.core.compile_cache import cache_stats
    from repro.sims import cell_clustering as cc

    cc.behavior()
    cc.behavior()
    stats = cache_stats()
    assert "sims.cell_clustering.behavior" in stats
    assert stats["sims.cell_clustering.behavior"]["hits"] >= 1
    for name in ("engine.local_step", "engine.sharded_step",
                 "engine.segment_runner"):
        assert name in stats, sorted(stats)
        assert stats[name]["maxsize"] == 64


def test_bench_results_merge_by_name(tmp_path):
    sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
    try:
        from run import merge_rows
    finally:
        sys.path.pop(0)

    out = tmp_path / "BENCH_results.json"
    out.write_text(json.dumps([
        {"name": "old_row", "us_per_call": 1.0, "derived": "keep me"},
        {"name": "updated", "us_per_call": 2.0, "derived": "stale"}]))
    merged = merge_rows(out, [("updated", 3.0, "fresh"),
                              ("new_row", 4.0, "")])
    by_name = {r["name"]: r for r in merged}
    assert set(by_name) == {"old_row", "updated", "new_row"}
    assert by_name["old_row"]["derived"] == "keep me"
    assert by_name["updated"]["us_per_call"] == 3.0
    # and a corrupt history is rebuilt rather than crashing
    out.write_text("not json")
    assert merge_rows(out, [("a", 1.0, "")])[0]["name"] == "a"
