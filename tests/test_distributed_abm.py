"""Distributed ABM engine tests.

These run in subprocesses because they need XLA placeholder devices
(``xla_force_host_platform_device_count``) which must be set before jax
initializes — and the main pytest process must keep seeing 1 device.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


COMMON = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, DeltaConfig, Engine, Domain, total_agents
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 300
pos = rng.uniform(0.5, 31.5, size=(n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, size=(n,)).astype(np.int32)}

def sorted_positions(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[v]
    return p[np.lexsort(p.T)]
"""


def test_distributed_matches_single_device_oracle():
    out = run_sub(COMMON + """
geom1 = Domain(cell_size=2.0, interior=(16, 16), mesh_shape=(1, 1), cap=16)
eng1 = Engine(geom=geom1, behavior=beh, dt=0.1)
s1 = eng1.init_state(pos, attrs, seed=0)
step1 = eng1.make_local_step()
for _ in range(10):
    s1 = step1(s1, full_halo=True)

geom4 = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=16)
eng4 = Engine(geom=geom4, behavior=beh, dt=0.1)
s4 = eng4.init_state(pos, attrs, seed=0)
from repro.launch.mesh import make_abm_mesh
mesh = make_abm_mesh((2, 2))
step4 = eng4.make_sharded_step(mesh)
for _ in range(10):
    s4 = step4(s4, full_halo=True)

assert total_agents(s4) == n, "agent loss"
err = np.max(np.abs(sorted_positions(s1) - sorted_positions(s4)))
assert err < 1e-4, f"divergence {err}"
print("OK", err)
""")
    assert "OK" in out


def test_distributed_delta_encoding_bounded_drift_and_byte_reduction():
    out = run_sub(COMMON + """
geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=16)
from repro.launch.mesh import make_abm_mesh
mesh = make_abm_mesh((2, 2))

def run(enabled):
    cfg = DeltaConfig(enabled=enabled, qdtype=jnp.int16, refresh_interval=8)
    eng = Engine(geom=geom, behavior=beh, delta_cfg=cfg, dt=0.1)
    s = eng.init_state(pos, attrs, seed=0)
    step = eng.make_sharded_step(mesh)
    byts = []
    for i in range(12):
        full = (not enabled) or (i % 8 == 0)
        s = step(s, full_halo=full)
        byts.append(int(s.halo_bytes[0, 0]))
    return s, byts

s0, b0 = run(False)
s1, b1 = run(True)
assert total_agents(s0) == total_agents(s1) == n
drift = np.max(np.abs(sorted_positions(s0) - sorted_positions(s1)))
assert drift < 0.05, drift
ratio = b0[1] / b1[1]
assert ratio > 1.2, f"no byte reduction: {ratio}"
print("OK drift=%.5f ratio=%.2f" % (drift, ratio))
""")
    assert "OK" in out


def test_toroidal_migration_wraps_domain_seam():
    out = run_sub(COMMON + """
# agents drifting east across the seam must reappear on device 0
# NB: 2x1 mesh of 8x8-cell interiors => domain is 32 x 16
pos = rng.uniform([0.5, 0.5], [31.5, 15.5], size=(n, 2)).astype(np.float32)
geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 1), cap=16,
                boundary="toroidal")
from repro.launch.mesh import make_abm_mesh
mesh = make_abm_mesh((2, 1))

def drift_update(attrs, valid, acc, key, params, dt):
    new = dict(attrs)
    new["pos"] = attrs["pos"] + jnp.where(
        valid[..., None], jnp.asarray([1.5, 0.0]), 0.0)
    return new, valid, jnp.zeros_like(valid), None

beh2 = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
                pair_attrs=("diameter", "ctype"), update_fn=drift_update,
                radius=2.0, params=beh.params)
eng = Engine(geom=geom, behavior=beh2, dt=1.0)
s = eng.init_state(pos, attrs, seed=0)
step = eng.make_sharded_step(mesh)
for _ in range(30):   # 30 * 1.5 = 45 > domain length 32: full wrap
    s = step(s, full_halo=True)
assert total_agents(s) == n, total_agents(s)
lx, ly = geom.domain_size
p = np.asarray(s.soa.attrs["pos"]).reshape(-1, 2)[np.asarray(s.soa.valid).ravel()]
assert (p[:, 0] >= 0).all() and (p[:, 0] <= lx).all()
print("OK")
""")
    assert "OK" in out


def test_spawn_conservation_distributed():
    """Proliferation on 2x2 mesh: spawned counts equal single-device run."""
    out = run_sub("""
import numpy as np, jax
from repro.sims import cell_proliferation as cp
from repro.core.engine import total_agents

from repro.launch.mesh import make_abm_mesh
mesh = make_abm_mesh((2, 2))
s1, m1 = cp.run(n_agents=40, steps=10, interior=(8, 8), mesh_shape=(1, 1))
s4, m4 = cp.run(n_agents=40, steps=10, interior=(4, 4), mesh_shape=(2, 2),
                mesh=mesh)
# spawning is RNG-dependent per device, so counts differ slightly; both must
# grow and conserve (no drops)
assert m1["n_final"] > m1["n_initial"]
assert m4["n_final"] > m4["n_initial"]
assert int(s4.dropped.sum()) == 0
print("OK", m1["n_final"], m4["n_final"])
""")
    assert "OK" in out
