"""Paper-claims validation for the four benchmark simulations (§3.1, Fig 5).
Correctness tests mirror the paper's §3.3: quantitative comparison against
analytical (epidemiology) / reference (oncology) data and qualitative
behavior for clustering."""

import numpy as np
import pytest

from repro.sims import (
    cell_clustering, cell_proliferation, epidemiology, oncology,
)


def test_cell_clustering_emergent_sorting():
    """Same-type adhesion must raise the same-type neighbor fraction well
    above the random-mixing 0.5 baseline (emergent behavior)."""
    _, m = cell_clustering.run(n_agents=300, steps=25, seed=0)
    assert 0.4 < m["same_frac_initial"] < 0.6
    assert m["same_frac_final"] > m["same_frac_initial"] + 0.15


def test_cell_proliferation_grows_population():
    state, m = cell_proliferation.run(n_agents=40, steps=15, seed=0)
    assert m["n_final"] > m["n_initial"] * 1.3
    counts = np.array(m["counts"])
    assert (np.diff(counts) >= 0).all()  # monotone growth
    assert int(state.dropped.sum()) == 0


def test_epidemiology_matches_sir_ode():
    """Spatial SIR with high mobility must track the Kermack–McKendrick ODE
    (the paper's Figure 5 'simulation vs analytical' check)."""
    n, i0, steps = 600, 15, 80
    _, m = epidemiology.run(n_agents=n, steps=steps, initial_infected=i0,
                            seed=1)
    ser = m["series"].astype(float)
    # conservation
    assert (ser.sum(axis=1) == n).all()
    # epidemic wave: I single-peaked (smoothed), R monotone, S monotone dec.
    r = ser[:, 2]
    s = ser[:, 0]
    assert (np.diff(r) >= 0).all()
    assert (np.diff(s) <= 0).all()
    i_curve = ser[:, 1]
    peak = i_curve.argmax()
    assert 2 < peak < steps - 5, f"degenerate epidemic (peak at {peak})"
    assert r[-1] > 0.5 * n, "epidemic failed to spread"
    # ODE comparison: fit effective beta by coarse grid search, then demand
    # the R-curve matches within 12% of N.
    best = np.inf
    for beta_eff in np.linspace(0.2, 3.0, 40):
        ode = epidemiology.sir_ode(n, i0, beta_eff, gamma=0.25, dt=1.0,
                                   steps=steps)
        dev = np.max(np.abs(ode[1:, 2] - r[:len(ode) - 1]))
        best = min(best, dev)
    assert best < 0.12 * n, f"SIR deviates from ODE by {best/n:.2%}"


def test_oncology_spheroid_growth():
    """Tumor diameter (bounding-box method, §3.4) grows with population."""
    state, m = oncology.run(n_agents=20, steps=30, seed=0)
    ser = m["series"]
    counts = np.array([c for c, _ in ser], float)
    diams = np.array([d for _, d in ser])
    assert counts[-1] > counts[0] * 2
    assert diams[-1] > diams[5]
    # diameter ~ sqrt(count) in 2D packing: correlation must be strong
    corr = np.corrcoef(np.sqrt(counts), diams)[0, 1]
    assert corr > 0.9, corr
