"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and finiteness (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get, names
from repro.data.pipeline import SyntheticLM
from repro.models import params as P
from repro.models.model import build_model
from repro.training.optimizer import AdamW, WSDSchedule
from repro.training.steps import make_serve_decode_step, make_train_step

ALL_ARCHS = names()


@pytest.fixture(scope="module")
def built():
    cache = {}

    def make(name):
        if name not in cache:
            cfg = get(name).smoke
            model = build_model(cfg)
            prm = P.init(model.spec, jax.random.PRNGKey(0))
            cache[name] = (cfg, model, prm)
        return cache[name]

    return make


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name, built):
    cfg, model, prm = built(name)
    pipe = SyntheticLM(cfg, seq_len=64, global_batch=2)
    batch = pipe.batch_for_step(0)
    logits = jax.jit(lambda p, b: model.logits(p, b, remat="none"))(prm, batch)
    s_expect = 64
    assert logits.shape == (2, s_expect, cfg.padded_vocab)
    real = logits[..., :cfg.vocab].astype(jnp.float32)
    assert bool(jnp.all(jnp.isfinite(real)))
    if cfg.padded_vocab != cfg.vocab:
        # padded logit columns masked to -inf
        assert float(logits[..., cfg.vocab:].max()) < -1e29


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_finite_loss(name, built):
    cfg, model, prm = built(name)
    opt = AdamW(schedule=WSDSchedule(warmup_steps=2, stable_steps=5,
                                     decay_steps=2))
    opt_state = opt.init(prm)
    pipe = SyntheticLM(cfg, seq_len=64, global_batch=2)
    step = jax.jit(make_train_step(model, opt, remat="none"))
    p = prm
    for i in range(2):
        p, opt_state, metrics = step(p, opt_state, pipe.batch_for_step(i))
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), p, prm)
    assert max(jax.tree_util.tree_leaves(delta)) > 0


@pytest.mark.parametrize(
    "name", [n for n in ALL_ARCHS if get(n).smoke.family != "audio"]
)
def test_decode_matches_full_forward(name, built):
    """Prefill + decode must reproduce the full-sequence forward logits."""
    cfg, model, prm = built(name)
    pipe = SyntheticLM(cfg, seq_len=32, global_batch=2)
    batch = pipe.batch_for_step(0)
    full = jax.jit(lambda p, b: model.logits(p, b, remat="none"))(prm, batch)

    if cfg.family == "vlm":
        pre_batch = {"tokens": batch["tokens"][:, :16],
                     "patches": batch["patches"]}
        pre_len = 16 + cfg.n_patches
    else:
        pre_batch = {"tokens": batch["tokens"][:, :16]}
        pre_len = 16
    cache = model.init_cache(2, pre_len + 8)
    logits_pre, cache = jax.jit(model.prefill)(prm, pre_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1], np.float32),
        np.asarray(full[:, pre_len - 1], np.float32), atol=0.06, rtol=0.05)

    dec = jax.jit(make_serve_decode_step(model))
    idx = pre_len
    for t in range(3):
        tok = batch["tokens"][:, 16 + t:17 + t]
        logits_d, cache = dec(prm, cache, tok, jnp.int32(idx))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, pre_len + t], np.float32),
            atol=0.06, rtol=0.05)
        idx += 1


def test_full_param_counts_match_published():
    """Exact spec-tree param counts must land near the published sizes."""
    expected = {
        "qwen3-moe-235b-a22b": (230e9, 240e9),
        "phi3.5-moe-42b-a6.6b": (40e9, 44e9),
        "internlm2-20b": (18e9, 21e9),
        "llava-next-mistral-7b": (7.0e9, 7.6e9),
        "minicpm3-4b": (3.8e9, 4.5e9),
        "minicpm-2b": (2.4e9, 3.0e9),
        "olmo-1b": (1.0e9, 1.4e9),
        "hubert-xlarge": (0.9e9, 1.1e9),
        "zamba2-1.2b": (1.0e9, 1.4e9),
        "xlstm-1.3b": (1.2e9, 2.2e9),
    }
    for name, (lo, hi) in expected.items():
        model = build_model(get(name).full)
        n = P.count_params(model.spec)
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
