"""Optional-hypothesis shim for the property-based tests.

The seed suite hard-imported ``hypothesis`` at module scope, turning a
missing dev dependency into a *collection error* that aborted the whole
run.  Importing ``given``/``settings``/``st`` from here instead keeps every
non-property test running and collects the property tests as skips when
hypothesis is absent; CI installs hypothesis so they execute there.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: any strategy call returns
        an inert placeholder (the test body never runs)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda fn: fn
