"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis
property tests (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,sq,skv,hd", [
    (2, 128, 128, 64),
    (1, 256, 256, 128),
    (3, 128, 256, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(bh, sq, skv, hd, causal, dtype):
    if causal and sq != skv:
        pytest.skip("causal requires square layout in this sweep")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (bh, sq, hd), dtype)
    k = jax.random.normal(k2, (bh, skv, hd), dtype)
    v = jax.random.normal(k3, (bh, skv, hd), dtype)
    from repro.kernels.flash_attention import flash_attention_kernel

    out = flash_attention_kernel(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_gqa_wrapper():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (2, 8, 128, 64), jnp.float32)
    k = jax.random.normal(k2, (2, 2, 128, 64), jnp.float32)
    v = jax.random.normal(k3, (2, 2, 128, 64), jnp.float32)
    out = ops.flash_attention_bhsd(q, k, v, causal=True)
    # oracle via repeat
    kr = jnp.repeat(k, 4, axis=1).reshape(16, 128, 64)
    vr = jnp.repeat(v, 4, axis=1).reshape(16, 128, 64)
    want = ref.flash_attention_ref(q.reshape(16, 128, 64), kr, vr,
                                   causal=True).reshape(2, 8, 128, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_path():
    """Kernel must agree with the chunked-scan attention used in models."""
    from repro.models.attention import sdpa_chunked

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 4, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 4, 256, 64), jnp.float32)
    got = ops.flash_attention_bhsd(q, k, v, causal=True)
    want = sdpa_chunked(q, k, v, causal=True, chunk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# neighbor interaction
# ---------------------------------------------------------------------------

def _random_cells(key, c, k, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    pos_i = jax.random.uniform(ks[0], (c, k, 2), dtype, 0, 10)
    diam_i = jax.random.uniform(ks[1], (c, k), dtype, 0.5, 1.5)
    type_i = jax.random.randint(ks[2], (c, k), 0, 2)
    valid_i = jax.random.bernoulli(ks[3], 0.8, (c, k))
    gid_i = jax.random.randint(ks[4], (c, k), 0, 10_000)
    return pos_i, diam_i, type_i, valid_i, gid_i


@pytest.mark.parametrize("c,k", [(8, 8), (16, 16), (4, 32)])
def test_neighbor_force_matches_ref(c, k):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    pos_i, diam_i, type_i, valid_i, gid_i = _random_cells(k1, c, k)
    pos_j, diam_j, type_j, valid_j, gid_j = _random_cells(k2, c, 9 * k)
    kw = dict(radius=2.0, repulsion=2.0, adhesion=0.4)
    got = ops.neighbor_force(pos_i, diam_i, type_i, valid_i, gid_i,
                             pos_j, diam_j, type_j, valid_j, gid_j, **kw)
    want = ref.neighbor_force_ref(pos_i, diam_i, type_i, valid_i,
                                  pos_j, diam_j, type_j, valid_j,
                                  gid_i, gid_j, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# delta codec
# ---------------------------------------------------------------------------

@given(
    n=st.sampled_from([8, 64, 256]),
    l=st.sampled_from([4, 128]),
    seed=st.integers(0, 2**31 - 1),
    amplitude=st.floats(1e-3, 1e3),
)
@settings(max_examples=25, deadline=None)
def test_delta_codec_roundtrip_error_bound(n, l, seed, amplitude):
    """Property: |decode(encode(x)) - x| <= scale/2 (+eps), scale exact max."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    ref_slab = jax.random.normal(k1, (n, l), jnp.float32) * amplitude
    delta = jax.random.normal(k2, (n, l), jnp.float32) * amplitude * 0.01
    x = ref_slab + delta
    q, scale = ops.delta_encode(x, ref_slab)
    out = ops.delta_decode(q, ref_slab, scale)
    err = np.max(np.abs(np.asarray(out) - np.asarray(x)))
    assert err <= float(scale) * 0.5 + 1e-6 * amplitude
    assert q.dtype == jnp.int8


def test_delta_codec_matches_ref():
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    r = jax.random.normal(k1, (64, 32), jnp.float32)
    x = r + jax.random.normal(k2, (64, 32), jnp.float32) * 0.01
    q, scale = ops.delta_encode(x, r)
    want_q = ref.delta_encode_ref(x, r, scale)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want_q))
    out = ops.delta_decode(q, r, scale)
    want_x = ref.delta_decode_ref(q, r, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_x),
                               rtol=1e-6)


def test_delta_codec_wire_bytes():
    """int8 payload is exactly 4x smaller than the f32 slab."""
    x = jnp.ones((128, 16), jnp.float32)
    q, scale = ops.delta_encode(x, jnp.zeros_like(x))
    assert q.nbytes * 4 == x.nbytes


def test_delta_encode_kernel_counts_saturating_elements():
    """Fixed-scale encode reports exactly how many deltas clipped at the
    int8 rail; the adaptive-scale wrapper always reports zero."""
    ref_slab = jnp.zeros((64, 4), jnp.float32)
    x = ref_slab.at[:3, 0].set(10.0).at[5, 1].set(-9.0)
    # scale 0.05 -> |q| = 200 and 180: 4 elements saturate
    from repro.kernels import delta_codec
    q, oflow = delta_codec.delta_encode_kernel(
        x, ref_slab, 0.05, interpret=True)
    assert int(oflow) == 4
    assert int(jnp.max(q)) == 127 and int(jnp.min(q)) == -127
    # exact-covering scale: nothing clips
    _, oflow = delta_codec.delta_encode_kernel(
        x, ref_slab, 10.0 / 127.0, interpret=True)
    assert int(oflow) == 0


def test_delta_encode_fixed_overflow_and_adaptive_zero():
    ref_slab = jnp.zeros((32, 8), jnp.float32)
    x = ref_slab + 1.0
    q, oflow = ops.delta_encode_fixed(x, ref_slab, 1e-3)  # q = 1000
    assert int(oflow) == x.size
    assert int(jnp.max(q)) == 127
    q, scale = ops.delta_encode(x, ref_slab)              # adaptive
    out = ops.delta_decode(q, ref_slab, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-5)


def test_migration_pos_codec_kernel_matches_reference():
    """Pallas migration position codec == the jnp reference used on the
    engine's migration hop (core.delta.encode_migration), including the
    min-image wrap on toroidal axes and the valid-masked overflow count;
    round-trip error is bounded by scale/2 per axis."""
    from repro.core.delta import (
        DeltaConfig, decode_migration, encode_migration,
    )
    from repro.kernels import delta_codec

    rng = np.random.default_rng(7)
    n, d = 96, 2
    lsz = np.asarray([32.0, 24.0], np.float32)
    toroidal = (True, False)
    center = jnp.asarray([16.0, 12.0], jnp.float32)
    half_rng = np.asarray([18.0, 14.0], np.float32)
    pos = jnp.asarray(rng.uniform([0, 0], lsz, (n, d)), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    cfg = DeltaConfig(migration=jnp.int16)

    want, want_of = encode_migration(
        {"pos": pos, "valid": valid}, "pos", center, half_rng, cfg,
        lsz=lsz, toroidal=toroidal)
    scale = jnp.asarray(half_rng) / 32767.0
    got_q, got_of = delta_codec.migration_pos_encode_kernel(
        pos, center, scale, valid=valid, lsz=lsz, toroidal=toroidal,
        interpret=True)
    v = np.asarray(valid)
    np.testing.assert_array_equal(np.asarray(got_q)[v],
                                  np.asarray(want["pos"])[v])
    assert int(got_of) == int(want_of) == 0

    got_pos = delta_codec.migration_pos_decode_kernel(
        got_q, center, scale, lsz=lsz, toroidal=toroidal, interpret=True)
    want_dec = decode_migration(
        dict(want), "pos", half_rng, cfg, lsz=lsz, toroidal=toroidal)
    # same math, different fusion: the interpret-mode kernel and the XLA
    # reference may differ in the last ulp of center + q*scale
    np.testing.assert_allclose(np.asarray(got_pos)[v],
                               np.asarray(want_dec["pos"])[v], atol=1e-5)
    # quantization error bound (min-image distance on the toroidal axis)
    err = np.abs(np.asarray(got_pos) - np.asarray(pos))[v]
    err[:, 0] = np.minimum(err[:, 0], lsz[0] - err[:, 0])
    assert err.max() <= float(np.max(scale)) * 0.5 + 1e-5
