"""Unit + property tests for the TeraAgent core engine (single device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (
    AgentSchema, Behavior, DeltaConfig, Engine, Domain, total_agents,
)
from repro.core.agent_soa import AgentSoA, POS
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.delta import (
    DeltaConfig as DC, decode_delta, encode_delta, payload_bytes,
)
from repro.core.grid import bin_agents
from repro.core import load_balance as lb


SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


def make_engine(interior=(8, 8), cap=16, boundary="closed", delta=None):
    geom = Domain(cell_size=2.0, interior=interior, mesh_shape=(1, 1),
                    cap=cap, boundary=boundary)
    beh = Behavior(
        schema=SCHEMA, pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
        radius=2.0,
        params={"repulsion": 2.0, "adhesion": 0.4, "same_type_only": 1.0,
                "max_step": 0.5})
    return Engine(geom=geom, behavior=beh,
                  delta_cfg=delta or DeltaConfig(enabled=False), dt=0.1)


def make_state(eng, n=200, seed=0):
    rng = np.random.default_rng(seed)
    lx, ly = eng.geom.domain_size
    pos = rng.uniform(0.5, lx - 0.5, size=(n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    return eng.init_state(pos, attrs, seed=seed)


# ---------------------------------------------------------------------------
# binning
# ---------------------------------------------------------------------------

def test_binning_places_agents_in_correct_cells():
    eng = make_engine()
    geom = eng.geom
    pos = np.array([[0.1, 0.1], [3.9, 0.1], [15.9, 15.9]], np.float32)
    attrs = {
        POS: jnp.asarray(pos),
        "gid_rank": jnp.zeros(3, jnp.int32),
        "gid_count": jnp.arange(3, dtype=jnp.int32),
        "diameter": jnp.ones(3, jnp.float32),
        "ctype": jnp.zeros(3, jnp.int32),
    }
    soa, dropped = bin_agents(geom, attrs, jnp.ones(3, bool),
                              jnp.zeros(2, jnp.float32))
    assert int(dropped) == 0
    # cell (0,0) interior = index (1,1); (3.9,0.1) -> (2,1); (15.9,15.9)->(8,8)
    assert bool(soa.valid[1, 1].any())
    assert bool(soa.valid[2, 1].any())
    assert bool(soa.valid[8, 8].any())
    assert int(soa.valid.sum()) == 3


def test_binning_overflow_detected():
    eng = make_engine(cap=2)
    geom = eng.geom
    n = 5
    attrs = {
        POS: jnp.full((n, 2), 0.5),
        "gid_rank": jnp.zeros(n, jnp.int32),
        "gid_count": jnp.arange(n, dtype=jnp.int32),
        "diameter": jnp.ones(n, jnp.float32),
        "ctype": jnp.zeros(n, jnp.int32),
    }
    _, dropped = bin_agents(geom, attrs, jnp.ones(n, bool),
                            jnp.zeros(2, jnp.float32))
    assert int(dropped) == 3


# ---------------------------------------------------------------------------
# step invariants
# ---------------------------------------------------------------------------

def test_agent_count_conserved_and_finite():
    eng = make_engine()
    state = make_state(eng, 300)
    step = eng.make_local_step()
    for _ in range(10):
        state = step(state, full_halo=True)
    assert total_agents(state) == 300
    assert int(state.dropped.sum()) == 0
    pos = np.asarray(state.soa.attrs[POS])
    assert np.isfinite(pos).all()


def test_closed_boundary_keeps_agents_inside():
    eng = make_engine(boundary="closed")
    state = make_state(eng, 200)
    step = eng.make_local_step()
    for _ in range(15):
        state = step(state, full_halo=True)
    lx, ly = eng.geom.domain_size
    pos = np.asarray(state.soa.attrs[POS]).reshape(-1, 2)
    v = np.asarray(state.soa.valid).ravel()
    assert (pos[v] >= 0).all() and (pos[v, 0] <= lx).all() \
        and (pos[v, 1] <= ly).all()


def test_gids_remain_unique():
    eng = make_engine()
    state = make_state(eng, 250)
    step = eng.make_local_step()
    for _ in range(5):
        state = step(state, full_halo=True)
    v = np.asarray(state.soa.valid).ravel()
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    keys = gr.astype(np.int64) * (1 << 32) + gc
    assert len(np.unique(keys)) == len(keys)


# ---------------------------------------------------------------------------
# delta codec (module-level, property-based)
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1),
       amp=st.floats(1e-2, 1e2),
       qdtype=st.sampled_from(["int8", "int16"]))
@settings(max_examples=20, deadline=None)
def test_delta_closed_loop_refs_stay_in_sync(seed, amp, qdtype):
    """Sender's new reference must equal receiver's reconstruction, and the
    error is bounded by the quantization step."""
    cfg = DC(enabled=True, qdtype=jnp.dtype(qdtype), refresh_interval=8)
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    ref = {"pos": jax.random.normal(k1, (16, 4), jnp.float32) * amp,
           "flag": jnp.zeros((16,), jnp.int32)}
    x = {"pos": ref["pos"] + jax.random.normal(k2, (16, 4)) * amp * 0.01,
         "flag": jnp.ones((16,), jnp.int32)}
    payload, ref_sender, oflow = encode_delta(x, ref, cfg)
    assert int(oflow) == 0  # adaptive scale never saturates
    recon, ref_receiver = decode_delta(payload, ref, cfg)
    for k in ref_sender:
        np.testing.assert_array_equal(np.asarray(ref_sender[k]),
                                      np.asarray(ref_receiver[k]))
    qmax = 127.0 if qdtype == "int8" else 32767.0
    err = np.max(np.abs(np.asarray(recon["pos"]) - np.asarray(x["pos"])))
    max_delta = np.max(np.abs(np.asarray(x["pos"] - ref["pos"])))
    # quantization half-step + f32 rounding on values of magnitude ~amp
    assert err <= max_delta / qmax * 0.51 + 4e-6 * amp
    # non-float attrs pass through exactly
    np.testing.assert_array_equal(np.asarray(recon["flag"]),
                                  np.asarray(x["flag"]))


def test_delta_payload_bytes_reduction():
    cfg8 = DC(enabled=True, qdtype=jnp.int8)
    ref = {"pos": jnp.zeros((64, 4), jnp.float32)}
    x = {"pos": jnp.ones((64, 4), jnp.float32)}
    p8, _, _ = encode_delta(x, ref, cfg8)
    full_bytes = payload_bytes(x)
    assert payload_bytes(p8) <= full_bytes // 4 + 8  # + scale scalar


def test_delta_engine_drift_bounded():
    """End-to-end: delta-encoded halo exchange drifts < 1e-3 vs exact."""
    eng_exact = make_engine()
    eng_delta = make_engine(delta=DeltaConfig(
        enabled=True, qdtype=jnp.int16, refresh_interval=8))
    s1 = make_state(eng_exact, 200)
    s2 = make_state(eng_delta, 200)
    step1 = eng_exact.make_local_step()
    step2 = eng_delta.make_local_step()
    for i in range(10):
        s1 = step1(s1, full_halo=True)
        s2 = step2(s2, full_halo=(i % 8 == 0))
    p1 = np.sort(np.asarray(s1.soa.attrs[POS]).reshape(-1, 2)[
        np.asarray(s1.soa.valid).ravel()], axis=0)
    p2 = np.sort(np.asarray(s2.soa.attrs[POS]).reshape(-1, 2)[
        np.asarray(s2.soa.valid).ravel()], axis=0)
    assert np.max(np.abs(p1 - p2)) < 1e-3


# ---------------------------------------------------------------------------
# load balancing planners
# ---------------------------------------------------------------------------

def test_rcb_improves_imbalance_on_skewed_density():
    rng = np.random.default_rng(0)
    w = rng.uniform(0, 1, size=(32, 32))
    w[:8, :8] += 20.0  # hot corner
    own_naive = np.repeat(np.repeat(
        np.arange(16).reshape(4, 4), 8, axis=0), 8, axis=1)
    before = lb.imbalance(lb.device_loads(own_naive, w, 16))
    own = lb.plan_rcb(w, 16)
    after = lb.imbalance(lb.device_loads(own, w, 16))
    assert after < before * 0.5
    assert set(np.unique(own)) == set(range(16))


def test_diffusive_step_moves_load_toward_balance():
    widths = np.array([8, 8, 8, 8])
    col_w = np.ones(32)
    col_w[:8] = 10.0  # device 0 overloaded
    runtimes = np.array([10.0, 1.0, 1.0, 1.0])
    new = lb.plan_diffusive(widths, col_w, runtimes)
    assert new[0] < 8 and new.sum() == 32 and (new >= 1).all()


def test_choose_mesh_shape_prefers_balanced_split():
    w = np.ones((16, 16))
    w[:, :4] = 100.0  # load concentrated in a y-band -> prefer y-splits
    # the legacy signature is a DeprecationWarning shim over
    # choose_partition(..., ownership="equal") since the uneven-ownership
    # refactor; the selection itself is unchanged (shim parity is pinned
    # in tests/test_partition.py)
    with pytest.warns(DeprecationWarning, match="choose_mesh_shape"):
        mx, my = lb.choose_mesh_shape(w, 4)
    assert (mx, my) in [(1, 4), (2, 2), (4, 1)]
    loads_chosen = w.reshape(mx, 16 // mx, my, 16 // my).sum(axis=(1, 3))
    assert lb.imbalance(loads_chosen.ravel()) <= 0.01
