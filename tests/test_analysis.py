"""simcheck acceptance tests: contract checker, jaxpr auditor, hot-path
lint, the construction gate, and the runtime clip fallback.

The five hazards the simcheck PR must catch (each silently corrupted a run
before):

1. ``Behavior.radius > cell_size``          -> ``stencil-soundness`` error
2. per-step displacement >= min slab width  -> ``one-hop-migration`` error
3. fixed delta scale with < 1.0 headroom    -> ``codec-headroom`` error
4. non-permutation ``ppermute`` edge list   -> ``collective-matching`` error
5. ``.item()`` / Python ``if`` in a hot fn  -> ``hot-host-sync`` /
   ``hot-python-branch`` error (lint) and a converted
   ConcretizationTypeError (jaxpr audit)

plus property tests pinning the checker against brute force: the stencil
check accepts iff the actual neighborhood sweep drops no interacting pair,
and the one-hop check flags iff a numpy slab-crossing search finds a
two-cut hop.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.analysis import (
    ContractError,
    Report,
    audit_engine,
    audit_fn,
    check_contracts,
    check_engine,
    displacement_bound,
    enforce,
    lint_behavior,
    lint_hot_fn,
    lint_source,
    min_slab_width_cells,
)
from repro.analysis.contracts import (
    CONTRACT_AURA,
    CONTRACT_HEADROOM,
    CONTRACT_ONE_HOP,
    CONTRACT_PARTITION,
    CONTRACT_STENCIL,
)
from repro.analysis.jaxpr_audit import (
    CONTRACT_COLLECTIVE,
    CONTRACT_HOST_SYNC,
)
from repro.analysis.lint import (
    CONTRACT_HOT_BRANCH,
    CONTRACT_HOT_NUMPY,
    CONTRACT_HOT_SYNC,
    CONTRACT_MUTABLE_DEFAULT,
    CONTRACT_SHADOWED_IMPORT,
    CONTRACT_UNUSED_IMPORT,
)
from repro.core import (
    AgentSchema, Behavior, DeltaConfig, Domain, Engine, Partition,
    Simulation,
)
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.delta import encode_delta
from repro.core.engine import codec_overflow_count
from repro.core.neighbors import sweep_accumulate

SCHEMA = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})


def mech_behavior(radius=2.0, max_step=0.5, **extra):
    params = {"repulsion": 2.0, "adhesion": 0.4, "same_type_only": 1.0,
              "max_step": max_step}
    params.update(extra)
    return Behavior(schema=SCHEMA, pair_fn=soft_repulsion_adhesion,
                    pair_attrs=("diameter", "ctype"),
                    update_fn=displacement_update, radius=radius,
                    params=params)


def contracts_of(diags):
    return {d.contract for d in diags}


# ---------------------------------------------------------------------------
# 1. stencil-soundness: radius vs cell_size
# ---------------------------------------------------------------------------

def test_radius_over_cell_size_is_stencil_error():
    geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1), cap=8)
    diags = check_contracts(geom, mech_behavior(radius=3.0))
    errs = [d for d in diags if d.severity == "error"]
    assert contracts_of(errs) == {CONTRACT_STENCIL}
    # radius == cell_size is the documented boundary: legal
    assert not check_contracts(geom, mech_behavior(radius=2.0))


def test_sharded_radius_violation_adds_aura_error():
    geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(2, 1), cap=8)
    diags = check_contracts(geom, mech_behavior(radius=2.5))
    errs = contracts_of(d for d in diags if d.severity == "error")
    assert CONTRACT_STENCIL in errs and CONTRACT_AURA in errs


def test_composed_stack_reports_offending_leaf():
    from repro.core import compose
    bad = mech_behavior(radius=5.0)
    comp = compose(mech_behavior(radius=2.0), bad)
    geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1), cap=8)
    diags = [d for d in check_contracts(geom, comp)
             if d.contract == CONTRACT_STENCIL]
    assert len(diags) == 1 and "b1" in diags[0].location


def test_simulation_gate_rejects_radius_over_cell_size():
    geom = dict(cell_size=2.0, interior=(6, 6), cap=8)
    with pytest.raises(ContractError) as e:
        Simulation(geom, mech_behavior(radius=3.0), dt=0.1)
    assert CONTRACT_STENCIL in {d.contract for d in e.value.diagnostics}
    # escape hatches
    with pytest.warns(UserWarning, match="simcheck contract"):
        Simulation(geom, mech_behavior(radius=3.0), dt=0.1, check="warn")
    Simulation(geom, mech_behavior(radius=3.0), dt=0.1, check="off")
    with pytest.raises(ValueError, match="check mode"):
        Simulation(geom, mech_behavior(radius=3.0), dt=0.1, check="loose")


def test_engine_check_field_gates_construction():
    geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1), cap=8)
    Engine(geom=geom, behavior=mech_behavior(radius=3.0))  # default: off
    with pytest.raises(ContractError):
        Engine(geom=geom, behavior=mech_behavior(radius=3.0), check="error")


def test_make_sim_gate_and_escape_hatch():
    from repro.sims.common import make_sim
    with pytest.raises(ContractError):
        make_sim(mech_behavior(radius=3.0), cell_size=2.0, interior=(6, 6))
    with pytest.warns(UserWarning, match="simcheck contract"):
        make_sim(mech_behavior(radius=3.0), cell_size=2.0, interior=(6, 6),
                 check="warn")


# ---------------------------------------------------------------------------
# 2. one-hop-migration: displacement vs narrowest slab
# ---------------------------------------------------------------------------

def test_one_hop_hard_bound_error_and_clean_pass():
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(2, 1), cap=8)
    # limit = 4 cells * 2.0 = 8.0 world units on the sharded axis
    bad = check_contracts(geom, mech_behavior(max_step=8.0))
    hop = [d for d in bad if d.contract == CONTRACT_ONE_HOP]
    assert len(hop) == 1 and hop[0].severity == "error"
    assert "axis 0" in hop[0].message
    ok = check_contracts(geom, mech_behavior(max_step=7.5))
    assert CONTRACT_ONE_HOP not in contracts_of(ok)


def test_one_hop_unsharded_axes_unconstrained():
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(1, 1), cap=8)
    assert not check_contracts(geom, mech_behavior(max_step=50.0))


def test_one_hop_stochastic_bound_is_warning():
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(2, 1), cap=8)
    beh = Behavior(schema=SCHEMA, pair_fn=soft_repulsion_adhesion,
                   pair_attrs=("diameter", "ctype"),
                   update_fn=displacement_update, radius=2.0,
                   params={"sigma": 2.5})   # 4*sigma = 10 >= 8
    hop = [d for d in check_contracts(geom, beh)
           if d.contract == CONTRACT_ONE_HOP]
    assert len(hop) == 1 and hop[0].severity == "warning"


def test_one_hop_unverifiable_bound_is_info():
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(2, 1), cap=8)
    beh = Behavior(schema=SCHEMA, pair_fn=soft_repulsion_adhesion,
                   pair_attrs=("diameter", "ctype"),
                   update_fn=displacement_update, radius=2.0, params={})
    hop = [d for d in check_contracts(geom, beh)
           if d.contract == CONTRACT_ONE_HOP]
    assert len(hop) == 1 and hop[0].severity == "info"
    assert displacement_bound(beh).kind == "unknown"


def test_declared_max_displacement_overrides_inference():
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(2, 1), cap=8)
    beh = dataclasses.replace(mech_behavior(max_step=50.0),
                              max_displacement=0.5)
    assert displacement_bound(beh).kind == "hard"
    assert displacement_bound(beh).value == 0.5
    assert CONTRACT_ONE_HOP not in contracts_of(check_contracts(geom, beh))


def test_rcb_narrow_slab_tightens_one_hop_bound():
    base = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1), cap=8)
    geom = base.repartition(Partition.from_widths(((2, 6), (8,))))
    assert min_slab_width_cells(geom, 0) == 2      # limit 4.0 world units
    beh = mech_behavior(max_step=5.0)              # legal on the 4+4 split
    equal = base.with_mesh_shape((2, 1))
    assert CONTRACT_ONE_HOP not in contracts_of(check_contracts(equal, beh))
    hop = [d for d in check_contracts(geom, beh)
           if d.contract == CONTRACT_ONE_HOP]
    assert len(hop) == 1 and hop[0].severity == "error"


# ---------------------------------------------------------------------------
# 3. codec-headroom: fixed quantization scale vs worst-case delta
# ---------------------------------------------------------------------------

def test_codec_headroom_fixed_scale_too_small_is_error():
    geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1), cap=8)
    beh = mech_behavior(max_step=0.5)
    bad = DeltaConfig(enabled=True, qdtype=jnp.int8, scale=1e-3)
    diags = [d for d in check_contracts(geom, beh, bad)
             if d.contract == CONTRACT_HEADROOM]
    assert len(diags) == 1 and diags[0].severity == "error"
    # representable 127e-3 = 0.127 < 0.5
    assert "0.127" in diags[0].message


def test_codec_headroom_warning_band_and_clean():
    geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1), cap=8)
    beh = mech_behavior(max_step=0.5)
    tight = DeltaConfig(enabled=True, qdtype=jnp.int8, scale=0.005)
    diags = [d for d in check_contracts(geom, beh, tight)
             if d.contract == CONTRACT_HEADROOM]
    assert len(diags) == 1 and diags[0].severity == "warning"  # 1.27x
    roomy = DeltaConfig(enabled=True, qdtype=jnp.int8, scale=0.01)
    assert CONTRACT_HEADROOM not in contracts_of(
        check_contracts(geom, beh, roomy))                     # 2.54x
    adaptive = DeltaConfig(enabled=True, qdtype=jnp.int8)      # scale=None
    assert CONTRACT_HEADROOM not in contracts_of(
        check_contracts(geom, beh, adaptive))


# ---------------------------------------------------------------------------
# partition-validity
# ---------------------------------------------------------------------------

def test_partition_validity_cell_size_and_cut_coverage():
    geom = Domain(cell_size=-1.0, interior=(4, 4), mesh_shape=(1, 1), cap=8)
    diags = check_contracts(geom, mech_behavior())
    errs = [d for d in diags if d.severity == "error"]
    assert CONTRACT_PARTITION in contracts_of(errs)
    assert any("must be positive" in d.message for d in errs)


# ---------------------------------------------------------------------------
# 4. jaxpr audit: planted bad ppermute
# ---------------------------------------------------------------------------

def test_audit_fn_flags_duplicate_source_ppermute():
    x = jnp.zeros((4,), jnp.float32)
    bad = lambda v: jax.lax.ppermute(v, "sx", [(0, 1), (0, 0)])  # noqa: E731
    diags = audit_fn(bad, x, axis_env=(("sx", 2),), context="planted")
    hits = [d for d in diags if d.contract == CONTRACT_COLLECTIVE]
    assert len(hits) == 1 and hits[0].severity == "error"
    assert "duplicate sources" in hits[0].message


def test_audit_fn_flags_out_of_range_and_dead_axis():
    x = jnp.zeros((4,), jnp.float32)
    oor = lambda v: jax.lax.ppermute(v, "sx", [(0, 3)])          # noqa: E731
    diags = audit_fn(oor, x, axis_env=(("sx", 2),))
    assert any(d.contract == CONTRACT_COLLECTIVE
               and "outside [0, 2)" in d.message for d in diags)
    # a dead axis name is rejected by jax at trace time; audit_fn converts
    # the NameError into the collective-matching finding it is
    dead = lambda v: jax.lax.ppermute(v, "zz", [(0, 1)])         # noqa: E731
    diags = audit_fn(dead, x, axis_env=(("sx", 2),))
    assert any(d.contract == CONTRACT_COLLECTIVE
               and "zz" in d.message for d in diags)
    # and the jaxpr walker itself flags an axis the live mesh doesn't have
    # (a step traced under one axis env but audited against another)
    from repro.analysis import audit_jaxpr
    closed = jax.make_jaxpr(dead, axis_env=[("zz", 2)])(x)
    diags = audit_jaxpr(closed, {"sx": 2}, context="mismatch")
    assert any(d.contract == CONTRACT_COLLECTIVE
               and "'zz'" in d.message for d in diags)


def test_audit_fn_accepts_partial_ring_permutation():
    x = jnp.zeros((4,), jnp.float32)
    # open-chain halo shift: 0->1, 1->2 (no wrap) — partial is legal
    ok = lambda v: jax.lax.ppermute(v, "sx", [(0, 1), (1, 2)])   # noqa: E731
    assert not audit_fn(ok, x, axis_env=(("sx", 3),))


# ---------------------------------------------------------------------------
# 5. hidden host sync: .item() / Python branch in a hot function
# ---------------------------------------------------------------------------

def _item_update(attrs, valid, acc, key, params, dt):
    drift = attrs["diameter"].sum().item()   # traced -> host escape
    new = dict(attrs)
    new["diameter"] = attrs["diameter"] + drift
    return new, valid, jnp.zeros_like(valid), None


def test_lint_flags_planted_item_in_update_fn():
    beh = dataclasses.replace(mech_behavior(), update_fn=_item_update)
    diags = lint_behavior(beh)
    hits = [d for d in diags if d.contract == CONTRACT_HOT_SYNC]
    assert hits and all(d.severity == "error" for d in hits)
    assert any("update_fn" in d.location
               and "test_analysis.py" in d.location for d in hits)


def test_jaxpr_audit_converts_item_to_diagnostic():
    f = lambda v: v * v.sum().item()                             # noqa: E731
    diags = audit_fn(f, jnp.ones((3,), jnp.float32), context="planted")
    assert [d.contract for d in diags] == [CONTRACT_HOST_SYNC]
    assert diags[0].severity == "error"


def test_lint_flags_python_branch_on_traced_value():
    def branchy(attrs, valid, acc, key, params, dt):
        if valid.sum() > 0:   # tracer branch
            return attrs, valid, jnp.zeros_like(valid), None
        return attrs, valid, valid, None

    diags = lint_hot_fn(branchy, label="branchy")
    assert any(d.contract == CONTRACT_HOT_BRANCH
               and d.severity == "error" for d in diags)


def test_lint_allows_static_branches_and_none_checks():
    def fine(attrs, valid, acc, key, params, dt):
        if params["mode"] > 0:     # params are static
            scale = 2.0
        else:
            scale = 1.0
        if acc is None:            # None-checks are shape-static
            return attrs, valid, jnp.zeros_like(valid), None
        new = dict(attrs)
        new["diameter"] = attrs["diameter"] * scale
        return new, valid, jnp.zeros_like(valid), None

    assert not lint_hot_fn(fine, label="fine")


def test_lint_flags_numpy_in_hot_fn():
    def uses_np(attrs, valid, acc, key, params, dt):
        new = dict(attrs)
        new["diameter"] = attrs["diameter"] + np.float32(1.0)
        return new, valid, jnp.zeros_like(valid), None

    diags = lint_hot_fn(uses_np, label="uses_np")
    assert any(d.contract == CONTRACT_HOT_NUMPY for d in diags)


# ---------------------------------------------------------------------------
# module lint
# ---------------------------------------------------------------------------

def test_lint_source_unused_import_and_noqa():
    src = "import os\nimport sys  # noqa\nprint(1)\n"
    diags = lint_source(src, "mod.py")
    assert [d.contract for d in diags] == [CONTRACT_UNUSED_IMPORT]
    assert "os" in diags[0].message


def test_lint_source_mutable_default_and_shadow():
    src = ("import json\n"
           "def f(x, acc=[]):\n"
           "    acc.append(x)\n"
           "    return acc\n"
           "json = 'oops'\n")
    got = contracts_of(lint_source(src, "mod.py"))
    assert CONTRACT_MUTABLE_DEFAULT in got
    assert CONTRACT_SHADOWED_IMPORT in got


def test_lint_source_subscript_store_is_not_a_shadow():
    src = ("import os\n"
           "os.environ['XLA_FLAGS'] = 'x'\n")
    assert not lint_source(src, "mod.py")


# ---------------------------------------------------------------------------
# jaxpr audit of real engines + the simcheck CLI
# ---------------------------------------------------------------------------

def test_audit_engine_clean_on_healthy_sharded_engine():
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(2, 2), cap=8,
                  boundary="toroidal")
    eng = Engine(geom=geom, behavior=mech_behavior())
    diags = audit_engine(eng)
    assert not [d for d in diags if d.severity != "info"]


def test_audit_engine_flags_item_behavior():
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(1, 1), cap=8)
    beh = dataclasses.replace(mech_behavior(), update_fn=_item_update)
    eng = Engine(geom=geom, behavior=beh)
    diags = audit_engine(eng)
    assert any(d.contract == CONTRACT_HOST_SYNC
               and d.severity == "error" for d in diags)


def test_simulation_validate_returns_clean_report():
    sim = Simulation(dict(interior=(6, 6), cap=12), mech_behavior(),
                     dt=0.1)
    rep = sim.validate()
    assert isinstance(rep, Report)
    assert rep.exit_code(strict=True) == 0


def test_simcheck_cli_shipped_sims_pass_strict(capsys):
    from repro.launch.simcheck import main
    assert main(["--sim", "tumor_spheroid", "--strict"]) == 0
    assert main(["--sim", "epidemiology", "--strict",
                 "--format", "json"]) == 0
    out = capsys.readouterr().out
    assert '"diagnostics"' in out


def test_simcheck_cli_lint_failure_exit_code(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import os\n\n\ndef f(x=[]):\n    return x\n")
    from repro.launch.simcheck import main
    # unused-import / mutable-default are warnings: clean exit by default,
    # failure under --strict
    assert main(["--lint", str(bad)]) == 0
    assert main(["--lint", str(bad), "--strict"]) == 1


def test_simcheck_virtual_variants_cover_uneven_cuts():
    from repro.launch.simcheck import virtual_variants
    geom = Domain(cell_size=2.0, interior=(10, 10), mesh_shape=(1, 1),
                  cap=12)
    eng = Engine(geom=geom, behavior=mech_behavior())
    labels = [lbl for lbl, _ in virtual_variants(eng)]
    assert any(lbl.startswith("mesh=") for lbl in labels)
    assert any(lbl.startswith("rcb=") for lbl in labels)
    # distributed engines are their own coverage
    sharded = Engine(geom=geom.with_mesh_shape((2, 1)),
                     behavior=mech_behavior())
    assert virtual_variants(sharded) == []


# ---------------------------------------------------------------------------
# runtime fallback: fixed-scale delta clipping forces a full refresh
# ---------------------------------------------------------------------------

def test_encode_delta_fixed_scale_counts_overflow():
    cfg = DeltaConfig(enabled=True, qdtype=jnp.int8, scale=0.01)
    ref = {"pos": jnp.zeros((8, 2), jnp.float32)}
    x = {"pos": ref["pos"] + 10.0}           # q = 1000 >> 127
    payload, _, oflow = encode_delta(x, ref, cfg)
    assert int(oflow) == 16
    small = {"pos": ref["pos"] + 0.5}        # q = 50, in range
    _, _, oflow = encode_delta(small, ref, cfg)
    assert int(oflow) == 0


def test_drive_forces_full_refresh_after_clip():
    """A clipping fixed-scale codec must trip the full-refresh fallback at
    the next host control point — the step after a clipped delta exchange
    re-sends full auras instead of stacking reconstruction error."""
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                  cap=24, boundary="toroidal")
    cfg = DeltaConfig(enabled=True, qdtype=jnp.int8, refresh_interval=4,
                      scale=1e-7)            # every nonzero delta clips
    eng = Engine(geom=geom, behavior=mech_behavior(), delta_cfg=cfg, dt=0.1)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.5, 15.5, (250, 2)).astype(np.float32)
    attrs = {"diameter": np.full((250,), 1.2, np.float32),
             "ctype": rng.integers(0, 2, 250).astype(np.int32)}
    state = eng.init_state(pos, attrs, seed=0)

    inner = eng.make_local_step()
    fulls = []

    def spy(s, full_halo):
        fulls.append(bool(full_halo))
        return inner(s, full_halo=full_halo)

    _, state, _ = eng.drive(state, 6, step_fn=spy)
    assert int(codec_overflow_count(state)) > 0
    # schedule alone would be [T, F, F, F, T, F]; the fallback turns every
    # step after a clipped delta exchange into a full refresh
    assert fulls[0] is True and fulls[1] is False
    assert fulls[2] is True


def test_drive_no_fallback_with_adaptive_scale():
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                  cap=24, boundary="toroidal")
    cfg = DeltaConfig(enabled=True, qdtype=jnp.int8, refresh_interval=4)
    eng = Engine(geom=geom, behavior=mech_behavior(), delta_cfg=cfg, dt=0.1)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.5, 15.5, (250, 2)).astype(np.float32)
    attrs = {"diameter": np.full((250,), 1.2, np.float32),
             "ctype": rng.integers(0, 2, 250).astype(np.int32)}
    state = eng.init_state(pos, attrs, seed=0)
    inner = eng.make_local_step()
    fulls = []

    def spy(s, full_halo):
        fulls.append(bool(full_halo))
        return inner(s, full_halo=full_halo)

    _, state, _ = eng.drive(state, 6, step_fn=spy)
    assert int(codec_overflow_count(state)) == 0
    assert fulls == [True, False, False, False, True, False]


# ---------------------------------------------------------------------------
# property: stencil checker vs the actual neighborhood sweep
# ---------------------------------------------------------------------------

def _count_behavior(radius):
    def count_pairs(ai, aj, disp, dist2, params):
        return {"nbr": jnp.ones_like(dist2)}

    def idle(attrs, valid, acc, key, params, dt):
        return dict(attrs), valid, jnp.zeros_like(valid), None

    return Behavior(schema=AgentSchema.create(
                        {"diameter": ((), jnp.float32)}),
                    pair_fn=count_pairs, pair_attrs=("diameter",),
                    update_fn=idle, radius=radius,
                    params={"max_step": 0.0})


def _sweep_pair_count(geom, beh, pos):
    eng = Engine(geom=geom, behavior=beh)
    attrs = {"diameter": np.ones((len(pos),), np.float32)}
    state = eng.init_state(pos, attrs, seed=0)
    acc = sweep_accumulate(geom, state.soa, beh.pair_fn, beh.pair_attrs,
                           beh.radius, beh.params)
    return float(jnp.sum(acc["nbr"]))


def _brute_pair_count(pos, radius):
    p = pos.astype(np.float32)
    d = p[None, :, :] - p[:, None, :]
    dist2 = (d * d).sum(-1)                  # f32, same ops as the sweep
    inr = dist2 <= np.float32(radius * radius)
    return float(inr.sum() - len(p))         # drop self pairs


@given(cell_size=st.sampled_from([1.0, 1.5, 2.0, 3.0]),
       ratio=st.floats(0.3, 2.0),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_stencil_checker_accepts_iff_sweep_drops_no_pair(cell_size, ratio,
                                                         seed):
    if abs(ratio - 1.0) < 0.05:
        ratio = 1.2                          # skirt the exact boundary
    radius = cell_size * ratio
    geom = Domain(cell_size=cell_size, interior=(6, 6), mesh_shape=(1, 1),
                  cap=24, boundary="closed")
    beh = _count_behavior(radius)
    flagged = CONTRACT_STENCIL in contracts_of(check_contracts(geom, beh))
    assert flagged == (ratio > 1.0)

    if not flagged:
        # accepted -> the sweep finds exactly the brute-force pair set
        rng = np.random.default_rng(seed)
        lo, hi = 0.1 * cell_size, 6 * cell_size - 0.1 * cell_size
        pos = rng.uniform(lo, hi, (40, 2)).astype(np.float32)
        assert _sweep_pair_count(geom, beh, pos) \
            == _brute_pair_count(pos, radius)
    else:
        # rejected -> a witness pair inside the radius but two cells apart
        # is silently dropped by the 9-cell sweep
        eps = cell_size * min(0.02, (ratio - 1.0) / 4.0)
        y = 3.0 * cell_size
        pos = np.array([[cell_size - eps, y],
                        [2.0 * cell_size + eps, y]], np.float32)
        assert _brute_pair_count(pos, radius) == 2.0
        assert _sweep_pair_count(geom, beh, pos) == 0.0


# ---------------------------------------------------------------------------
# property: one-hop checker vs numpy slab-crossing brute force
# ---------------------------------------------------------------------------

@given(widths=st.lists(st.integers(1, 6), min_size=2, max_size=4),
       quarter=st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_one_hop_checker_matches_bruteforce_slab_crossing(widths, quarter):
    d = quarter * 0.25 + 0.125   # never ties with an integer slab width
    L = sum(widths)
    base = Domain(cell_size=1.0, interior=(L, 4), mesh_shape=(1, 1),
                  cap=4, boundary="toroidal")
    geom = base.repartition(Partition.from_widths((tuple(widths), (4,))))
    beh = _count_behavior(1.0)
    beh = dataclasses.replace(beh, params={"max_step": d})
    flagged = CONTRACT_ONE_HOP in contracts_of(
        check_contracts(geom, beh))

    # brute force: does any start position cross >= 2 slab boundaries when
    # displaced by d on the ring?  (crossing two cuts = skipping a device)
    cuts = np.cumsum(widths).astype(np.float64)
    periods = int(d // L) + 2
    bounds = np.sort(np.concatenate(
        [cuts + m * L for m in range(periods)]))
    xs = np.arange(0.0, L, 1 / 16.0) + 1 / 32.0
    crossed = (np.searchsorted(bounds, xs + d, side="right")
               - np.searchsorted(bounds, xs, side="right"))
    violation = bool((crossed >= 2).any())
    assert flagged == violation
