"""Uneven ownership tests: box-granular RCB partitions with padded
per-device grids and masked halo exchange.

Covers the `Partition` spec + `Domain` plumbing, the rectilinear planner
and the `choose_mesh_shape` deprecation shim, partition-aware histograms /
flatten, and — property-style, in subprocesses with XLA placeholder
devices — bit-exact parity of sharded stepping on arbitrary randomized
valid partitions against the local single-device oracle (toroidal axes and
spawn paths included), the delta closed-loop refs invariant across a
mid-run re-cut, the facade's `Rebalance(ownership="rcb")` path, and the
elastic partition round-trip.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AgentSchema, Behavior, Domain, Engine, Partition, Simulation,
    total_agents,
)
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.load_balance import (
    choose_mesh_shape,
    choose_partition,
    equal_split_loads,
    imbalance,
    partition_loads,
    plan_rectilinear,
)
from repro.core.reshard import flatten_state, occupancy_histogram

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ---------------------------------------------------------------------------
# Partition spec + Domain plumbing
# ---------------------------------------------------------------------------

def test_partition_construction_and_derived():
    p = Partition(cuts=((0, 3, 16), (0, 7, 12)))
    assert p.ndim == 2
    assert p.mesh_shape == (2, 2)
    assert p.global_cells == (16, 12)
    assert p.widths == ((3, 13), (7, 5))
    assert p.max_widths == (13, 7)
    assert not p.is_equal
    assert p.scale(2).cuts == ((0, 6, 32), (0, 14, 24))
    # padded allocation 13*7 per device * 4 devices over 16*12 owned cells
    assert p.pad_fraction() == pytest.approx(4 * 13 * 7 / (16 * 12) - 1)

    eq = Partition.equal((16, 12), (2, 2))
    assert eq.is_equal and eq.widths == ((8, 8), (6, 6))
    assert Partition.from_widths(((3, 13), (7, 5))) == p
    assert hash(Partition(cuts=((0, 3, 16), (0, 7, 12)))) == hash(p)


def test_partition_validation():
    with pytest.raises(ValueError, match="strictly increasing"):
        Partition(cuts=((0, 5, 5, 16), (0, 12)))
    with pytest.raises(ValueError, match="start at 0"):
        Partition(cuts=((1, 16), (0, 12)))
    with pytest.raises(ValueError, match="2-D and 3-D"):
        Partition(cuts=((0, 16),))
    with pytest.raises(ValueError, match="does not divide"):
        Partition.equal((16, 12), (3, 2))


def test_domain_carries_partition_and_normalizes_equal():
    part = Partition(cuts=((0, 3, 16), (0, 12)))
    d = Domain(cell_size=2.0, interior=(13, 12), mesh_shape=(2, 1),
               cap=16, partition=part)
    assert d.uneven
    assert d.global_cells == (16, 12)
    assert d.domain_size == (32.0, 24.0)
    # an equal Partition IS the legacy geometry: it normalizes away so
    # hashes/compiled-cache keys match the pre-Partition Domain bit-exactly
    deq = Domain(cell_size=2.0, interior=(8, 12), mesh_shape=(2, 1), cap=16,
                 partition=Partition.equal((16, 12), (2, 1)))
    dplain = Domain(cell_size=2.0, interior=(8, 12), mesh_shape=(2, 1),
                    cap=16)
    assert deq == dplain and hash(deq) == hash(dplain) and not deq.uneven

    # repartition: same global cells, padded interior, normalizing
    d2 = dplain.repartition(part)
    assert d2 == d
    assert d2.with_mesh_shape((2, 1)) == dplain    # drops the partition
    assert d2.repartition(Partition.equal((16, 12), (2, 1))) == dplain

    with pytest.raises(ValueError, match="does not match"):
        Domain(cell_size=2.0, interior=(13, 12), mesh_shape=(4, 1),
               cap=16, partition=part)
    with pytest.raises(ValueError, match="max slab widths"):
        Domain(cell_size=2.0, interior=(16, 12), mesh_shape=(2, 1),
               cap=16, partition=part)
    with pytest.raises(ValueError, match="covers"):
        dplain.repartition(Partition(cuts=((0, 3, 14), (0, 12))))


def test_device_origin_and_owned_widths_uneven():
    part = Partition(cuts=((0, 3, 16), (0, 7, 12)))
    d = Domain(cell_size=2.0, interior=(13, 7), mesh_shape=(2, 2), cap=16,
               partition=part)
    o = d.device_origin((jnp.int32(1), jnp.int32(0)))
    np.testing.assert_allclose(np.asarray(o), [6.0, 0.0])
    w = d.owned_widths((jnp.int32(1), jnp.int32(1)))
    assert [int(v) for v in w] == [13, 5]
    # equal domains report no owned widths: the legacy static-index paths
    assert Domain(cell_size=2.0, interior=(8, 8)).owned_widths(
        (jnp.int32(0), jnp.int32(0))) is None


# ---------------------------------------------------------------------------
# Planner: rectilinear cuts + deprecation shim
# ---------------------------------------------------------------------------

def _clustered_hist(seed=0, n=600):
    rng = np.random.default_rng(seed)
    c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
    pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5)
    hist, _, _ = np.histogram2d(pos[:, 0], pos[:, 1], bins=(16, 16),
                                range=((0, 32), (0, 32)))
    return hist


def test_plan_rectilinear_beats_equal_on_clustered_density():
    hist = _clustered_hist()
    eq = imbalance(equal_split_loads(hist, (2, 2)))
    part = plan_rectilinear(hist, (2, 2))
    assert part.mesh_shape == (2, 2)
    assert part.global_cells == hist.shape
    un = imbalance(partition_loads(hist, part))
    assert un < eq
    # loads account for every box exactly once
    assert partition_loads(hist, part).sum() == pytest.approx(hist.sum())


def test_choose_partition_scans_factorizations_and_ownership_modes():
    hist = _clustered_hist()
    eq = choose_partition(hist, 4, ownership="equal")
    un = choose_partition(hist, 4, ownership="rcb")
    assert eq.partition.is_equal
    assert un.imbalance <= eq.imbalance + 1e-12
    with pytest.raises(ValueError, match="unknown ownership"):
        choose_partition(hist, 4, ownership="diffusive")
    # uneven cuts don't need divisibility: 5 devices over 16x16 boxes
    un5 = choose_partition(hist, 5, ownership="rcb")
    assert np.prod(un5.mesh_shape) == 5


def test_choose_mesh_shape_shim_warns_and_matches_partition_path():
    """GridGeom-precedent deprecation shim: same selection, plus a
    DeprecationWarning from the legacy signature."""
    hist = _clustered_hist()
    with pytest.warns(DeprecationWarning, match="choose_mesh_shape"):
        legacy = choose_mesh_shape(hist, 4)
    assert legacy == choose_partition(hist, 4,
                                      ownership="equal").mesh_shape
    # the historical tie-break and scan order: every divisor factorization
    # (incl. non-powers of two) of a 3-D histogram
    hist3 = np.random.default_rng(1).random((4, 4, 6))
    with pytest.warns(DeprecationWarning):
        legacy3 = choose_mesh_shape(hist3, 6)
    assert np.prod(legacy3) == 6
    assert legacy3 == choose_partition(hist3, 6,
                                       ownership="equal").mesh_shape
    # no divisor factorization divides the grid -> the historical error
    with pytest.raises(ValueError, match="factorization"), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        choose_mesh_shape(np.ones((5, 7)), 4)


def test_plan_reshard_survives_equal_planner_failure():
    """A box grid with no equal-split factorization (7x7 boxes, 4 devices)
    must still produce the realizable uneven plan — the equal planner's
    ValueError may not abort planning (code-review regression)."""
    from repro.core.reshard import plan_reshard

    part = Partition.from_widths(((3, 4), (3, 4)))
    geom = Domain(cell_size=2.0, interior=(4, 4), mesh_shape=(2, 2),
                  cap=16, partition=part)
    hist = np.random.default_rng(0).random((7, 7)) + 0.1
    plan = plan_reshard(hist, geom)
    assert plan.partition is not None
    assert plan.imbalance == float("inf")     # no equal plan exists
    assert np.prod(plan.partition.mesh_shape) == 4
    # nothing realizable at all -> the historical error still surfaces
    with pytest.raises(ValueError, match="factorization"):
        plan_reshard(np.ones((1, 3)), Domain(
            cell_size=2.0, interior=(1, 3), mesh_shape=(1, 1), cap=16),
            n_devices=5)


def test_domain_rejects_box_misaligned_partition():
    """Cut positions must lie on partitioning-box boundaries: fail where
    the partition is supplied, not mid-run in the first rebalance check
    (code-review regression)."""
    part = Partition.from_widths(((3, 5), (4, 4)))
    with pytest.raises(ValueError, match="aligned to"):
        Domain(cell_size=1.0, interior=(5, 4), mesh_shape=(2, 2),
               box_factor=2, partition=part)
    # aligned cuts construct fine with the same box_factor
    ok = Partition.from_widths(((2, 6), (4, 4)))
    d = Domain(cell_size=1.0, interior=(6, 4), mesh_shape=(2, 2),
               box_factor=2, partition=ok)
    assert d.box_grid == (4, 4)


# ---------------------------------------------------------------------------
# Histograms / flatten respect cut positions (host-side, no device mesh)
# ---------------------------------------------------------------------------

MECH_SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


def _mech_behavior():
    return Behavior(
        schema=MECH_SCHEMA, pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
        radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                            "same_type_only": 1.0, "max_step": 0.5})


def test_uneven_histogram_and_flatten_respect_cuts():
    part = Partition(cuts=((0, 3, 16), (0, 7, 12)))
    geom = Domain(cell_size=2.0, interior=(13, 7), mesh_shape=(2, 2),
                  cap=32, partition=part)
    eng = Engine(geom=geom, behavior=_mech_behavior(), dt=0.1)
    rng = np.random.default_rng(0)
    n = 400
    pos = rng.uniform(0.5, [31.5, 23.5], (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    state = eng.init_state(pos, attrs, seed=0)

    hist = occupancy_histogram(geom, state)
    assert hist.shape == geom.box_grid
    assert hist.sum() == n
    # the histogram is the true global cell occupancy: padding cells of the
    # uneven blocks must not shift any counts
    want, _ = np.histogramdd(pos, bins=geom.global_cells,
                             range=[(0, 32), (0, 24)])
    np.testing.assert_array_equal(hist, want)

    flat = flatten_state(geom, state)
    assert flat.positions.shape == (n, 2)
    order = np.lexsort(flat.positions.T)
    np.testing.assert_allclose(flat.positions[order],
                               pos[np.lexsort(pos.T)], atol=0)
    gids = (np.asarray(flat.attrs["gid_rank"], np.int64) << 32) | \
        np.asarray(flat.attrs["gid_count"], np.int64)
    assert len(np.unique(gids)) == n


# ---------------------------------------------------------------------------
# Property-style: arbitrary valid partitions bit-exact vs the local oracle
# ---------------------------------------------------------------------------

# Deterministic behavior for cross-partition bit-exactness: the pair
# accumulator is a neighbor count (order-independent float sum of exact
# small integers) and the drift/spawn are deterministic functions of it, so
# every partition of the same global domain must produce bit-identical
# trajectories — floats and all.
DET_COMMON = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, Domain, Engine, Partition, total_agents
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})

def count_pair(ai, aj, disp, dist2, params):
    return {"cnt": jnp.ones_like(dist2)}

def det_update(attrs, valid, acc, key, params, dt):
    new = dict(attrs)
    # per-step displacement stays under one NSG cell (cell_size 2.0), the
    # engine's one-device-hop migration contract — the same bound every
    # bundled sim enforces via max_step (docs/domains.md)
    step = jnp.asarray([1.25, -0.75], jnp.float32) * (
        1.0 + 0.0625 * jnp.minimum(acc["cnt"], 8.0)[..., None])
    new["pos"] = attrs["pos"] + jnp.where(valid[..., None], step, 0.0)
    spawn = valid & (acc["cnt"] == 3.0) & (attrs["ctype"] == 1)
    child = dict(new)
    # the child's total displacement from the parent's old cell must also
    # stay under one cell (1.875 + 0.1 < 2.0), same one-hop contract
    child["pos"] = new["pos"] + jnp.asarray([0.1, 0.05], jnp.float32)
    child["ctype"] = jnp.zeros_like(attrs["ctype"])   # children never spawn
    return new, valid, spawn, child

beh = Behavior(schema=schema, pair_fn=count_pair, pair_attrs=("ctype",),
               update_fn=det_update, radius=2.0, params={}, can_spawn=True)

GX, GY = 16, 12
BOUNDARY = ("toroidal", "closed")
rng = np.random.default_rng(11)
n = 220
pos = rng.uniform(0.5, [2 * GX - 0.5, 2 * GY - 0.5], (n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}

def fingerprint(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[v]
    c = np.asarray(state.soa.attrs["ctype"]).ravel()[v]
    d = np.asarray(state.soa.attrs["diameter"]).ravel()[v]
    o = np.lexsort((d, c, p[:, 1], p[:, 0]))
    return p[o], c[o], d[o]
"""


def test_random_partitions_bit_exact_with_local_oracle():
    """Property-style: randomized valid partitions (both mesh orientations,
    uneven cuts on both axes, toroidal x / closed y, deterministic spawn)
    step bit-exactly like the single-device oracle."""
    out = run_sub(DET_COMMON + """
geom1 = Domain(cell_size=2.0, interior=(GX, GY), cap=48, boundary=BOUNDARY)
eng1 = Engine(geom=geom1, behavior=beh, dt=1.0)
s1 = eng1.init_state(pos, attrs, seed=0)
_, s1, _ = eng1.drive(s1, 10)
want = fingerprint(s1)
assert total_agents(s1) > n       # the spawn path fired

prng = np.random.default_rng(5)
def random_cuts(total, parts):
    inner = np.sort(prng.choice(np.arange(1, total), parts - 1,
                                replace=False))
    return (0,) + tuple(int(v) for v in inner) + (total,)

cases = []
for trial in range(2):
    cases.append(Partition(cuts=(random_cuts(GX, 2), random_cuts(GY, 2))))
cases.append(Partition(cuts=(random_cuts(GX, 4), (0, GY))))

for part in cases:
    geom = Domain(cell_size=2.0, interior=part.max_widths,
                  mesh_shape=part.mesh_shape, cap=48, boundary=BOUNDARY,
                  partition=part)
    assert geom.uneven and geom.global_cells == (GX, GY), part.cuts
    eng = Engine(geom=geom, behavior=beh, dt=1.0)
    s = eng.init_state(pos, attrs, seed=0)
    _, s, _ = eng.drive(s, 10, mesh=make_abm_mesh(part.mesh_shape))
    assert int(s.dropped.sum()) == 0, part.cuts
    got = fingerprint(s)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b, err_msg=str(part.cuts))
    print("OK", part.cuts)
print("DONE", len(cases))
""")
    assert "DONE 3" in out


def test_uneven_delta_refs_closed_loop_across_recut():
    """Masked halo delta references: the per-directed-edge closed-loop
    invariant (my xp_out == my +x neighbor's xm_in) holds on an uneven
    partition under arbitrary full/delta mixes, and again after a mid-run
    re-cut onto a DIFFERENT partition (refs reset -> forced full refresh
    closes the loop on the new cuts)."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, DeltaConfig, Domain, Engine, Partition, total_agents
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.core.reshard import reshard_state
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 240
pos = rng.uniform(0.5, [31.5, 15.5], size=(n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}

def assert_closed_loop(state, mx):
    refs = state.refs
    for i in range(mx - 1):
        for field in refs["xp_out"]:
            np.testing.assert_array_equal(
                np.asarray(refs["xp_out"][field])[i, 0],
                np.asarray(refs["xm_in"][field])[i + 1, 0],
                err_msg=f"xp@{i} {field}")
            np.testing.assert_array_equal(
                np.asarray(refs["xm_out"][field])[i + 1, 0],
                np.asarray(refs["xp_in"][field])[i, 0],
                err_msg=f"xm@{i} {field}")

cfg = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=6)
part = Partition(cuts=((0, 5, 16), (0, 8)))
geom = Domain(cell_size=2.0, interior=(11, 8), mesh_shape=(2, 1), cap=24,
              partition=part)
eng = Engine(geom=geom, behavior=beh, delta_cfg=cfg, dt=0.1)
state = eng.init_state(pos, attrs, seed=0)
step = eng.make_sharded_step(make_abm_mesh((2, 1)))

sched = np.random.default_rng(7)
for full in [True] + list(sched.random(11) < 0.3):
    state = step(state, full_halo=bool(full))
    assert_closed_loop(state, 2)
assert total_agents(state) == n

# mid-run re-cut onto different uneven cuts (still 2 devices)
part2 = Partition(cuts=((0, 11, 16), (0, 8)))
eng2, state2 = reshard_state(eng, state, partition=part2)
assert eng2.geom.uneven and eng2.geom.partition == part2
assert eng2.geom.interior == (11, 8)
step2 = eng2.make_sharded_step(make_abm_mesh((2, 1)))
state2 = step2(state2, full_halo=True)     # refs reset -> full closes loop
assert_closed_loop(state2, 2)
for full in [False, False, True, False]:
    state2 = step2(state2, full_halo=full)
    assert_closed_loop(state2, 2)
assert total_agents(state2) == n
print("OK")
""", devices=2)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Equal-split pinning: Partition.equal runs bit-exact with the legacy engine
# ---------------------------------------------------------------------------

def _sim_cases():
    from repro.sims import (cell_clustering, cell_proliferation,
                            epidemiology, oncology)
    return {
        "cell_clustering": (cell_clustering, 2),
        "cell_proliferation": (cell_proliferation, 2),
        "epidemiology": (epidemiology, 2),
        "oncology": (oncology, 2),
        "tumor_spheroid": (None, 3),
    }


@pytest.mark.parametrize("name", sorted(_sim_cases()))
def test_equal_partition_bit_exact_with_legacy_engine(name):
    """`Partition.equal` is the pre-PR engine: every bundled 2-D sim plus
    the 3-D spheroid runs bit-identically whether the geometry is built
    plain or through an (equal) Partition — the normalized Domains share
    hash and compiled-cache keys, so this also pins zero re-tracing."""
    from repro.sims.common import make_sim

    if name == "tumor_spheroid":
        from repro.sims import tumor_spheroid as mod
        kw = dict(interior=(4, 4, 4), mesh_shape=(1, 1, 1), cap=32)
        init = lambda sim: mod.init(sim, 30, seed=3)
    else:
        mod = _sim_cases()[name][0]
        kw = dict(interior=(6, 6), mesh_shape=(1, 1), cap=32)
        if name == "epidemiology":
            init = lambda sim: mod.init(sim, 60, 6, seed=3)
        else:
            init = lambda sim: mod.init(sim, 60, seed=3)
    beh = mod.behavior()

    def final(partition):
        sim = make_sim(beh, partition=partition, **(
            {k: v for k, v in kw.items()
             if partition is None or k == "cap"}))
        init(sim)
        sim.run(4)
        return sim.state

    eq = Partition.equal(kw["interior"], kw["mesh_shape"])
    s1 = final(None)
    s2 = final(eq)
    np.testing.assert_array_equal(np.asarray(s1.soa.valid),
                                  np.asarray(s2.soa.valid))
    for k in s1.soa.attrs:
        np.testing.assert_array_equal(np.asarray(s1.soa.attrs[k]),
                                      np.asarray(s2.soa.attrs[k]),
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(s1.key), np.asarray(s2.key))


# ---------------------------------------------------------------------------
# The facade path: Rebalance(ownership="rcb") end to end
# ---------------------------------------------------------------------------

def test_facade_rcb_rebalance_lands_uneven_and_conserves():
    out = run_sub("""
import numpy as np
from repro.core import Rebalance, Simulation
from repro.core.reshard import current_imbalance
from repro.sims import cell_clustering

sim = Simulation(dict(interior=(8, 8), mesh_shape=(2, 2), cap=64),
                 cell_clustering.behavior(adhesion=0.3), dt=0.1,
                 rebalance=Rebalance(every=4, threshold=0.3,
                                     ownership="rcb"))
rng = np.random.default_rng(0)
n = 500
centers = np.asarray([(8.0, 8.0), (24.0, 24.0)])
pos = np.clip(centers[rng.integers(0, 2, n)] + rng.normal(0, 3.0, (n, 2)),
              0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}
sim.init(pos, attrs, seed=0)
before = current_imbalance(sim.geom, sim.state)
sim.run(10)
applied = [r for r in sim.rebalancer.history if r["applied"]]
assert applied, sim.rebalancer.history
assert sim.engine.geom.uneven, "rcb rebalance should land uneven here"
after = current_imbalance(sim.geom, sim.state)
assert sim.n_agents() == n
assert int(np.asarray(sim.state.dropped).sum()) == 0
assert after < before / 2, (before, after)
rec = applied[0]
assert rec["partition_imbalance"] <= rec["rcb_bound"] * 1.1 + 1e-9
# the facade swapped engine/mesh/state consistently: keep running
sim.run(4)
assert sim.n_agents() == n
print("OK", before, "->", after, sim.engine.geom.partition.widths)
""")
    assert "OK" in out
