"""Fused interaction-sweep parity + scan-fused driver equivalence.

Pins the three sweep backends (reference | tiled | pallas) against each
other for every bundled sim behavior and for composed stacks (including the
spawn path), the INTERPRET auto-detection contract, the one-pass migration
invariants, and the segment runner (``Engine.drive`` / ``Simulation.run``
scan fusion) against the per-step loop — the latter under
warnings-as-errors so no deprecation or tracing warning hides in the fused
path.

Tolerances: ``tiled`` re-associates nothing (the j axis is reduced in the
reference's offset order) but XLA fuses the two graphs differently, so FMA
contraction can flip the last bit of float force chains — tiled parity is
pinned to 1e-5 absolute on float accumulators and *exact* on count-valued
ones.  ``pallas`` (interpret mode on CPU) is pinned to the usual kernel
tolerance.  The scan-fused driver runs the identical per-step graph inside
``fori_loop`` and is pinned bit-exact.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AgentSchema, Behavior, DeltaConfig, Domain, Engine, compose,
    total_agents,
)
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.grid import clear_ring
from repro.core.halo import LocalComm, halo_exchange
from repro.core.neighbors import (
    SWEEP_BACKENDS,
    pair_accumulate,
    resolve_sweep_backend,
    sweep_accumulate,
    sweep_accumulate_overlapped,
)
from repro.sims import (
    cell_clustering, cell_proliferation, epidemiology, oncology,
    sir_mechanics,
)

SIM_BEHAVIORS = {
    "cell_clustering": (cell_clustering.behavior(), "closed"),
    "cell_proliferation": (cell_proliferation.behavior(), "closed"),
    "epidemiology": (epidemiology.behavior(), "toroidal"),
    "oncology": (oncology.behavior(), "closed"),
}


def make_state(beh, boundary="closed", n=260, seed=0, interior=(6, 6),
               cap=16):
    geom = Domain(cell_size=2.0, interior=interior, mesh_shape=(1, 1),
                    cap=cap, boundary=boundary)
    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    rng = np.random.default_rng(seed)
    lx, ly = geom.domain_size
    pos = rng.uniform(0.5, lx - 0.5, (n, 2)).astype(np.float32)
    attrs = {}
    for name, _, dtype in beh.schema.fields:
        if dtype == jnp.int32:
            attrs[name] = rng.integers(0, 2, n).astype(np.int32)
        else:
            attrs[name] = rng.uniform(0.6, 1.4, n).astype(np.float32)
    return eng, eng.init_state(pos, attrs, seed=seed)


def run_sweep(eng, state, backend):
    beh = eng.behavior
    fn = jax.jit(lambda soa: sweep_accumulate(
        eng.geom, soa, beh.pair_fn, beh.pair_attrs, beh.radius, beh.params,
        backend=backend))
    return fn(state.soa)


def assert_acc_close(got, want, atol):
    assert set(got) == set(want)
    for k in want:
        g, w = np.asarray(got[k]), np.asarray(want[k])
        if atol == 0:
            np.testing.assert_array_equal(g, w, err_msg=k)
        else:
            np.testing.assert_allclose(g, w, atol=atol, rtol=atol,
                                       err_msg=k)


# ---------------------------------------------------------------------------
# backend parity: all four sims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SIM_BEHAVIORS))
@pytest.mark.parametrize("backend", ["tiled", "pallas"])
def test_sweep_backend_matches_reference(name, backend):
    beh, boundary = SIM_BEHAVIORS[name]
    eng, state = make_state(beh, boundary)
    want = run_sweep(eng, state, "reference")
    got = run_sweep(eng, state, backend)
    assert_acc_close(got, want, atol=1e-5)


def test_tiled_count_accumulators_exact():
    """Integer-valued accumulators (sums of 1.0) have no rounding: the
    tiled sweep must agree with the reference bit-for-bit on them."""
    beh, boundary = SIM_BEHAVIORS["epidemiology"]
    eng, state = make_state(beh, boundary)
    want = run_sweep(eng, state, "reference")
    got = run_sweep(eng, state, "tiled")
    assert_acc_close(got, want, atol=0)   # n_inf: pure neighbor counts


@pytest.mark.parametrize("backend", ["tiled", "pallas"])
def test_sweep_backend_matches_reference_composed(backend):
    """Composed stack (mechanics + SIR, distinct radii, namespaced
    accumulators) through one sweep on every backend."""
    beh = sir_mechanics.behavior()
    eng, state = make_state(beh, "toroidal")
    want = run_sweep(eng, state, "reference")
    assert any(k.startswith("b0.") for k in want)  # namespaced stack
    got = run_sweep(eng, state, backend)
    assert_acc_close(got, want, atol=1e-5)


@pytest.mark.parametrize("backend", ["tiled", "pallas"])
def test_composed_spawning_stack_end_to_end(backend):
    """compose(mechanics, proliferation) driven through the engine on each
    backend vs the reference backend: the spawn path (children, gid issue,
    re-bin) must produce the same population and near-identical positions."""
    comp = compose(cell_clustering.behavior(), cell_proliferation.behavior())
    assert comp.can_spawn

    def final(backend):
        geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1),
                        cap=32)
        eng = Engine(geom=geom, behavior=comp, dt=0.1,
                     sweep_backend=backend)
        rng = np.random.default_rng(3)
        lx, ly = geom.domain_size
        n = 40
        pos = rng.uniform(2.0, lx - 2.0, (n, 2)).astype(np.float32)
        attrs = {"diameter": np.full((n,), 0.8, np.float32),
                 "ctype": rng.integers(0, 2, n).astype(np.int32)}
        state = eng.init_state(pos, attrs, seed=0)
        _, state, _ = eng.drive(state, 8)
        return state

    want = final("reference")
    got = final(backend)
    assert total_agents(got) == total_agents(want) > 40
    sort = lambda s: np.sort(
        np.asarray(s.soa.attrs["pos"]).reshape(-1, 2)[
            np.asarray(s.soa.valid).ravel()], axis=0)
    np.testing.assert_allclose(sort(got), sort(want), atol=1e-4)


@pytest.mark.parametrize("boundary", ["closed", "toroidal"])
def test_3d_pallas_matches_tiled_oracle(boundary):
    """The kernel factory on a 3-D Domain (27-offset stencil, INTERPRET
    mode on CPU) against the tiled oracle: count accumulators exact, float
    accumulators to kernel tolerance; the explicit 2-D path is covered
    bit-for-bit by the parametrized parity tests above."""
    from repro.sims import tumor_spheroid

    beh = tumor_spheroid.behavior()       # composed stack, count acc
    geom = Domain(cell_size=2.0, interior=(3, 4, 5), mesh_shape=(1, 1, 1),
                  cap=12, boundary=boundary)
    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    rng = np.random.default_rng(7)
    n = 150
    size = geom.domain_size
    pos = rng.uniform([0.5] * 3, [s - 0.5 for s in size], (n, 3)
                      ).astype(np.float32)
    attrs = {"diameter": rng.uniform(0.6, 1.4, n).astype(np.float32),
             "ctype": np.ones((n,), np.int32),
             "nutrient": rng.uniform(0.0, 1.0, n).astype(np.float32)}
    state = eng.init_state(pos, attrs, seed=0)

    want = run_sweep(eng, state, "tiled")
    got = run_sweep(eng, state, "pallas")
    assert set(got) == set(want)
    counts = [k for k in want if k.endswith("crowd")]
    assert counts
    for k in counts:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)
    assert_acc_close(got, want, atol=1e-5)


def test_resolve_backend_3d_no_longer_falls_back():
    """The kernel factory now takes 3-D blocks: explicit 'pallas' is legal
    on 3-D domains, and 'auto' resolves identically for 2-D and 3-D (pallas
    on TPU, tiled elsewhere)."""
    assert resolve_sweep_backend("pallas", ndim=3) == "pallas"
    assert resolve_sweep_backend("auto", ndim=3) == \
        resolve_sweep_backend("auto", ndim=2)
    if jax.default_backend() != "tpu":
        assert resolve_sweep_backend("auto", ndim=3) == "tiled"


def test_resolve_backend_and_interpret_auto():
    from repro.kernels import ops

    # auto resolves per JAX backend (this container is CPU -> tiled,
    # interpreted Pallas)
    assert resolve_sweep_backend("auto") in SWEEP_BACKENDS
    if jax.default_backend() != "tpu":
        assert resolve_sweep_backend("auto") == "tiled"
        assert ops.use_interpret() is True
    with pytest.raises(ValueError):
        resolve_sweep_backend("vectorized")
    # explicit overrides win over auto-detection
    assert ops.use_interpret(True) is True
    assert ops.use_interpret(False) is False
    old = ops.INTERPRET
    try:
        ops.INTERPRET = False
        assert ops.use_interpret() is False
        assert ops.use_interpret(True) is True
    finally:
        ops.INTERPRET = old


# ---------------------------------------------------------------------------
# scan-fused driver vs per-step loop (warnings-as-errors)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delta", [False, True])
def test_segment_runner_matches_per_step_drive(delta):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        beh = cell_clustering.behavior()
        cfg = DeltaConfig(enabled=delta, qdtype=jnp.int16,
                          refresh_interval=4)
        geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                        cap=24)
        eng = Engine(geom=geom, behavior=beh, delta_cfg=cfg, dt=0.1)
        rng = np.random.default_rng(0)
        pos = rng.uniform(0.5, 15.5, (250, 2)).astype(np.float32)
        attrs = {"diameter": np.full((250,), 1.0, np.float32),
                 "ctype": rng.integers(0, 2, 250).astype(np.int32)}
        s0 = eng.init_state(pos, attrs, seed=0)

        # per-step loop (explicit step_fn keeps drive on the legacy path)
        _, s1, _ = eng.drive(s0, 10, step_fn=eng.make_local_step())
        # scan-fused: one dispatch per refresh segment
        _, s2, _ = eng.drive(s0, 10)

        np.testing.assert_array_equal(np.asarray(s1.soa.attrs["pos"]),
                                      np.asarray(s2.soa.attrs["pos"]))
        np.testing.assert_array_equal(np.asarray(s1.soa.valid),
                                      np.asarray(s2.soa.valid))
        np.testing.assert_array_equal(np.asarray(s1.key), np.asarray(s2.key))
        assert int(s2.it[0, 0]) == 10


def test_facade_fuses_segments_and_matches_per_step():
    """Simulation.run with a sparse scheduled op fuses the gaps; results
    and op cadence match the per-step facade exactly."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        from repro.core import Simulation

        beh = cell_clustering.behavior()
        geom = dict(interior=(8, 8), cap=24)
        pos, attrs = _inputs()

        sim_fused = Simulation(geom, beh, dt=0.1).init(pos, attrs, seed=0)
        sim_fused.every(5, lambda s: s.n_agents(), name="n")
        sim_fused.run(12)

        sim_step = Simulation(geom, beh, dt=0.1).init(pos, attrs, seed=0)
        sim_step.every(5, lambda s: s.n_agents(), name="n")
        sim_step.run(12, fused=False)   # one dispatch per step

        assert sim_fused.series["n"] == sim_step.series["n"]
        assert sim_fused.iteration == sim_step.iteration == 12
        np.testing.assert_array_equal(
            np.asarray(sim_fused.state.soa.attrs["pos"]),
            np.asarray(sim_step.state.soa.attrs["pos"]))


def _inputs(n=250, seed=0, domain=16.0):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.5, domain - 0.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    return pos, attrs


# ---------------------------------------------------------------------------
# stencil soundness: radius > cell_size is rejected, not silently wrong
# ---------------------------------------------------------------------------

def test_radius_over_cell_size_rejected_and_pins_the_silent_failure():
    """Before the simcheck gate, ``radius > cell_size`` built fine and the
    3**ndim sweep silently dropped every pair between non-adjacent cells.
    The facade now rejects it at construction; ``check="off"`` keeps the
    escape hatch and this test pins the miss the gate is protecting
    against: the identical two-agent configuration interacts when the cell
    covers the radius and is invisible when it doesn't."""
    from repro.analysis import ContractError
    from repro.core import Simulation

    beh = Behavior(
        schema=AgentSchema.create({"diameter": ((), jnp.float32),
                                   "ctype": ((), jnp.int32)}),
        pair_fn=soft_repulsion_adhesion, pair_attrs=("diameter", "ctype"),
        update_fn=displacement_update, radius=3.0,
        params={"repulsion": 2.0, "adhesion": 0.4, "same_type_only": 0.0,
                "max_step": 0.5})

    with pytest.raises(ContractError, match="stencil-soundness"):
        Simulation(dict(cell_size=2.0, interior=(6, 6), cap=8), beh, dt=0.1)

    # two agents 2.2 apart (< radius 3): cells (0, *) and (2, *) under
    # cell_size=2.0 -- non-adjacent, so the sweep never pairs them
    pos = np.array([[1.9, 6.0], [4.1, 6.0]], np.float32)

    def total_force(cell_size, interior):
        geom = Domain(cell_size=cell_size, interior=interior,
                      mesh_shape=(1, 1), cap=8)
        eng = Engine(geom=geom, behavior=beh, dt=0.1)   # check defaults off
        attrs = {"diameter": np.full((2,), 1.0, np.float32),
                 "ctype": np.zeros((2,), np.int32)}
        state = eng.init_state(pos, attrs, seed=0)
        acc = sweep_accumulate(geom, state.soa, beh.pair_fn, beh.pair_attrs,
                               beh.radius, beh.params)
        return float(jnp.sum(jnp.abs(acc["force"])))

    assert total_force(cell_size=4.0, interior=(3, 3)) > 0.0  # honest cell
    assert total_force(cell_size=2.0, interior=(6, 6)) == 0.0  # dropped


# ---------------------------------------------------------------------------
# one-pass migration invariants
# ---------------------------------------------------------------------------

def test_one_pass_migration_conserves_through_diagonal_wrap():
    """Toroidal single-device domain with diagonal drift: every step every
    agent crosses a ring in both axes (the forwarded-corner path) and the
    population, ids and domain bounds must hold."""
    schema = AgentSchema.create({"diameter": ((), jnp.float32),
                                 "ctype": ((), jnp.int32)})

    def drift(attrs, valid, acc, key, params, dt):
        new = dict(attrs)
        new["pos"] = attrs["pos"] + jnp.where(
            valid[..., None], jnp.asarray([1.7, 1.3]), 0.0)
        return new, valid, jnp.zeros_like(valid), None

    beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
                   pair_attrs=("diameter", "ctype"), update_fn=drift,
                   radius=2.0,
                   params={"repulsion": 0.0, "adhesion": 0.0,
                           "same_type_only": 0.0, "max_step": 0.0})
    geom = Domain(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1),
                    cap=16, boundary="toroidal")
    eng = Engine(geom=geom, behavior=beh, dt=1.0)
    rng = np.random.default_rng(1)
    n = 150
    lx, ly = geom.domain_size
    pos = rng.uniform(0.0, lx, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": np.zeros((n,), np.int32)}
    state = eng.init_state(pos, attrs, seed=0)
    _, state, _ = eng.drive(state, 25)
    assert total_agents(state) == n
    assert int(state.dropped.sum()) == 0
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[
        np.asarray(state.soa.valid).ravel()]
    assert (p >= 0).all() and (p[:, 0] <= lx).all() and (p[:, 1] <= ly).all()
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()
    v = np.asarray(state.soa.valid).ravel()
    keys = gr[v].astype(np.int64) * (1 << 32) + gc[v]
    assert len(np.unique(keys)) == n


# ---------------------------------------------------------------------------
# overlapped interior/boundary split vs the monolithic sweep
# ---------------------------------------------------------------------------

def split_vs_monolithic(eng, state, backend):
    """(overlapped, monolithic) accumulators for one engine state, built
    exactly the way ``Engine.local_step`` builds them: ``soa_pre`` is the
    ring-invalidated SoA (the interior pass's input) and ``soa_post`` the
    SoA after a full-refresh LocalComm aura exchange (wrap fill on
    toroidal axes, cleared ring on closed ones)."""
    geom, beh = eng.geom, eng.behavior
    soa_pre = clear_ring(state.soa)
    idx0 = (0,) * geom.ndim
    refs = {d: {f: v[idx0] for f, v in slab.items()}
            for d, slab in state.refs.items()}
    comm = LocalComm(toroidal=geom.toroidal)
    soa_post, _, _, _ = halo_exchange(
        geom, soa_pre, comm, refs, eng.delta_cfg, True, None)

    fn = jax.jit(lambda pre, post: (
        sweep_accumulate_overlapped(
            geom, pre, post, beh.pair_fn, beh.pair_attrs, beh.radius,
            beh.params, backend=backend),
        sweep_accumulate(
            geom, post, beh.pair_fn, beh.pair_attrs, beh.radius,
            beh.params, backend=backend)))
    return fn(soa_pre, soa_post)


@pytest.mark.parametrize("name", sorted(SIM_BEHAVIORS))
@pytest.mark.parametrize("backend", ["reference", "tiled", "pallas"])
def test_overlapped_split_bitexact_vs_monolithic(name, backend):
    """The interior/boundary split is a pure re-schedule: on the equal
    split every interior cell's accumulators must match the monolithic
    sweep bit-for-bit, per backend, for every bundled sim."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        beh, boundary = SIM_BEHAVIORS[name]
        eng, state = make_state(beh, boundary)
        got, want = split_vs_monolithic(eng, state, backend)
        assert_acc_close(got, want, atol=0)


@pytest.mark.parametrize("backend", ["reference", "tiled", "pallas"])
def test_overlapped_split_bitexact_3d_spheroid(backend):
    """3-D composed spheroid stack: the split recomputes 6 faces whose
    3-plane bands overlap at edges and corners — the idempotent-overwrite
    argument must hold in 3-D too, bit-for-bit."""
    from repro.sims import tumor_spheroid

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        beh = tumor_spheroid.behavior()
        geom = Domain(cell_size=2.0, interior=(3, 4, 5),
                      mesh_shape=(1, 1, 1), cap=12, boundary="closed")
        eng = Engine(geom=geom, behavior=beh, dt=0.1)
        rng = np.random.default_rng(7)
        n = 150
        size = geom.domain_size
        pos = rng.uniform([0.5] * 3, [s - 0.5 for s in size], (n, 3)
                          ).astype(np.float32)
        attrs = {"diameter": rng.uniform(0.6, 1.4, n).astype(np.float32),
                 "ctype": np.ones((n,), np.int32),
                 "nutrient": rng.uniform(0.0, 1.0, n).astype(np.float32)}
        state = eng.init_state(pos, attrs, seed=0)
        got, want = split_vs_monolithic(eng, state, backend)
        assert_acc_close(got, want, atol=0)


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 4, timeout: int = 1800) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_engine_overlap_sharded_matches_sequential():
    """Full driven runs on a 2x2 mesh, overlap on vs off, all three
    backends, equal split and uneven RCB ownership, delta-by-default.

    Equal split: the boundary faces cover every ring-adjacent plane, so
    the whole run (positions, gids, validity) is pinned bit-exact.
    Uneven RCB: the face index is traced (the owned extent), XLA fuses
    the dynamic-sliced band differently, and FMA contraction can flip
    the last bits of float force chains — positions are pinned to 1e-5,
    ids and population exactly."""
    out = run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, Partition
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.sims.common import make_sim

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 300
pos = rng.uniform(0.5, 31.5, size=(n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, size=(n,)).astype(np.int32)}

def key(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[v]
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    o = np.lexsort((gc, gr))
    return p[o], gr[o], gc[o]

def run(overlap, backend, part=None):
    kw = (dict(partition=part) if part is not None
          else dict(interior=(8, 8), mesh_shape=(2, 2)))
    sim = make_sim(beh, cap=24, dt=0.5, overlap=overlap,
                   sweep_backend=backend, **kw)
    sim.init(pos, attrs)
    sim.run(6)
    return key(sim.state)

import warnings
part = Partition(cuts=((0, 6, 16), (0, 9, 16)))
for backend in ("reference", "tiled", "pallas"):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        seq = run("off", backend)
        ovl = run("on", backend)
        for a, b in zip(seq, ovl):
            np.testing.assert_array_equal(a, b)   # equal split: bit-exact
        sequ = run("off", backend, part)
        ovlu = run("on", backend, part)
        np.testing.assert_array_equal(sequ[1], ovlu[1])
        np.testing.assert_array_equal(sequ[2], ovlu[2])
        np.testing.assert_allclose(sequ[0], ovlu[0], atol=1e-5)
    print("OK", backend)
print("OK overlap sharded")
""", devices=4)
    assert "OK overlap sharded" in out
