"""Simulation facade + behavior composition tests.

Parity tests pin the facade's contract: it is a zero-semantics wrapper —
bit-exact with the raw engine loop locally and on a sharded mesh, and
``compose`` of a single behavior is bit-exact with that behavior alone.
The re-shard tests pin the headline API fix: ``sim.engine``/``sim.state``
stay consistent across a mid-run mass migration with no stale-handle
warning on any facade path.

Sharded cases run in subprocesses (XLA placeholder devices must be
configured before jax initializes), same pattern as test_distributed_abm.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AgentSchema, Behavior, Checkpoint, Engine, Domain, Rebalance,
    Simulation, compose, operations, total_agents,
)
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.reshard import estimate_device_runtimes

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


def make_behavior(**over):
    params = {"repulsion": 2.0, "adhesion": 0.4, "same_type_only": 1.0,
              "max_step": 0.5}
    params.update(over.pop("params", {}))
    return Behavior(
        schema=SCHEMA, pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
        radius=over.pop("radius", 2.0), params=params, **over)


def make_inputs(n=250, seed=0, domain=16.0):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.5, domain - 0.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    return pos, attrs


def sorted_positions(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[v]
    return p[np.lexsort(p.T)]


# ---------------------------------------------------------------------------
# facade parity (local)
# ---------------------------------------------------------------------------

def test_facade_matches_raw_engine_bit_exact():
    pos, attrs = make_inputs()
    beh = make_behavior()
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                    cap=24)

    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    s = eng.init_state(pos, attrs, seed=0)
    step = eng.make_local_step()
    for _ in range(8):
        s = step(s, full_halo=True)

    sim = Simulation(geom, beh, dt=0.1).init(pos, attrs, seed=0).run(8)
    np.testing.assert_array_equal(np.asarray(sim.state.soa.attrs["pos"]),
                                  np.asarray(s.soa.attrs["pos"]))
    np.testing.assert_array_equal(np.asarray(sim.state.soa.valid),
                                  np.asarray(s.soa.valid))
    assert sim.iteration == 8 and sim.mesh is None


def test_facade_matches_deprecated_run_sim():
    from repro.sims import common

    pos, attrs = make_inputs()
    beh = make_behavior()
    with pytest.warns(DeprecationWarning):
        eng = common.make_engine(beh, interior=(8, 8))
    s = eng.init_state(pos, attrs, seed=0)
    with pytest.warns(DeprecationWarning):
        s, series = common.run_sim(eng, s, 6,
                                   collect=lambda st: total_agents(st))

    sim = common.make_sim(beh, interior=(8, 8)).init(pos, attrs, seed=0)
    sim.run(6, collect=lambda st: total_agents(st))
    assert sim.series["collect"] == series
    np.testing.assert_array_equal(sorted_positions(sim.state),
                                  sorted_positions(s))


# ---------------------------------------------------------------------------
# behavior composition
# ---------------------------------------------------------------------------

def test_compose_single_behavior_bit_exact():
    pos, attrs = make_inputs()
    beh = make_behavior()
    geom = dict(interior=(8, 8), cap=24)

    sim1 = Simulation(geom, beh, dt=0.1).init(pos, attrs, seed=0).run(8)
    simc = Simulation(geom, compose(beh), dt=0.1).init(
        pos, attrs, seed=0).run(8)
    np.testing.assert_array_equal(np.asarray(simc.state.soa.attrs["pos"]),
                                  np.asarray(sim1.state.soa.attrs["pos"]))


def test_compose_single_spawning_behavior_bit_exact():
    from repro.sims import cell_proliferation as cp

    sims = []
    for behs in (cp.behavior(), compose(cp.behavior())):
        sim = Simulation(dict(interior=(8, 8), cap=32), behs, dt=0.1)
        cp.init(sim, 40, seed=0)
        sims.append(sim.run(10))
    assert sims[0].n_agents() == sims[1].n_agents() > 40
    np.testing.assert_array_equal(sorted_positions(sims[0].state),
                                  sorted_positions(sims[1].state))


def test_compose_merges_schema_params_radius_and_spawn():
    from repro.sims import cell_proliferation as cp, epidemiology as epi

    c = compose(cp.behavior(radius=2.0), epi.behavior(radius=1.5))
    assert c.schema.names() == ("ctype", "diameter", "state")
    assert c.radius == 2.0
    assert c.can_spawn
    assert c.params["b0.repulsion"] == 2.0 and "b1.beta" in c.params
    assert set(c.pair_attrs) == {"ctype", "diameter", "state"}
    with pytest.raises(ValueError):
        compose()
    # conflicting attribute spec across schemas
    other = AgentSchema.create({"diameter": ((), jnp.int32)})
    bad = Behavior(schema=other, pair_fn=c.pair_fn, pair_attrs=(),
                   update_fn=c.update_fn, radius=1.0)
    with pytest.raises(ValueError):
        compose(cp.behavior(), bad)


def test_compose_gates_smaller_radius_kernel():
    """A sub-behavior's pair kernel must not see pairs beyond its own
    radius even though the composed sweep uses the max radius."""

    def count_pair(ai, aj, disp, dist2, params):
        return {"n": jnp.ones_like(dist2)}

    def keep(attrs, valid, acc, key, params, dt):
        return dict(attrs), valid, jnp.zeros_like(valid), None

    near = Behavior(schema=SCHEMA, pair_fn=count_pair, pair_attrs=(),
                    update_fn=keep, radius=1.0)
    far = Behavior(schema=SCHEMA, pair_fn=count_pair, pair_attrs=(),
                   update_fn=keep, radius=2.0)
    comp = compose(near, far)

    # two agents 1.5 apart: only the far kernel may count the pair
    pos = np.asarray([[4.0, 4.0], [5.5, 4.0]], np.float32)
    attrs = {"diameter": np.ones(2, np.float32),
             "ctype": np.zeros(2, np.int32)}
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1), cap=8)
    eng = Engine(geom=geom, behavior=comp, dt=0.1)
    state = eng.init_state(pos, attrs, seed=0)

    from repro.core.neighbors import pair_accumulate
    acc = pair_accumulate(geom, state.soa, comp.pair_fn, comp.pair_attrs,
                          comp.radius, comp.params)
    assert float(jnp.sum(acc["b0.n"])) == 0.0   # gated at radius 1.0
    assert float(jnp.sum(acc["b1.n"])) == 2.0   # one pair, both directions


def test_compose_completes_partial_child_to_union_schema():
    """A spawner whose child dict covers only its own schema must still
    work when composed with a schema-extending behavior: compose fills the
    missing child attributes (e.g. the SIR state) from the parent."""
    from repro.core.agent_soa import POS
    from repro.sims import epidemiology as epi

    schema_a = AgentSchema.create({"diameter": ((), jnp.float32)})

    def no_pair(ai, aj, disp, dist2, params):
        return {"z": jnp.zeros_like(dist2)}

    def spawn_update(attrs, valid, acc, key, params, dt):
        new = dict(attrs)
        child = {POS: new[POS] + 0.05,
                 "diameter": new["diameter"] * 0.5}   # own schema only
        return new, valid, valid, child

    a = Behavior(schema=schema_a, pair_fn=no_pair, pair_attrs=(),
                 update_fn=spawn_update, radius=1.0, can_spawn=True)
    comp = compose(a, epi.behavior(sigma=0.1))

    n = 20
    rng = np.random.default_rng(0)
    pos = rng.uniform(2.0, 14.0, (n, 2)).astype(np.float32)
    st = np.zeros((n,), np.int32)
    st[:5] = epi.I
    sim = Simulation(dict(interior=(8, 8), cap=16), comp, dt=0.1)
    sim.init(pos, {"diameter": np.full((n,), 1.0, np.float32),
                   "state": st}, seed=0)
    sim.run(1)
    assert sim.n_agents() == 2 * n       # every agent spawned one child
    soa = sim.state.soa
    states = np.asarray(soa.attrs["state"]).ravel()[
        np.asarray(soa.valid).ravel()]
    assert set(np.unique(states)) <= {epi.S, epi.I, epi.R}  # inherited


def test_composed_sir_mechanics_sim():
    from repro.sims import sir_mechanics

    state, m = sir_mechanics.run(n_agents=300, steps=30, seed=0)
    ser = m["series"].astype(float)
    assert (ser.sum(axis=1) == 300).all()          # conservation
    assert (np.diff(ser[:, 2]) >= 0).all()         # R monotone
    assert ser[-1, 2] > ser[0, 2] + 50             # epidemic spread
    assert m["same_frac_final"] > m["same_frac_initial"] + 0.15  # clustering
    assert np.isfinite(np.asarray(state.soa.attrs["pos"])).all()


# ---------------------------------------------------------------------------
# scheduled operations
# ---------------------------------------------------------------------------

def test_scheduled_op_cadence_and_series():
    pos, attrs = make_inputs()
    sim = Simulation(dict(interior=(8, 8), cap=24), make_behavior(), dt=0.1)
    sim.init(pos, attrs, seed=0)
    pre_ticks, post_its = [], []
    sim.every(3, lambda s: pre_ticks.append(s.iteration), pre=True,
              record=False)
    sim.every(3, lambda s: s.iteration, name="it")
    sim.every(1, operations.agent_count)
    sim.run(7)
    assert pre_ticks == [0, 3, 6]            # before steps 0, 3, 6
    assert sim.series["it"] == [3, 6]        # after 3 and 6 completed steps
    assert sim.series["agent_count"] == [len(pos)] * 7
    # cadence continues across run() calls
    sim.run(2)
    assert sim.series["it"] == [3, 6, 9]


def test_checkpoint_op_and_elastic_restore_roundtrip(tmp_path):
    pos, attrs = make_inputs(n=120)
    beh = make_behavior()
    sim = Simulation(dict(interior=(8, 8), cap=24), beh, dt=0.1,
                     checkpoint=Checkpoint(str(tmp_path), every=4))
    sim.init(pos, attrs, seed=0)
    sim.run(8)
    from repro.distributed.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 8     # saved after steps 4 and 8

    sim2 = Simulation.restore(str(tmp_path), beh, n_devices=1)
    assert sim2.n_agents() == sim.n_agents()
    assert sim2.iteration == 8
    np.testing.assert_array_equal(sorted_positions(sim2.state),
                                  sorted_positions(sim.state))
    sim2.run(3)                                # restored facade keeps running
    assert sim2.iteration == 11


# ---------------------------------------------------------------------------
# measured runtime attribution (weighted rebalance signal)
# ---------------------------------------------------------------------------

def test_estimate_device_runtimes_weights_dense_devices():
    rng = np.random.default_rng(0)
    n = 300
    # all agents clustered on device (0,0) of a 2x2 mesh; a few elsewhere
    pos = np.concatenate([
        rng.uniform(1.0, 6.0, (n - 10, 2)),
        rng.uniform(17.0, 30.0, (10, 2))]).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": np.zeros((n,), np.int32)}
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2),
                    cap=64)
    eng = Engine(geom=geom, behavior=make_behavior(), dt=0.1)
    state = eng.init_state(pos, attrs, seed=0)

    rt = estimate_device_runtimes(geom, state, wall_s=1.0)
    assert rt.shape == (2, 2)
    assert rt.sum() == pytest.approx(1.0)
    # the dense device dominates the measured-work attribution, and
    # super-linearly vs its agent share (quadratic pair-work signal)
    assert rt[0, 0] > 0.9
    assert rt[0, 0] / max(rt[1, 1], 1e-12) > (n - 10) / 10

    # empty state falls back to a uniform split
    empty = eng.init_state(np.zeros((0, 2), np.float32),
                           {"diameter": np.zeros(0, np.float32),
                            "ctype": np.zeros(0, np.int32)}, seed=0)
    np.testing.assert_allclose(
        estimate_device_runtimes(geom, empty, 1.0), 0.25)


# ---------------------------------------------------------------------------
# sharded execution through the facade (subprocess: needs devices)
# ---------------------------------------------------------------------------

def run_sub(code: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_facade_matches_raw_sharded_loop():
    """Facade on a 2x2 mesh is bit-exact with the hand-built shard_map
    loop — and the facade built its own mesh from the geometry."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, Engine, Domain, Simulation
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 300
pos = rng.uniform(0.5, 31.5, size=(n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}

geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=16)
eng = Engine(geom=geom, behavior=beh, dt=0.1)
s = eng.init_state(pos, attrs, seed=0)
step = eng.make_sharded_step(make_abm_mesh((2, 2)))
for _ in range(8):
    s = step(s, full_halo=True)

sim = Simulation(geom, beh, dt=0.1).init(pos, attrs, seed=0).run(8)
assert sim.mesh is not None and sim.mesh.devices.shape == (2, 2)
np.testing.assert_array_equal(np.asarray(sim.state.soa.attrs["pos"]),
                              np.asarray(s.soa.attrs["pos"]))
np.testing.assert_array_equal(np.asarray(sim.state.soa.valid),
                              np.asarray(s.soa.valid))
print("OK")
""")
    assert "OK" in out


def test_reshard_through_facade_keeps_engine_state_consistent():
    """Mid-run re-shard via the facade: no stale-engine warning anywhere,
    sim.engine/sim.state/sim.mesh all agree on the new mesh, and the
    trajectory still matches the single-device oracle."""
    out = run_sub("""
import warnings, numpy as np, jax, jax.numpy as jnp
from repro.core import (AgentSchema, Behavior, Engine, Domain, Rebalance,
                        Simulation)
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.core.reshard import current_imbalance

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 400
c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}

def sorted_positions(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[v]
    return p[np.lexsort(p.T)]

# single-device oracle
geom1 = Domain(cell_size=2.0, interior=(16, 16), mesh_shape=(1, 1), cap=32)
s1 = Simulation(geom1, beh, dt=0.1).init(pos, attrs, seed=0).run(10)

# facade on the pathological 2x2 split, weighted re-shard allowed at step 5
geom4 = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=32)
sim = Simulation(geom4, beh, dt=0.1,
                 rebalance=Rebalance(every=5, threshold=0.3, weighted=True))
sim.init(pos, attrs, seed=0)
before = current_imbalance(sim.geom, sim.state)
with warnings.catch_warnings():
    warnings.simplefilter("error")      # any stale-engine warning -> fail
    sim.run(10)
assert any(r["applied"] for r in sim.rebalancer.history), \
    sim.rebalancer.history
assert sim.engine.geom.mesh_shape != (2, 2)
assert sim.mesh.devices.shape == sim.engine.geom.mesh_shape
assert sim.state.it.shape == sim.engine.geom.mesh_shape
assert sim.n_agents() == n
after = current_imbalance(sim.geom, sim.state)
assert after * 2 <= before, (before, after)
err = np.max(np.abs(sorted_positions(s1.state) - sorted_positions(sim.state)))
assert err < 1e-4, f"divergence {err}"
# facade keeps running on the new mesh without any caller-side fixup
sim.run(3)
assert sim.iteration == 13
print("OK", before, "->", after, "err", err)
""")
    assert "OK" in out
