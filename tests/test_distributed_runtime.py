"""Checkpoint/restore (incl. elastic), gradient compression, and the int8
collective building block."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as ck
from repro.distributed.grad_compress import DeltaEFCompressor

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    for step in (1, 2, 3, 4):
        ck.save(str(tmp_path), step, tree, extras={"seed": 7}, keep=2)
    assert ck.latest_step(str(tmp_path)) == 4
    # retention pruned old checkpoints
    kept = sorted(p.name for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))
    assert len(kept) == 2
    step, restored, extras = ck.restore(str(tmp_path), like=tree)
    assert step == 4 and extras == {"seed": 7}
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_async_checkpointer(tmp_path):
    tree = {"w": jnp.full((4, 4), 3.0)}
    acp = ck.AsyncCheckpointer(str(tmp_path))
    acp.save(10, tree)
    acp.wait()
    step, restored, _ = ck.restore(str(tmp_path), like=tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_train_resume_bit_identical(tmp_path):
    """Train 4 steps; checkpoint at 2; resume; steps 3-4 must match exactly
    (deterministic pipeline + full state in checkpoint)."""
    from repro.configs.base import get
    from repro.data.pipeline import SyntheticLM
    from repro.models import params as P
    from repro.models.model import build_model
    from repro.training.optimizer import AdamW
    from repro.training.steps import make_train_step

    cfg = get("olmo-1b").smoke
    model = build_model(cfg)
    opt = AdamW()
    pipe = SyntheticLM(cfg, seq_len=32, global_batch=2)
    step_fn = jax.jit(make_train_step(model, opt, remat="none"))

    params = P.init(model.spec, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    for i in range(2):
        params, opt_state, _ = step_fn(params, opt_state,
                                       pipe.batch_for_step(i))
    ck.save(str(tmp_path), 2, {"params": params, "opt": opt_state})
    # continue run A
    pa, oa = params, opt_state
    for i in range(2, 4):
        pa, oa, _ = step_fn(pa, oa, pipe.batch_for_step(i))
    # restore + continue run B
    _, restored, _ = ck.restore(str(tmp_path),
                                like={"params": params, "opt": opt_state})
    pb, ob = restored["params"], restored["opt"]
    for i in range(2, 4):
        pb, ob, _ = step_fn(pb, ob, pipe.batch_for_step(i))
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compress_error_feedback_converges():
    """Quantized-with-EF gradient descent must reach the optimum of a
    quadratic despite 8-bit gradients (the EF-SGD guarantee)."""
    comp = DeltaEFCompressor(qdtype=jnp.int8, refresh_interval=1000)
    w_true = jnp.asarray([1.5, -2.0, 0.5])
    w = jnp.zeros(3)
    ctx = comp.init({"w": w})
    lr = 0.2
    for _ in range(120):
        g = {"w": 2.0 * (w - w_true)}
        g, ctx = comp(g, ctx)
        w = w - lr * g["w"]
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_true), atol=1e-2)


def test_grad_compress_wire_bytes():
    comp = DeltaEFCompressor(qdtype=jnp.int8)
    params = {"w": jnp.zeros((1000,))}
    assert comp.wire_bytes(params, full=False) * 4 == comp.wire_bytes(
        params, full=True)


def test_compressed_psum_int8_on_wire():
    """compressed_psum must (a) approximate the true sum, (b) lower to an
    int8 all-reduce visible in the HLO."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.grad_compress import compressed_psum

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("d",))
def body(x):
    return compressed_psum(x[0], "d", axis_size=4)[None]
from repro.compat import shard_map_compat
f = jax.jit(shard_map_compat(body, mesh=mesh, in_specs=P("d"), out_specs=P("d")))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
got = np.asarray(f(x))
want = np.asarray(jnp.sum(x, axis=0))
err = np.max(np.abs(got - want[None]))
assert err < np.max(np.abs(want)) * 0.05 + 0.05, err
txt = f.lower(x).compile().as_text()
lines = txt.splitlines()
# both wire phases carry s8 payloads of the data size
assert any("all-to-all" in l and "s8[" in l for l in lines), "no s8 a2a"
assert any("all-gather" in l and "s8[" in l for l in lines), "no s8 ag"
# and no f32 all-reduce of the full vector sneaks in
assert not any("all-reduce" in l and "f32[256" in l for l in lines)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert p.returncode == 0, p.stderr
    assert "OK" in p.stdout


def test_elastic_restore_different_device_count(tmp_path):
    """Checkpoint written logically; restore targets a different mesh."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs.base import get
from repro.models.model import build_model
from repro.models import params as P
from repro.distributed import checkpoint as ck
from repro.distributed.elastic import elastic_restore, choose_lm_mesh

cfg = get("olmo-1b").smoke
model = build_model(cfg)
params = P.init(model.spec, jax.random.PRNGKey(0))
ck.save({str(tmp_path)!r}, 5, params)

# restore onto 8 devices (writer was 1 device)
step, restored, mesh, _ = elastic_restore(
    {str(tmp_path)!r}, model, n_devices=8, rules=None)
assert step == 5
assert mesh.devices.size == 8
for a, b in zip(jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# degraded counts factorize sanely
assert choose_lm_mesh(512) == ((32, 16), ("data", "model"))
assert choose_lm_mesh(384) == ((24, 16), ("data", "model"))
assert choose_lm_mesh(100) == ((25, 4), ("data", "model"))
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert p.returncode == 0, p.stderr
    assert "OK" in p.stdout
