"""Re-shard runtime tests: occupancy extraction, planner gains, mid-run
mass migration, and the elastic ABM restore path.

Sharded-mesh cases run in subprocesses (XLA placeholder devices must be
configured before jax initializes), same pattern as test_distributed_abm.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AgentSchema, Behavior, Engine, Domain, Rebalancer, total_agents,
)
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.load_balance import equal_split_loads, imbalance
from repro.core.reshard import (
    current_imbalance,
    flatten_state,
    occupancy_histogram,
    plan_reshard,
    reshard_state,
)
from repro.distributed import checkpoint as ck
from repro.distributed.elastic import elastic_restore_abm

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


def make_behavior():
    return Behavior(
        schema=SCHEMA, pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
        radius=2.0,
        params={"repulsion": 2.0, "adhesion": 0.4, "same_type_only": 1.0,
                "max_step": 0.5})


def clustered_positions(rng, n, domain, centers, sigma=3.0):
    c = np.asarray(centers)[rng.integers(0, len(centers), n)]
    pos = c + rng.normal(0.0, sigma, (n, 2))
    return np.clip(pos, 0.5, domain - 0.5).astype(np.float32)


def make_skewed_state(mesh_shape=(2, 2), n=400, cap=32, seed=0):
    """Gaussian-clustered density: two diagonal clusters on a 32x32 domain —
    pathological for the static 2x2 equal split, near-perfect for a 1-D
    4-way split."""
    gx = gy = 16
    geom = Domain(cell_size=2.0,
                    interior=(gx // mesh_shape[0], gy // mesh_shape[1]),
                    mesh_shape=mesh_shape, cap=cap)
    eng = Engine(geom=geom, behavior=make_behavior(), dt=0.1)
    rng = np.random.default_rng(seed)
    pos = clustered_positions(rng, n, 32.0, [(8.0, 8.0), (24.0, 24.0)])
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    return eng, eng.init_state(pos, attrs, seed=seed)


def gid_set(state):
    v = np.asarray(state.soa.valid).ravel()
    r = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    c = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    return set(zip(r.tolist(), c.tolist()))


# ---------------------------------------------------------------------------
# occupancy histogram
# ---------------------------------------------------------------------------

def test_occupancy_histogram_counts_interior_agents_exactly():
    eng, state = make_skewed_state()
    hist = occupancy_histogram(eng.geom, state)
    assert hist.shape == eng.geom.box_grid
    assert hist.sum() == total_agents(state)
    loads = equal_split_loads(hist, eng.geom.mesh_shape)
    # diagonal clusters: the two off-diagonal quadrants are near-empty
    assert loads.min() < 0.05 * loads.max()


def test_occupancy_histogram_excludes_aura_copies():
    """After a step the halo ring holds neighbor copies; the histogram must
    still sum to the live agent count."""
    eng, state = make_skewed_state(mesh_shape=(1, 1))
    step = eng.make_local_step()
    state = step(state, full_halo=True)
    hist = occupancy_histogram(eng.geom, state)
    assert hist.sum() == total_agents(state)


def test_occupancy_histogram_runtime_weighting():
    eng, state = make_skewed_state()
    n = total_agents(state)
    base = occupancy_histogram(eng.geom, state)
    rt = np.asarray([[10.0, 1.0], [1.0, 1.0]])
    weighted = occupancy_histogram(eng.geom, state, runtimes=rt)
    assert weighted.sum() == pytest.approx(n)
    bx, by = eng.geom.box_grid
    per_agent_00 = (weighted[:bx // 2, :by // 2].sum()
                    / base[:bx // 2, :by // 2].sum())
    per_agent_11 = (weighted[bx // 2:, by // 2:].sum()
                    / base[bx // 2:, by // 2:].sum())
    # device (0,0) measured 10x slower -> its boxes weigh ~10x more per agent
    assert per_agent_00 / per_agent_11 == pytest.approx(10.0, rel=0.3)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def test_plan_reshard_reduces_imbalance_on_skewed_density():
    eng, state = make_skewed_state()
    hist = occupancy_histogram(eng.geom, state)
    plan = plan_reshard(hist, eng.geom)
    assert plan.current > 1.0
    assert plan.imbalance * 2 <= plan.current
    assert plan.mesh_shape != eng.geom.mesh_shape
    # box-granular RCB bound is also a strict improvement on the static split
    assert plan.rcb_bound is not None and plan.rcb_bound < plan.current


def test_plan_reshard_reports_diffusive_bound_on_1d_mesh():
    """One diffusive step over a heavily end-loaded 1-D chain must move
    load toward balance (it is iterative, so near-balanced densities may
    oscillate — that is the planner's documented behavior, not a bug)."""
    gx = gy = 16
    geom = Domain(cell_size=2.0, interior=(4, 16), mesh_shape=(4, 1),
                    cap=48)
    eng = Engine(geom=geom, behavior=make_behavior(), dt=0.1)
    rng = np.random.default_rng(0)
    n = 400
    pos = clustered_positions(rng, n, 32.0, [(4.0, 16.0)], sigma=3.0)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    state = eng.init_state(pos, attrs)
    hist = occupancy_histogram(eng.geom, state)
    plan = plan_reshard(hist, eng.geom)
    assert plan.diffusive_bound is not None
    assert plan.diffusive_bound < plan.current


# ---------------------------------------------------------------------------
# mass migration (host path; mesh-sharded execution covered below)
# ---------------------------------------------------------------------------

def test_reshard_preserves_agents_gids_iteration_and_drop_count():
    eng, state = make_skewed_state()
    state.dropped = state.dropped.at[1, 1].add(jnp.int32(3))
    gids_before = gid_set(state)
    n = total_agents(state)
    eng2, state2 = reshard_state(eng, state, (1, 4))
    assert eng2.geom.mesh_shape == (1, 4)
    assert eng2.geom.interior == (16, 4)
    assert total_agents(state2) == n
    assert gid_set(state2) == gids_before
    assert int(np.asarray(state2.dropped).sum()) == 3
    assert int(np.max(np.asarray(state2.it))) == int(
        np.max(np.asarray(state.it)))


def test_reshard_spawn_counters_never_reissue_gids():
    """Per-rank counters after a re-shard must exceed every carried id of
    that rank, so post-reshard spawns cannot collide."""
    eng, state = make_skewed_state()
    eng2, state2 = reshard_state(eng, state, (4, 1))
    counters = np.asarray(state2.gid_counter).ravel()
    v = np.asarray(state2.soa.valid).ravel()
    ranks = np.asarray(state2.soa.attrs["gid_rank"]).ravel()[v]
    counts = np.asarray(state2.soa.attrs["gid_count"]).ravel()[v]
    for r in range(counters.size):
        mine = counts[ranks == r]
        if mine.size:
            assert counters[r] > mine.max()


def test_gid_floors_survive_mesh_downsize():
    """Counters are exact issuance trackers: restoring onto a smaller mesh
    must keep every new rank's counter above the *global* floor bound, so
    ids issued by dropped ranks (even to since-dead agents) are never
    reissued after a later re-expansion."""
    geom = Domain(cell_size=2.0, interior=(8, 16), mesh_shape=(2, 1),
                    cap=32)
    eng = Engine(geom=geom, behavior=make_behavior(), dt=0.1)
    rng = np.random.default_rng(0)
    n = 20
    pos = rng.uniform(0.5, 31.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32),
             "gid_rank": np.zeros(n, np.int32),
             "gid_count": np.arange(n, dtype=np.int32)}
    # floors from a previous 4-rank mesh; rank 3 issued up to id 38
    state = eng.init_state(pos, attrs,
                           gid_counters=np.asarray([5, 5, 5, 39]))
    assert (np.asarray(state.gid_counter) >= 39).all()


def test_rebalancer_acceptance_two_x_reduction_and_conservation():
    """Acceptance demo: Gaussian-clustered density on a 2x2 mesh — the
    Rebalancer must cut imbalance() by >= 2x vs the static equal split and
    conserve the agent population."""
    eng, state = make_skewed_state(mesh_shape=(2, 2))
    n = total_agents(state)
    before = current_imbalance(eng.geom, state)
    rb = Rebalancer(every=1, threshold=0.2)
    eng2, state2, resharded = rb.maybe_reshard(eng, state)
    assert resharded
    after = current_imbalance(eng2.geom, state2)
    assert after * 2 <= before
    assert total_agents(state2) == n
    rec = rb.history[-1]
    assert rec["applied"] and rec["mesh_to"] == eng2.geom.mesh_shape


def test_rebalancer_declines_below_threshold_and_without_gain():
    # uniform density: already balanced -> below threshold, no re-shard
    gx = gy = 16
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=32)
    eng = Engine(geom=geom, behavior=make_behavior(), dt=0.1)
    rng = np.random.default_rng(1)
    n = 400
    pos = rng.uniform(0.5, 31.5, (n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    state = eng.init_state(pos, attrs)
    rb = Rebalancer(every=1, threshold=0.5)
    eng2, state2, resharded = rb.maybe_reshard(eng, state)
    assert not resharded and eng2 is eng
    assert rb.history[-1]["applied"] is False
    # skewed but no realizable gain (threshold 0 + huge min_gain) -> declined
    eng, state = make_skewed_state()
    rb = Rebalancer(every=1, threshold=0.0, min_gain=1e9)
    _, _, resharded = rb.maybe_reshard(eng, state)
    assert not resharded


def test_reshard_transport_validation():
    eng, state = make_skewed_state()
    with pytest.raises(ValueError, match="transport"):
        reshard_state(eng, state, (1, 4), transport="carrier-pigeon")
    # explicit device transport without enough real devices must refuse
    # loudly, never silently fall back to the host round trip
    with pytest.raises(ValueError, match="use the host path"):
        reshard_state(eng, state, (1, 4), transport="device")


def test_reshard_auto_transport_falls_back_to_host_when_unrealizable():
    """auto on a single real device (this test process) must take the host
    path and still produce the full re-shard result."""
    eng, state = make_skewed_state()
    gids = gid_set(state)
    eng2, state2 = reshard_state(eng, state, (4, 1), transport="auto")
    assert eng2.geom.mesh_shape == (4, 1)
    assert gid_set(state2) == gids


def test_flatten_state_roundtrip_single_device():
    eng, state = make_skewed_state(mesh_shape=(1, 1))
    flat = flatten_state(eng.geom, state)
    assert flat.positions.shape == (total_agents(state), 2)
    eng2, state2 = reshard_state(eng, state, (1, 1))
    p1 = np.sort(flat.positions, axis=0)
    flat2 = flatten_state(eng2.geom, state2)
    np.testing.assert_array_equal(p1, np.sort(flat2.positions, axis=0))


# ---------------------------------------------------------------------------
# elastic ABM restore
# ---------------------------------------------------------------------------

def test_elastic_abm_restore_onto_different_device_count(tmp_path):
    eng, state = make_skewed_state(mesh_shape=(1, 1))
    step = eng.make_local_step()
    for _ in range(3):
        state = step(state, full_halo=True)
    n = total_agents(state)
    ck.save_abm(str(tmp_path), 3, eng, state)

    eng4, state4, step_ = elastic_restore_abm(str(tmp_path),
                                              make_behavior(), n_devices=4)
    assert step_ == 3
    assert int(np.prod(eng4.geom.mesh_shape)) == 4
    assert total_agents(state4) == n
    assert gid_set(state4) == gid_set(state)
    assert int(np.max(np.asarray(state4.it))) == 3
    # the chosen mesh beats the naive 2x2 equal split on this density
    hist = occupancy_histogram(eng4.geom, state4)
    assert imbalance(equal_split_loads(hist, eng4.geom.mesh_shape)) <= \
        imbalance(equal_split_loads(hist, (2, 2)))

    # degraded, non-power-of-two survivor counts factorize too
    eng3, state3, _ = elastic_restore_abm(str(tmp_path),
                                          make_behavior(), n_devices=2)
    assert int(np.prod(eng3.geom.mesh_shape)) == 2
    assert total_agents(state3) == n


# ---------------------------------------------------------------------------
# sharded execution across a mid-run re-shard (subprocess: needs devices)
# ---------------------------------------------------------------------------

def run_sub(code: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_mid_run_reshard_matches_single_device_oracle():
    """A distributed sim re-sharded mid-run conserves the population and
    tracks the single-device oracle's positions."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, Engine, Domain, Rebalancer, total_agents
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.core.reshard import current_imbalance
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 400
c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}

def sorted_positions(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[v]
    return p[np.lexsort(p.T)]

# single-device oracle
geom1 = Domain(cell_size=2.0, interior=(16, 16), mesh_shape=(1, 1), cap=32)
eng1 = Engine(geom=geom1, behavior=beh, dt=0.1)
s1 = eng1.init_state(pos, attrs, seed=0)
step1 = eng1.make_local_step()
for _ in range(10):
    s1 = step1(s1, full_halo=True)

# distributed on the pathological 2x2 split, re-shard allowed at step 5
geom4 = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=32)
eng4 = Engine(geom=geom4, behavior=beh, dt=0.1)
s4 = eng4.init_state(pos, attrs, seed=0)
before = current_imbalance(eng4.geom, s4)
rb = Rebalancer(every=5, threshold=0.3)
step4 = eng4.make_sharded_step(make_abm_mesh((2, 2)))
eng_out, s4, _ = eng4.drive(s4, 10, step_fn=step4, rebalancer=rb)
assert any(r["applied"] for r in rb.history), rb.history
assert eng_out.geom.mesh_shape != (2, 2)
after = current_imbalance(eng_out.geom, s4)
assert total_agents(s4) == n, "agent loss across re-shard"
err = np.max(np.abs(sorted_positions(s1) - sorted_positions(s4)))
assert err < 1e-4, f"divergence {err}"
assert after * 2 <= before, (before, after)
print("OK", before, "->", after, "err", err)
""")
    assert "OK" in out


def test_device_reshard_bit_exact_vs_host_and_zero_host_bytes():
    """The device-to-device transport must reproduce the host path
    bit-for-bit (slots, carry, RNG lineage) on fresh AND stepped states,
    for equal-split and uneven-partition targets — and must never call
    ``flatten_state`` (no agent bytes through host)."""
    out = run_sub("""
import numpy as np, jax.numpy as jnp
import repro.core.reshard as rs
from repro.core import AgentSchema, Behavior, Engine, Domain
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.core.reshard import (occupancy_histogram, plan_reshard,
                                reshard_state)

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})

def make(seed=0):
    geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=32)
    eng = Engine(geom=geom, behavior=beh, dt=0.1)
    rng = np.random.default_rng(seed)
    n = 400
    c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
    pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    return eng, eng.init_state(pos, attrs, seed=seed)

for stepped in (False, True):
    for target in ("equal", "partition"):
        eng, st = make()
        if stepped:
            eng, st, _ = eng.drive(st, 3)
        if target == "equal":
            kw = dict(mesh_shape=(4, 1))
        else:
            plan = plan_reshard(occupancy_histogram(eng.geom, st), eng.geom)
            kw = dict(partition=plan.partition)
        eh, sh = reshard_state(eng, st, transport="host", **kw)

        orig, calls = rs.flatten_state, []
        rs.flatten_state = lambda *a, **k: calls.append(1)
        try:
            ed, sd = reshard_state(eng, st, transport="device", **kw)
        finally:
            rs.flatten_state = orig
        assert not calls, "device path touched flatten_state"
        assert eh.geom == ed.geom
        np.testing.assert_array_equal(np.asarray(sh.soa.valid),
                                      np.asarray(sd.soa.valid))
        for name in sh.soa.attrs:
            np.testing.assert_array_equal(np.asarray(sh.soa.attrs[name]),
                                          np.asarray(sd.soa.attrs[name]),
                                          err_msg=name)
        for f in ("it", "key", "gid_counter", "dropped"):
            np.testing.assert_array_equal(np.asarray(getattr(sh, f)),
                                          np.asarray(getattr(sd, f)),
                                          err_msg=f)
        print("bit-exact", "stepped" if stepped else "fresh", target)
print("OK")
""")
    assert "OK" in out


def test_deferred_rebalance_overlaps_plan_with_device_migration():
    """defer=True: the snapshot tick returns without re-sharding (the old
    mesh keeps stepping), the decision lands one step later, applied
    migrations ride the device transport, and the population is
    conserved."""
    out = run_sub("""
import numpy as np, jax.numpy as jnp
import repro.core.reshard as rs
from repro.core import AgentSchema, Behavior, Engine, Domain, Rebalancer, total_agents
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=32)
eng = Engine(geom=geom, behavior=beh, dt=0.1)
rng = np.random.default_rng(0)
n = 400
c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}
st = eng.init_state(pos, attrs, seed=0)

orig, calls = rs.flatten_state, []
rs.flatten_state = lambda *a, **k: calls.append(1)
try:
    rb = Rebalancer(every=4, threshold=0.2, min_gain=1.05,
                    ownership="rcb", defer=True)
    e2, s2, _ = eng.drive(st, 12, rebalancer=rb)
finally:
    rs.flatten_state = orig
applied = [h for h in rb.history if h["applied"]]
assert applied, rb.history
# phase 2 lands one step after the every=4 snapshot ticks
assert all(h["it"] % 4 == 1 for h in rb.history), rb.history
assert all(h.get("deferred") for h in rb.history)
assert all(h["transport"] == "device" for h in applied)
assert not calls, "deferred device migration touched flatten_state"
assert total_agents(s2) + int(np.sum(np.asarray(s2.dropped))) == n
print("OK")
""")
    assert "OK" in out


def test_mid_run_reshard_with_delta_encoding_forces_full_refresh():
    """Re-shard zeroes the delta references; the driver must force a full
    aura refresh so the run stays bounded-drift."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (AgentSchema, Behavior, DeltaConfig, Engine, Domain,
                        Rebalancer, total_agents)
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 400
c = np.asarray([(8.0, 8.0), (24.0, 24.0)])[rng.integers(0, 2, n)]
pos = np.clip(c + rng.normal(0, 3.0, (n, 2)), 0.5, 31.5).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, n).astype(np.int32)}

geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 2), cap=32)
cfg = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=8)
eng = Engine(geom=geom, behavior=beh, delta_cfg=cfg, dt=0.1)
s = eng.init_state(pos, attrs, seed=0)
rb = Rebalancer(every=3, threshold=0.3)
step = eng.make_sharded_step(make_abm_mesh((2, 2)))
eng_out, s, _ = eng.drive(s, 9, step_fn=step, rebalancer=rb)
assert any(r["applied"] for r in rb.history)
assert total_agents(s) == n
pos_f = np.asarray(s.soa.attrs["pos"]).reshape(-1, 2)[
    np.asarray(s.soa.valid).ravel()]
assert np.isfinite(pos_f).all()
print("OK")
""")
    assert "OK" in out
